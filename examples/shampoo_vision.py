"""Example 4: Shampoo with PRISM inverse roots on a vision-style task
(the paper's Fig. 5 setting, CPU-scaled).

    PYTHONPATH=src python examples/shampoo_vision.py

Compares eigendecomposition vs PolarExpress vs PRISM as the inverse-root
backend inside the *same* Shampoo optimizer on synthetic CIFAR-shaped
data, printing loss trajectories and per-step wall time.
"""
import sys

sys.path.insert(0, "src")

from benchmarks import fig5_shampoo

if __name__ == "__main__":
    fig5_shampoo.run()
