"""Quickstart: PRISM matrix functions in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the unified ``repro.core.matfn`` API — polar factor
(orthogonalization), matrix square roots, inverses and inverse p-th
roots — comparing PRISM's distribution-free adaptive iterations against
the classical Newton-Schulz and the dense-LA oracles.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

key = jax.random.PRNGKey(0)
cfg = PrismConfig(degree=2, sketch_dim=8)

print("== polar factor (the Muon primitive) ==")
# a nasty spectrum: singular values log-uniform down to 1e-5 — PolarExpress
# is tuned for 1e-3 and classical NS crawls; PRISM adapts per-iteration.
A = rm.log_uniform_spectrum(key, 512, 256, 1e-5)
ref = matfn.polar(A, method="svd")
for method, kw in [("prism", dict(cfg=cfg, key=key, iters=30)),
                   ("newton_schulz", dict(cfg=cfg, iters=30)),
                   ("polar_express", dict(iters=30))]:
    X, info = matfn.polar(A, method=method, return_info=True, **kw)
    res = info.residual_fro if hasattr(info, "residual_fro") else info
    import numpy as np

    it = int(np.argmax(np.asarray(res) / 16 < 1e-3)) or 30
    err = float(jnp.linalg.norm(X - ref) / jnp.linalg.norm(ref))
    print(f"  {method:15s} iters-to-tol ~{it:2d}  rel err {err:.2e}")

print("== matrix square root / inverse square root (Shampoo) ==")
S = rm.spd_with_eigs(key, 256, jnp.linspace(1e-4, 1.0, 256))
sq, isq = matfn.sqrtm(S, method="prism", cfg=cfg, key=key, iters=20)
sq_ref, isq_ref = matfn.sqrtm(S, method="eigh")
print(f"  prism  sqrt err {float(jnp.linalg.norm(sq - sq_ref) / jnp.linalg.norm(sq_ref)):.2e}  "
      f"invsqrt err {float(jnp.linalg.norm(isq - isq_ref) / jnp.linalg.norm(isq_ref)):.2e}")

print("== inverse (PRISM-Chebyshev) and inverse 4th root ==")
B = rm.spd_with_eigs(key, 128, jnp.linspace(0.05, 1.0, 128))
inv = matfn.inv(B, method="prism_chebyshev", iters=30, key=key)
print(f"  inv err {float(jnp.linalg.norm(B @ inv - jnp.eye(128)) / 11.3):.2e}")
r4 = matfn.inv_proot(B, p=4, iters=30, key=key)
r4_ref = matfn.inv_proot(B, p=4, method="eigh")
print(f"  inv 4th-root err {float(jnp.linalg.norm(r4 - r4_ref) / jnp.linalg.norm(r4_ref)):.2e}")

print("== alphas adapt to the spectrum (the PRISM idea) ==")
for name, Amat in [("gaussian", rm.gaussian(key, 256, 256)),
                   ("heavy-tail htmp(0.1)", rm.htmp(key, 256, 128, 0.1))]:
    _, info = matfn.polar(Amat, method="prism", cfg=cfg, key=key, iters=8,
                          return_info=True)
    al = [round(float(a), 3) for a in info.alphas]
    print(f"  {name:22s} alpha_k = {al}")

print("== iteration counts adapt too (adaptive early stopping, tol) ==")
# One bucket, one residual target: with PrismConfig.tol set, every fitted
# iteration reads a convergence certificate est_r ~ ||R_k||_F off the
# sketched trace chain it already computes, and each matrix freezes the
# moment it certifies — `iterations` becomes a budget, and iters_used
# reports what each instance actually needed.  A fixed-iters engine must
# provision for the worst instance; the certificate refunds the rest.
tol_cfg = PrismConfig(degree=2, sketch_dim=8, iterations=20,
                      warm_alpha_iters=1, tol=2e-2)
bucket = jnp.stack([rm.gaussian(key, 256, 256),                  # easy
                    rm.log_uniform_spectrum(jax.random.fold_in(key, 7),
                                            256, 256, 1e-5)])    # nasty
X, iters_used = matfn.polar(bucket, method="prism", cfg=tol_cfg, key=key,
                            return_iters=True)
resid = jnp.linalg.norm(
    jnp.eye(256) - jnp.swapaxes(X, -1, -2) @ X, axis=(-2, -1))
for name, it, r in zip(["well-conditioned gaussian",
                        "ill-conditioned (1e-5)"], iters_used, resid):
    print(f"  {name:26s} iters_used = {int(it):2d} / budget 20   "
          f"||I - X^T X||_F = {float(r):.1e}  (tol 2e-2)")
print("done.")
