"""Serving example: batched prefill + KV-cache decode with greedy sampling.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b --smoke

Prefills a batch of prompts (last-token logits only — real serving
semantics), then decodes tokens autoregressively against the rolling KV
cache via ``serve_step``.  The same ``serve_step`` is what the multi-pod
dry-run lowers for the decode_32k / long_500k cells.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build
from repro.models.inputs import make_train_batch
from repro.serving import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--gen_len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B = args.batch

    # ---- "prefill" by streaming the prompt through decode steps (keeps
    # the example single-code-path; production prefill uses model.prefill)
    prompts = make_train_batch(key, cfg, B, args.prompt_len)["tokens"]
    cache = model.init_cache(B, args.prompt_len + args.gen_len)
    serve_step = jax.jit(make_serve_step(model))

    t0 = time.perf_counter()
    nxt = None
    for t in range(args.prompt_len):
        tok = prompts[..., t:t + 1]
        pos = jnp.full((B, 1), t, jnp.int32)
        _, nxt, cache = serve_step(params, cache, tok, pos)
    prefill_s = time.perf_counter() - t0

    # ---- autoregressive greedy decode
    generated = []
    tok = nxt.reshape(prompts[..., :1].shape)
    t0 = time.perf_counter()
    for t in range(args.prompt_len, args.prompt_len + args.gen_len):
        pos = jnp.full((B, 1), t, jnp.int32)
        _, nxt, cache = serve_step(params, cache, tok, pos)
        tok = nxt.reshape(tok.shape)
        generated.append(jax.device_get(tok))
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0

    print(f"arch={cfg.name} batch={B}")
    print(f"prompt streaming: {prefill_s:.2f}s; decode: "
          f"{decode_s / args.gen_len * 1000:.1f} ms/token (batched x{B})")
    first = [int(g.reshape(B, -1)[0, 0]) for g in generated]
    print(f"sample 0 generated token ids: {first}")


if __name__ == "__main__":
    main()
