"""End-to-end training driver: the paper's Sec. 6.2 Muon experiment.

Trains the paper's GPT-2 config (10 layers, 16 heads, d=1024 — ~130M
params) with Muon + PRISM-accelerated polar decomposition on the
deterministic synthetic bigram stream, with checkpoint/restart enabled.

    PYTHONPATH=src python examples/train_lm.py --steps 300        # full
    PYTHONPATH=src python examples/train_lm.py --preset cpu-small # quick

Kill it mid-run and re-invoke: it resumes from the newest checkpoint.
On a TPU fleet add --mesh production (see repro/launch/train.py).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.config import OptimizerConfig, PrismConfig, TrainConfig
from repro.configs import get_config
from repro.data import DataConfig
from repro.models import build
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", default="full",
                    choices=["full", "cpu-small"])
    ap.add_argument("--method", default="prism",
                    choices=["prism", "polar_express", "newton_schulz"])
    ap.add_argument("--ckpt_dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--precond_every", type=int, default=1,
                    help="refresh the orthogonalization every K steps, "
                         "serving cached polar factors in between "
                         "(DESIGN.md §8)")
    ap.add_argument("--matfn_dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="compute dtype of the matrix-function engine — "
                         "bfloat16 halves chain HBM traffic and cached "
                         "optimizer state; accumulation and the PRISM "
                         "fit stay fp32 (DESIGN.md §9)")
    args = ap.parse_args()

    cfg = get_config("gpt2-paper")
    if args.preset == "cpu-small":
        cfg = cfg.replace(num_layers=4, d_model=256, num_heads=8,
                          num_kv_heads=8, head_dim=32, d_ff=1024,
                          vocab_size=4096)
        seq, batch = 128, 8
    else:
        seq, batch = 512, 4  # ~2M tokens over 300 steps, CPU-feasible
    model = build(cfg)
    n_params = sum(
        int(__import__("numpy").prod(s.shape))
        for s in __import__("jax").tree.leaves(model.param_shapes()))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    ocfg = OptimizerConfig(
        name="muon", learning_rate=6e-3, momentum=0.95, weight_decay=0.01,
        matfn_method=args.method, precond_every=args.precond_every,
        matfn_dtype=args.matfn_dtype,
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=3,
                          sketch_dim=8))
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=50, log_every=10)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, markov_rank=64)
    trainer = Trainer(model, ocfg, tcfg, dcfg)
    _, _, losses = trainer.run()
    print(f"first-10 mean loss {sum(losses[:10]) / 10:.4f} -> "
          f"last-10 mean loss {sum(losses[-10:]) / 10:.4f}")
    if trainer.straggler_events:
        print(f"straggler events: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
