"""Logical activation-sharding context (MaxText-style constraints).

Model code annotates activations with *logical* axis names:

    x = shard_activation(x, ("batch", "seq", "embed"))

Outside any context this is a no-op (CPU tests, single-device runs).  The
launcher/dry-run installs (mesh, rules) via ``activation_sharding(...)``;
annotations then become ``with_sharding_constraint``s.  Without them GSPMD
happily picks replicated layouts for scan carries (verified on the dry-run:
attention ran fully replicated across the model axis because the
online-softmax carry had no sharding preference).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax

_CTX: list = []


def compat_shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """shard_map across jax versions: jax.shard_map (new; check_vma,
    axis_names = mapped axes) vs jax.experimental.shard_map (0.4.x;
    check_rep, auto = UNmapped axes).  Replication checking is disabled
    either way: the bodies we map (matrix-function chains, int8 psum)
    return all-gathered results whose replication the checker cannot
    always infer.  ``axis_names`` restricts manual mode to those mesh
    axes (None = all)."""
    sm = getattr(jax, "shard_map", None)
    kw: dict = {}
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
        kw["check_rep"] = False
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    else:
        kw["check_vma"] = False
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)
    except TypeError:  # intermediate releases: jax.shard_map + check_rep
        kw.pop("check_vma", None)
        kw["check_rep"] = False
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kw)


@contextlib.contextmanager
def activation_sharding(mesh, rules: Dict[str, Any]):
    _CTX.append((mesh, rules))
    try:
        yield
    finally:
        _CTX.pop()


@contextlib.contextmanager
def suspend_activation_sharding():
    """Temporarily disable activation constraints (trace-time scoped).

    Used while tracing code that runs *inside* a shard_map body manual
    over some mesh axis (the 1F1B pipeline stages): there,
    ``with_sharding_constraint`` against the full mesh is illegal, and
    GSPMD infers layouts for the remaining auto axes on its own."""
    saved = list(_CTX)
    _CTX.clear()
    try:
        yield
    finally:
        _CTX.clear()
        _CTX.extend(saved)


def current() -> Optional[Tuple[Any, Dict[str, Any]]]:
    return _CTX[-1] if _CTX else None


def shard_activation(x: jax.Array, axes: Tuple) -> jax.Array:
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    from jax.sharding import NamedSharding, PartitionSpec as P

    entries = []
    used: set = set()  # dedup: batch (leftmost) wins over later axes
    for dim, a in zip(x.shape, axes):
        e = rules.get(a, None)
        if e is not None:
            axs = [ax for ax in (e if isinstance(e, (tuple, list))
                                 else (e,)) if ax not in used]
            n = 1
            for ax in axs:
                n *= mesh.shape[ax]
            # constraints tolerate uneven (padded) sharding, unlike jit
            # in_shardings; only refuse when shards would outnumber rows
            if not axs or dim < n:
                e = None
            else:
                used.update(axs)
                e = tuple(axs) if len(axs) > 1 else axs[0]
        entries.append(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
