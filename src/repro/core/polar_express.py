"""PolarExpress baseline (Amsel et al. 2025, Algorithm 1).

Minimax-optimal composed degree-5 polynomials for the polar factor,
pre-optimized for singular values in [1e-3, 1] — the exact variant the
PRISM paper compares against (and the one that degrades when the true
sigma_min deviates from 1e-3; reproduced in benchmarks/fig1_sigma_sweep.py).

Coefficients are the published Algorithm-1 schedule; after the listed
iterations the (numerically safe) asymptotic tuple (1.875, -1.25, 0.375)
repeats.  Each update is X <- a X + b X (X^T X) + c X (X^T X)^2.

Via Higham's Theorem 3 the same h(M) = aI + bM + cM^2 schedule runs in
coupled form for the (inverse) square root.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import _mm  # fp32-accumulate (DESIGN.md §9)

# Algorithm 1 of Amsel et al. (2025), sigma_min = 1e-3 variant.
POLAR_EXPRESS_COEFFS: Tuple[Tuple[float, float, float], ...] = (
    (8.28721201814563, -23.595886519098837, 17.300387312530933),
    (4.107059111542203, -2.9478499167379106, 0.5448431082926601),
    (3.9486908534822946, -2.908902115962949, 0.5518191394370137),
    (3.3184196573706015, -2.488488024314874, 0.51004894012372),
    (2.300652019954817, -1.6689039845747493, 0.4188073119525673),
    (1.891301407787398, -1.2679958271945868, 0.37680408948524835),
    (1.8750014808534479, -1.2500016453999487, 0.3750001645474248),
    (1.875, -1.25, 0.375),
)
_SAFETY = 1.01  # Amsel et al. divide by 1.01 * ||A||_F for bf16 safety


def _fro(M):
    return jnp.sqrt(jnp.sum(jnp.square(M.astype(jnp.float32)),
                            axis=(-2, -1), keepdims=True))


def _coeff(k: int) -> Tuple[float, float, float]:
    return POLAR_EXPRESS_COEFFS[min(k, len(POLAR_EXPRESS_COEFFS) - 1)]


def polar(A: jax.Array, iters: int = 8, dtype=jnp.float32,
          return_info: bool = False):
    """Polar factor of A [..., m, n] via PolarExpress."""
    transpose = A.shape[-2] < A.shape[-1]
    X = jnp.swapaxes(A, -1, -2) if transpose else A
    in_dtype = X.dtype
    X = X.astype(dtype) / (_SAFETY * _fro(X).astype(dtype))
    fros = []
    for k in range(iters):
        a, b, c = _coeff(k)
        M = _mm(jnp.swapaxes(X, -1, -2), X)
        if return_info:
            eye = jnp.eye(M.shape[-1], dtype=M.dtype)
            fros.append(_fro(eye - M)[..., 0, 0])
        M2 = _mm(M, M)
        X = a * X + b * _mm(X, M) + c * _mm(X, M2)
    X = jnp.swapaxes(X, -1, -2) if transpose else X
    X = X.astype(in_dtype)
    if return_info:
        return X, jnp.stack(fros)
    return X


def sqrtm(A: jax.Array, iters: int = 8, dtype=jnp.float32,
          return_info: bool = False):
    """(A^{1/2}, A^{-1/2}) via PolarExpress in coupled form (Thm 3).

    Any sign iteration X <- X h(X^2) couples as X <- X h(YX), Y <- h(YX) Y.
    For PolarExpress on the square root, the optimized interval [1e-3, 1]
    on singular values becomes [1e-6, 1] on eigenvalues of YX (the paper's
    Fig. 1 note).
    """
    in_dtype = A.dtype
    c0 = _SAFETY * _fro(A).astype(dtype)
    X = A.astype(dtype) / c0
    Y = jnp.broadcast_to(jnp.eye(X.shape[-1], dtype=dtype), X.shape)
    fros = []
    for k in range(iters):
        a, b, c = _coeff(k)
        M = _mm(Y, X)
        if return_info:
            eye = jnp.eye(M.shape[-1], dtype=M.dtype)
            fros.append(_fro(eye - M)[..., 0, 0])
        M2 = _mm(M, M)
        H = a * jnp.broadcast_to(jnp.eye(M.shape[-1], dtype=M.dtype), M.shape) \
            + b * M + c * M2
        X = _mm(X, H)
        Y = _mm(H, Y)
    sc = jnp.sqrt(c0)
    out = (X * sc).astype(in_dtype), (Y / sc).astype(in_dtype)
    if return_info:
        return out, jnp.stack(fros)
    return out
