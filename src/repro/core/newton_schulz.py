"""Newton-Schulz iterations (classical + PRISM-accelerated).

Covers the paper's Table-1 rows:
  * matrix sign                 X_{k+1} = X_k g_d(R_k; a),  R_k = I - X_k^2
  * square / inverse sqrt       coupled (X, Y), R_k = I - X_k Y_k   (Thm 3)
  * polar factor U V^T          R_k = I - X_k^T X_k                 (Thm 4)

for d=1 (3rd order) and d=2 (5th order).  ``alpha`` per iteration is either
the classical Taylor coefficient, a fixed warm value u (paper Sec. C), or
the PRISM sketched fit (core/prism.py).

All entry points broadcast over leading batch dims (stacked layer params)
and are jit/vmap/grad-safe; iteration counts are static Python ints so warm
iterations compile to zero fitting overhead.

Phase structure (DESIGN.md §10): every chain is an explicit sequence of
WARM phases — maximal runs of iterations whose alpha is a static Python
float (the PRISM warm-start value u, or the classical Taylor coefficient,
which makes a whole classical chain one phase) — and FIT iterations,
whose alpha is the sketched argmin and therefore data-dependent.  With
``use_kernels`` and the fused kernel tier engaged (``cfg.fuse``, chosen
at trace time from the matrix shape against the VMEM budget), a warm
phase runs as ONE multi-iteration Pallas launch with X ping-ponging in
VMEM, and a fit iteration as TWO launches: fused residual+sketch-chain,
then the fused d-GEMM Horner application — the closed-form alpha
minimization runs between them in XLA, which is exactly why the fit
phase cannot fuse across iterations (alpha_{k+1} needs the traces of
R_{k+1}).

Adaptive early stopping (DESIGN.md §11): with ``cfg.tol`` set, each
maximal run of fitted iterations becomes one ``lax.while_loop`` whose
body is a single fitted iteration plus a per-matrix convergence mask —
the certificate est_r ~ ||R_k||_F is read off the trace chain the fit
already computes (prism.fit_alpha_from_traces), converged [B, n, n]
slices freeze bit-stably, and the loop exits when the slowest slice
certifies.  ``iterations`` is then a budget; the realized per-matrix
counts surface through ``return_iters``.  tol=None (default) keeps the
fully-unrolled static chains (and is required for reverse-mode autodiff
through the iteration, which lax.while_loop does not support).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import PrismConfig
from repro.core import polynomials as poly
from repro.core import prism
from repro.core import sketch as sk


class IterInfo(NamedTuple):
    alphas: jax.Array          # [iters, ...]
    residual_fro: jax.Array    # [iters, ...] ||R_k||_F before each update


def _eye_like(M: jax.Array) -> jax.Array:
    n = M.shape[-1]
    return jnp.eye(n, dtype=M.dtype)


def _fro(M: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(jnp.square(M.astype(jnp.float32)),
                            axis=(-2, -1), keepdims=True))


def _safe_fro(M: jax.Array) -> jax.Array:
    """||M||_F clamped away from zero for the entry-point normalizations.

    A zero slice (rank-collapsed momentum, freshly-padded bucket slot)
    would otherwise normalize as 0/0 = NaN before the first iteration
    ever runs — the one poisoning the §15 guardian cannot contain,
    because it happens upstream of the certificate.  Clamping to the
    smallest normal fp32 leaves every slice with ||M||_F >= tiny
    bit-identical and turns zero slices into exact zero pass-throughs
    (0 / tiny = 0), which the chains then fix at X = 0.
    """
    return jnp.maximum(_fro(M), jnp.float32(np.finfo(np.float32).tiny))


def _mm(A, B, use_kernels=False, alpha=1.0, C=None, beta=0.0):
    """alpha * A @ B (+ beta * C), optionally through the Pallas kernel.

    The jnp path mirrors the kernels' accumulation semantics exactly
    (DESIGN.md §9): the dot accumulates fp32 regardless of the operand
    dtype, the epilogue runs on the fp32 accumulator, and only the final
    result rounds back to the compute dtype — bit-matching ref.matmul_add.
    """
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.matmul_add(A, B, C=C, alpha=alpha, beta=beta)
    out = jnp.matmul(A, B, preferred_element_type=jnp.float32)
    if alpha != 1.0:
        out = alpha * out
    if C is not None:
        out = out + beta * C.astype(jnp.float32)
    return out.astype(A.dtype)


def _gram_residual(X: jax.Array, use_kernels: bool) -> jax.Array:
    """R = I - X^T X (symmetric; Pallas syrk kernel when enabled).

    jnp path: fp32-accumulated Gram + fp32 epilogue, rounded once to the
    compute dtype (matches ref.gram / the kernel, DESIGN.md §9).
    """
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.gram(X, alpha=1.0, beta=-1.0)
    Xt = jnp.swapaxes(X, -1, -2)
    G = jnp.matmul(Xt, X, preferred_element_type=jnp.float32)
    eye = jnp.eye(X.shape[-1], dtype=jnp.float32)
    return (eye - G).astype(X.dtype)


def apply_g(X: jax.Array, R: jax.Array, alpha, d: int,
            side: str = "right", use_kernels: bool = False) -> jax.Array:
    """X @ g_d(R; alpha)  (side='right')  or  g_d(R; alpha) @ X  (side='left').

    g_d(x; a) = f_{d-1}(x) + a x^d with f the Taylor series of (1-x)^{-1/2}.
    Evaluated as a chain of d GEMMs (Horner on R), never forming g(R).

    alpha is applied IN fp32 (DESIGN.md §9): the PRISM fit is pinned
    fp32, so under a bf16 compute policy the fitted alpha multiplies the
    fp32-upcast X and the product rounds ONCE to the compute dtype —
    never pre-rounding alpha itself to bf16 (which would throw away the
    fit's precision before it reaches the update).  The fused kernel
    tier (kernels/fused_iter.apply_g) and ref.apply_g keep the same
    contract inside the fp32 Horner accumulator.
    """
    f = poly.taylor_inv_sqrt(d - 1)  # ascending, length d
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim:
        alpha = alpha[..., None, None]
    acc = (alpha * X.astype(jnp.float32)).astype(X.dtype)
    if side == "right":
        # X (f0 I + f1 R + ... + a R^d) = f0 X + (f1 X + (... + a X R) R) R
        for j in range(d - 1, 0, -1):
            acc = _mm(acc, R, use_kernels, C=X, beta=float(f[j]))
        return _mm(acc, R, use_kernels, C=X, beta=float(f[0]))
    else:
        for j in range(d - 1, 0, -1):
            acc = _mm(R, acc, use_kernels, C=X, beta=float(f[j]))
        return _mm(R, acc, use_kernels, C=X, beta=float(f[0]))


def _classical_alpha(d: int) -> float:
    return float(poly.taylor_inv_sqrt(d)[d])


def _resolve_alpha(k: int, R: jax.Array, cfg: PrismConfig, method: str,
                   key: Optional[jax.Array],
                   n_real: Optional[jax.Array] = None):
    """Static-k alpha resolution: classical coefficient or the shared
    warm/PRISM-fit implementation in prism.resolve_alpha."""
    if method == "newton_schulz":
        return jnp.full(R.shape[:-2], _classical_alpha(cfg.degree),
                        dtype=jnp.float32)
    assert method == "prism"
    return prism.resolve_alpha(k, R, poly.newton_schulz_residual(cfg.degree),
                               cfg, key, n_real=n_real)


# ---------------------------------------------------------------------------
# Phase plan + fused-tier routing (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _static_alpha(k: int, cfg: PrismConfig, method: str) -> Optional[float]:
    """alpha_k when it is a compile-time constant, else None (fit)."""
    if method == "newton_schulz":
        return _classical_alpha(cfg.degree)
    # fail fast on unknown methods on BOTH tiers (the unfused path's
    # _resolve_alpha asserts the same; the fused fit path skips it)
    assert method == "prism", method
    if k < cfg.warm_alpha_iters:
        return float(cfg.bounds[1])
    return None


def _phase_plan(iters: int, cfg: PrismConfig,
                method: str) -> List[Tuple[str, object]]:
    """[("warm", (a_0, ..)), ("fit", (k0, count)), ...] — maximal runs
    of static-alpha iterations become single warm phases (one fused
    launch, §10) and maximal runs of consecutive FITTED iterations
    become single fit phases.  A fit phase unrolls statically
    (``cfg.tol is None``: count data-independent iterations, the
    pre-§11 behavior) or runs as ONE ``lax.while_loop`` with per-matrix
    convergence masks (``cfg.tol`` set): ``count`` is then the budget,
    not the cost."""
    phases: List[Tuple[str, object]] = []
    for k in range(iters):
        a = _static_alpha(k, cfg, method)
        if a is None:
            if phases and phases[-1][0] == "fit":
                k0, count = phases[-1][1]
                phases[-1] = ("fit", (k0, count + 1))
            else:
                phases.append(("fit", (k, 1)))
        else:
            if phases and phases[-1][0] == "warm":
                phases[-1] = ("warm", phases[-1][1] + (a,))
            else:
                phases.append(("warm", (a,)))
    return phases


def _fused_tier(cfg: PrismConfig, mshape, return_info: bool,
                coupled: bool = False) -> bool:
    """Trace-time fused-tier choice: kernels on, not a diagnostics run
    (return_info needs per-iteration residuals the fused launches never
    materialize), and the per-slice working set fits the VMEM budget."""
    if not cfg.use_kernels or return_info or cfg.fuse == "off":
        return False
    if cfg.fuse == "on":
        return True
    from repro.kernels import ops as kops

    return kops.fused_fits(mshape, jnp.dtype(cfg.dtype), coupled=coupled,
                           budget=cfg.vmem_budget)


def _fused_fit_step(X, cfg: PrismConfig, k: int, key, n_real,
                    family: str, Y=None):
    """One fitted iteration in TWO launches: fused residual+sketch-chain,
    the XLA closed-form alpha fit, then the fused Horner application."""
    from repro.kernels import ops as kops

    apoly = poly.newton_schulz_residual(cfg.degree)
    lo, hi = cfg.bounds
    n = X.shape[-1]
    S = sk.gaussian_sketch(prism.alpha_schedule_key(key, k), cfg.sketch_dim,
                           n, dtype=X.dtype)
    R, t = kops.residual_chain(X, S, poly.max_trace_power(apoly),
                               family=family, Y=Y)
    a = prism.fit_alpha_from_traces(t, apoly, lo, hi, S=S, n_real=n_real)
    return kops.apply_g(X, R, a, degree=cfg.degree, Y=Y)


def _adaptive_fit_run(X, Y, cfg: PrismConfig, k0: int, count: int, key,
                      n_real, family: str, residual_fn, fused: bool):
    """A maximal run of fitted iterations as ONE ``lax.while_loop`` with
    per-matrix convergence masks (DESIGN.md §11).

    Every loop step reads the certificate est_r ~ ||R_k||_F off the same
    sketched trace chain the alpha fit consumes (zero extra launches) and
    freezes any batch slice with est_r <= cfg.tol: frozen slices pass
    through a masked identity update (``jnp.where`` on the untouched
    iterate — bitwise-stable) while stragglers keep iterating.  The loop
    exits when the SLOWEST slice certifies or the ``count`` budget runs
    out.  The same certificate drives the §15 divergence detector
    (``cfg.divergence_factor``): a slice whose est_r goes non-finite or
    blows past its best-so-far is QUARANTINED — rolled back to the
    best-certified iterate and withdrawn.  Returns (X, Y, used, status)
    with ``used`` the per-slice number of updates actually applied
    (shape ``X.shape[:-2]``, int32) and ``status`` the per-slice int8
    guardian code (prism.STATUS_*).

    The §10 launch contracts survive unchanged: the loop body is the
    body of one fitted iteration — 2 launches on the fused tier, 2+d on
    the §7 tier — issued per RUNTIME iteration, while a single trace of
    the while_loop (what ``ops.count_launches`` counts) contains the
    body once, independent of the budget and of the data.
    """
    coupled = Y is not None
    apoly = poly.newton_schulz_residual(cfg.degree)
    lo, hi = cfg.bounds
    n = X.shape[-1]
    use_fused_fit = fused and key is not None and cfg.sketch_dim > 0
    if fused:
        from repro.kernels import ops as kops

    def fit(it, k):
        """(R, alpha, est_r) for iteration k (k is traced)."""
        X_, Y_ = it["X"], it.get("Y")
        if use_fused_fit:
            S = sk.gaussian_sketch(prism.alpha_schedule_key(key, k),
                                   cfg.sketch_dim, n, dtype=X_.dtype)
            R, t = kops.residual_chain(X_, S, poly.max_trace_power(apoly),
                                       family=family, Y=Y_)
            a, est = prism.fit_alpha_from_traces(t, apoly, lo, hi, S=S,
                                                 n_real=n_real,
                                                 return_est_r=True)
            return R, a, est
        R = residual_fn(X_, Y_)
        kk = prism.alpha_schedule_key(key, k) if key is not None else None
        a, est = prism.fit_alpha(R, apoly, lo, hi, key=kk,
                                 sketch_dim=cfg.sketch_dim,
                                 use_kernels=cfg.use_kernels,
                                 n_real=n_real,
                                 vmem_budget=cfg.vmem_budget,
                                 return_est_r=True)
        return R, a, est

    def step(it, R, a):
        X_, Y_ = it["X"], it.get("Y")
        if fused:
            out = kops.apply_g(X_, R, a, degree=cfg.degree, Y=Y_)
            Xn, Yn = out if coupled else (out, None)
        else:
            Xn = apply_g(X_, R, a, cfg.degree, "right", cfg.use_kernels)
            Yn = apply_g(Y_, R, a, cfg.degree, "left",
                         cfg.use_kernels) if coupled else None
        return {"X": Xn, "Y": Yn} if coupled else {"X": Xn}

    iterates = {"X": X, "Y": Y} if coupled else {"X": X}
    out, used, status = prism.adaptive_masked_loop(
        iterates, fit, step, cfg.tol, k0, count, X.shape[:-2],
        divergence_factor=cfg.divergence_factor)
    return out["X"], out.get("Y", Y), used, status


def _run_phases(X, cfg: PrismConfig, method: str, iters: int, key,
                return_info: bool, family: str, residual_fn,
                Y=None, n_real=None):
    """Shared warm/fit phase driver for the three NS families (§10/§11).

    ``residual_fn(X, Y)`` computes the family residual on the unfused
    path; ``Y`` is non-None only for the coupled sqrt family (both
    iterates then update per phase).  Returns (X, Y, alphas, fros,
    iters_used): the info lists are populated only under ``return_info``
    (which disables the fused tier — see _fused_tier — and the adaptive
    engine, whose per-iteration quantities a dynamic loop cannot stack);
    ``iters_used`` is the per-matrix count of applied updates, shape
    ``X.shape[:-2]`` — the static total unless ``cfg.tol`` turns the fit
    phases adaptive (§11).  ``status`` is the per-matrix int8 guardian
    code (prism.STATUS_*), the severity-maximum across all adaptive fit
    runs (all-zeros on static chains, which carry no certificate).
    """
    coupled = Y is not None
    fused = _fused_tier(cfg, X.shape[-2:], return_info, coupled=coupled)
    if fused:
        from repro.kernels import ops as kops
    alphas, fros = [], []
    iters_used = jnp.zeros(X.shape[:-2], jnp.int32)
    status = jnp.zeros(X.shape[:-2], jnp.int8)
    adaptive = cfg.tol is not None and not return_info

    def unpack(out):
        return out if coupled else (out, Y)

    for kind, payload in _phase_plan(iters, cfg, method):
        if kind == "warm":
            iters_used = iters_used + len(payload)
            if fused:
                X, Y = unpack(kops.warm_tail(X, payload, degree=cfg.degree,
                                             family=family, Y=Y))
                continue
            for a in payload:
                R = residual_fn(X, Y)
                aa = jnp.full(R.shape[:-2], a, dtype=jnp.float32)
                X = apply_g(X, R, aa, cfg.degree, "right", cfg.use_kernels)
                if coupled:
                    Y = apply_g(Y, R, aa, cfg.degree, "left",
                                cfg.use_kernels)
                if return_info:
                    alphas.append(aa)
                    fros.append(_fro(R)[..., 0, 0])
            continue
        k0, count = payload
        if adaptive:
            X, Y, used, st = _adaptive_fit_run(X, Y, cfg, k0, count, key,
                                               n_real, family, residual_fn,
                                               fused)
            iters_used = iters_used + used
            status = jnp.maximum(status, st)
            continue
        for k in range(k0, k0 + count):
            iters_used = iters_used + 1
            if fused and key is not None and cfg.sketch_dim > 0:
                X, Y = unpack(_fused_fit_step(X, cfg, k, key, n_real,
                                              family, Y=Y))
                continue
            R = residual_fn(X, Y)
            a = _resolve_alpha(k, R, cfg, method, key, n_real=n_real)
            if fused:
                X, Y = unpack(kops.apply_g(X, R, a, degree=cfg.degree,
                                           Y=Y))
            else:
                X = apply_g(X, R, a, cfg.degree, "right", cfg.use_kernels)
                if coupled:
                    Y = apply_g(Y, R, a, cfg.degree, "left",
                                cfg.use_kernels)
            if return_info:
                alphas.append(a)
                fros.append(_fro(R)[..., 0, 0])
    return X, Y, alphas, fros, iters_used, status


# ---------------------------------------------------------------------------
# Polar factor (orthogonalization) — the Muon primitive
# ---------------------------------------------------------------------------


def _with_telemetry(out, info, iters_used, return_info, return_iters,
                    status=None, return_status=False):
    """(out[, IterInfo][, iters_used][, status]) per the telemetry
    flags — ``status`` is the per-matrix int8 guardian code
    (prism.STATUS_*), appended last so existing unpackers are
    untouched."""
    res = (out,)
    if return_info:
        alphas, fros = info
        res = res + (IterInfo(jnp.stack(alphas), jnp.stack(fros)),)
    if return_iters:
        res = res + (iters_used,)
    if return_status:
        res = res + (status,)
    return res if len(res) > 1 else res[0]


def polar(A: jax.Array, cfg: Optional[PrismConfig] = None,
          method: str = "prism", iters: Optional[int] = None,
          key: Optional[jax.Array] = None, return_info: bool = False,
          n_real: Optional[jax.Array] = None, return_iters: bool = False,
          return_status: bool = False):
    """Polar factor U V^T of A [..., m, n] via (PRISM-)Newton-Schulz.

    method: "prism" | "newton_schulz" (classical Taylor alpha).
    n_real: per-matrix real extent of the Gram dimension (= min(m, n) side)
      when A is a zero-padded pad-to-bucket stack; makes the sketched alpha
      fit exactly ignore the padding (see prism.fit_alpha).  Zero-padding
      itself is exact for the iterations: pad rows/cols of X stay zero and
      the real block evolves as if unpadded.
    return_iters: also return ``iters_used`` — the per-matrix number of
      iterations actually applied, shape ``A.shape[:-2]`` (int32).  Equals
      ``iters`` unless ``cfg.tol`` enables adaptive early stopping
      (DESIGN.md §11), where converged slices freeze early.
    return_status: also return the per-matrix int8 guardian status
      (prism.STATUS_*, DESIGN.md §15) — appended after ``iters_used``.
      All-zeros unless ``cfg.tol`` runs the adaptive certificate.
    """
    cfg = PrismConfig() if cfg is None else cfg
    iters = cfg.iterations if iters is None else iters
    transpose = A.shape[-2] < A.shape[-1]
    X = jnp.swapaxes(A, -1, -2) if transpose else A
    in_dtype = X.dtype
    X = X.astype(cfg.dtype) / _safe_fro(X).astype(cfg.dtype)
    X, _, alphas, fros, used, status = _run_phases(
        X, cfg, method, iters, key, return_info, "polar",
        lambda x, y: _gram_residual(x, cfg.use_kernels), n_real=n_real)
    X = jnp.swapaxes(X, -1, -2) if transpose else X
    X = X.astype(in_dtype)
    return _with_telemetry(X, (alphas, fros), used, return_info,
                           return_iters, status, return_status)


# ---------------------------------------------------------------------------
# Coupled square root / inverse square root (Higham Thm 3)
# ---------------------------------------------------------------------------


def _coupled_residual(X, Y, use_kernels: bool):
    # R = I - Y X (Thm 3 coupling: X <- X h(YX), Y <- h(YX) Y).  This is
    # Higham's numerically *stable* coupled form; the R = I - X Y variant
    # written in the paper's Table-1 "Residual" column is the classically
    # unstable coupling and diverges right after convergence (verified
    # empirically in fp64 — see tests/test_matfn.py::test_sqrt_stability).
    R = _eye_like(X) - _mm(Y, X, use_kernels)
    return 0.5 * (R + jnp.swapaxes(R, -1, -2))  # stability: re-symmetrize


def sqrtm(A: jax.Array, cfg: Optional[PrismConfig] = None,
          method: str = "prism", iters: Optional[int] = None,
          key: Optional[jax.Array] = None, return_info: bool = False,
          return_iters: bool = False, return_status: bool = False):
    """(A^{1/2}, A^{-1/2}) for symmetric PSD A via coupled (PRISM-)NS.

    Normalizes by ||A||_F (so spectrum in (0, 1]) and rescales the outputs.
    ``return_iters`` appends the per-matrix ``iters_used`` telemetry (see
    ``polar``); with ``cfg.tol`` set, BOTH coupled iterates freeze
    together once the slice's certificate est_r ~ ||I - Y X||_F clears
    tol (DESIGN.md §11).  ``return_status`` appends the per-matrix int8
    guardian status (prism.STATUS_*, DESIGN.md §15).
    """
    cfg = PrismConfig() if cfg is None else cfg
    iters = cfg.iterations if iters is None else iters
    in_dtype = A.dtype
    c = _safe_fro(A).astype(cfg.dtype)
    X = A.astype(cfg.dtype) / c
    Y = jnp.broadcast_to(_eye_like(X), X.shape)
    X, Y, alphas, fros, used, status = _run_phases(
        X, cfg, method, iters, key, return_info, "sqrt",
        lambda x, y: _coupled_residual(x, y, cfg.use_kernels), Y=Y)
    sqrt_c = jnp.sqrt(c)
    out = (X * sqrt_c).astype(in_dtype), (Y / sqrt_c).astype(in_dtype)
    return _with_telemetry(out, (alphas, fros), used, return_info,
                           return_iters, status, return_status)


# ---------------------------------------------------------------------------
# Matrix sign
# ---------------------------------------------------------------------------


def signm(A: jax.Array, cfg: Optional[PrismConfig] = None,
          method: str = "prism", iters: Optional[int] = None,
          key: Optional[jax.Array] = None, return_info: bool = False,
          return_iters: bool = False, return_status: bool = False):
    """sign(A) for A with A^2 symmetric and ||A||_2 <= 1 after ||.||_F
    scaling.  ``return_iters`` appends per-matrix ``iters_used`` (see
    ``polar``); ``return_status`` the int8 guardian status (§15)."""
    cfg = PrismConfig() if cfg is None else cfg
    iters = cfg.iterations if iters is None else iters
    in_dtype = A.dtype
    X = A.astype(cfg.dtype) / _safe_fro(A).astype(cfg.dtype)
    X, _, alphas, fros, used, status = _run_phases(
        X, cfg, method, iters, key, return_info, "sign",
        lambda x, y: _eye_like(x) - _mm(x, x, cfg.use_kernels))
    X = X.astype(in_dtype)
    return _with_telemetry(X, (alphas, fros), used, return_info,
                           return_iters, status, return_status)
