"""Coupled inverse-Newton iteration for A^{-1/p} (paper App. A.3).

  R_k = I - M_k
  X_{k+1} = X_k (I + a_k R_k),         X_0 = I / c
  M_{k+1} = (I + a_k R_k)^p M_k,       M_0 = A / c^p
  c = (2 ||A||_F / (p+1))^{1/p}

PRISM picks a_k by minimizing the sketched Frobenius norm of the next
residual, a degree-2p polynomial in alpha whose coefficients come from the
generic trace machinery (core/polynomials.inverse_newton_residual).  For
p <= 2 the minimization is closed-form; p >= 3 uses the grid+Newton path.
Classical inverse Newton is a_k = 1/p; the default constraint interval
[1/p, 2/p] contains it, so the PRISM step is never worse in ||.||_F.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import PrismConfig
from repro.core import polynomials as poly
from repro.core import prism
from repro.core.newton_schulz import IterInfo, _fro, _mm


def inv_proot(A: jax.Array, p: int, iters: int = 20, method: str = "prism",
              sketch_dim: int = 8, key: Optional[jax.Array] = None,
              dtype=jnp.float32, alpha_bounds: Optional[Tuple[float, float]] = None,
              return_info: bool = False, tol: Optional[float] = None,
              return_iters: bool = False, return_status: bool = False,
              divergence_factor: float = 10.0):
    """A^{-1/p} for SPD A via (PRISM-)coupled inverse Newton.

    tol: adaptive early-stopping certificate (DESIGN.md §11): with
      ``method="prism"`` the chain runs as one ``lax.while_loop`` that
      freezes BOTH coupled iterates of a batch slice (bit-stably) once
      its sketched est_r ~ ||I - M_k||_F drops to tol; ``iters`` becomes
      a budget.  Classical inverse Newton (and ``return_info``) ignores
      tol and runs the fixed count — it computes no sketched traces to
      certify from.
    return_iters: also return per-matrix ``iters_used`` (int32).
    return_status: also return the per-matrix int8 guardian status
      (prism.STATUS_*, DESIGN.md §15); ``divergence_factor`` is the
      adaptive loop's detector threshold.  All-zeros on non-adaptive
      paths.
    """
    in_dtype = A.dtype
    n = A.shape[-1]
    A32 = A.astype(dtype)
    # zero-slice guard (§15): for an all-zero slice the scale underflows
    # (XLA CPU flushes the subnormal to 0) and X_0 = I/c would start the
    # chain at inf, upstream of any certificate.  c = 1 instead keeps
    # the iterates bounded; the slice then exits as MAXITER, never OK.
    c_raw = (2.0 * _fro(A32).astype(dtype) / (p + 1)) ** (1.0 / p)
    c = jnp.where(jnp.isfinite(c_raw) & (c_raw > 0), c_raw,
                  jnp.ones_like(c_raw))
    X = jnp.broadcast_to(jnp.eye(n, dtype=dtype), A32.shape) / c
    M = A32 / c ** p
    lo, hi = alpha_bounds if alpha_bounds is not None else (1.0 / p, 2.0 / p)
    apoly = poly.inverse_newton_residual(p)
    eye = jnp.eye(n, dtype=dtype)
    batch = A.shape[:-2]
    adaptive = tol is not None and method == "prism" and not return_info

    def fit(R, k):
        kk = prism.alpha_schedule_key(key, k) if key is not None else None
        return prism.fit_alpha(R, apoly, lo, hi, key=kk,
                               sketch_dim=sketch_dim, return_est_r=True)

    def step(X_, M_, a):
        ab = a.astype(dtype)[..., None, None]
        T = eye + ab * (eye - M_)
        # fp32-accumulated chain products (DESIGN.md §9)
        Xn = _mm(X_, T)
        Mn = M_
        for _ in range(p):
            Mn = _mm(T, Mn)
        return Xn, Mn

    if adaptive:
        def afit(it, k):
            a, est = fit(eye - it["M"], k)
            return None, a, est

        def astep(it, _aux, a):
            Xn, Mn = step(it["X"], it["M"], a)
            return {"X": Xn, "M": Mn}

        out_it, used, status = prism.adaptive_masked_loop(
            {"X": X, "M": M}, afit, astep, tol, 0, iters, batch,
            divergence_factor=divergence_factor)
        X = out_it["X"]
    else:
        alphas, fros = [], []
        for k in range(iters):
            R = eye - M
            if method == "prism":
                a, _ = fit(R, k)
            else:
                a = jnp.full(batch, 1.0 / p, dtype=jnp.float32)
            if return_info:
                alphas.append(a)
                fros.append(_fro(R)[..., 0, 0])
            X, M = step(X, M, a)
        used = jnp.full(batch, iters, jnp.int32)
        status = jnp.zeros(batch, jnp.int8)
    # M_k = X_k^p A is invariant, so M_k -> I gives X_k -> A^{-1/p} directly;
    # the initial 1/c scaling needs no undoing.
    out = X.astype(in_dtype)
    res = (out,)
    if return_info:
        res = res + (IterInfo(jnp.stack(alphas), jnp.stack(fros)),)
    if return_iters:
        res = res + (used,)
    if return_status:
        res = res + (status,)
    return res if len(res) > 1 else res[0]
