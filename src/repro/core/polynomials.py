"""Polynomial algebra underlying the PRISM meta-algorithm.

PRISM Part II replaces the degree-d Taylor update ``f_d`` with
``g_d(xi; alpha) = f_{d-1}(xi) + alpha xi^d`` and picks ``alpha`` by
minimizing the (sketched) Frobenius norm of the *next* residual.  For every
algorithm in the paper's Table 1 that next residual is a polynomial in the
current residual matrix R whose coefficients are polynomials in alpha:

    h(x; alpha) = P_0(x) + alpha P_1(x) + ... + alpha^s P_s(x)

and the objective

    m(alpha) = || S h(R; alpha) ||_F^2 = tr( S h(R; alpha)^2 S^T )

is a degree-2s polynomial in alpha whose coefficients are *fixed* linear
combinations of the sketched power traces t_i = tr(S R^i S^T).  This module
computes those fixed linear maps symbolically (in numpy, at trace time) and
provides jittable constrained minimizers for m.

The hand-derived c_1..c_4 formulas in the paper's Sec. 4.2 / App. A are
reproduced exactly by this machinery (see tests/test_polynomials.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Scalar Taylor series of the PRISM target functions
# ---------------------------------------------------------------------------


def taylor_inv_sqrt(d: int) -> np.ndarray:
    """Coefficients (ascending) of the degree-d Taylor poly of (1-x)^{-1/2}.

    c_j = (2j-1)!! / (2j)!! = prod_{i<=j} (2i-1)/(2i);  c_0 = 1.
    """
    c = np.ones(d + 1, dtype=np.float64)
    for j in range(1, d + 1):
        c[j] = c[j - 1] * (2 * j - 1) / (2 * j)
    return c


def poly_mul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.convolve(a, b)


def poly_add(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    n = max(len(a), len(b))
    out = np.zeros(n, dtype=np.float64)
    out[: len(a)] += a
    out[: len(b)] += b
    return out


def poly_scale(a: np.ndarray, s: float) -> np.ndarray:
    return np.asarray(a, dtype=np.float64) * s


def monomial(k: int) -> np.ndarray:
    m = np.zeros(k + 1, dtype=np.float64)
    m[k] = 1.0
    return m


# ---------------------------------------------------------------------------
# Residual polynomials  h(x; alpha) = sum_j alpha^j P_j(x)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AlphaPoly:
    """h(x; alpha) = sum_j alpha^j polys[j](x); coefficient vectors ascending."""

    polys: Tuple[Tuple[float, ...], ...]

    @staticmethod
    def make(polys: Sequence[np.ndarray]) -> "AlphaPoly":
        return AlphaPoly(tuple(tuple(float(v) for v in p) for p in polys))

    @property
    def alpha_degree(self) -> int:
        return len(self.polys) - 1

    @property
    def x_degree(self) -> int:
        return max(len(p) for p in self.polys) - 1

    def np_polys(self) -> Tuple[np.ndarray, ...]:
        return tuple(np.asarray(p, dtype=np.float64) for p in self.polys)

    def eval(self, x, alpha):
        """Scalar/elementwise evaluation (used by oracles and tests)."""
        x = jnp.asarray(x)
        out = 0.0
        for j, p in enumerate(self.np_polys()):
            px = jnp.polyval(jnp.asarray(p[::-1].copy()), x)
            out = out + (alpha ** j) * px
        return out


@functools.lru_cache(maxsize=None)
def newton_schulz_residual(d: int) -> AlphaPoly:
    """Residual poly of PRISM Newton-Schulz (sign / sqrt / polar).

    h(x, alpha) = 1 - (1 - x) * g_d(x; alpha)^2 with
    g_d(x; alpha) = f_{d-1}(x) + alpha x^d.
    Expanding in alpha:
      P_0 = 1 - (1-x) f_{d-1}^2
      P_1 = -2 (1-x) x^d f_{d-1}
      P_2 = -(1-x) x^{2d}
    """
    f = taylor_inv_sqrt(d - 1)
    one_minus_x = np.array([1.0, -1.0])
    p0 = poly_add(np.array([1.0]), poly_scale(poly_mul(one_minus_x, poly_mul(f, f)), -1.0))
    p1 = poly_scale(poly_mul(one_minus_x, poly_mul(monomial(d), f)), -2.0)
    p2 = poly_scale(poly_mul(one_minus_x, monomial(2 * d)), -1.0)
    return AlphaPoly.make([p0, p1, p2])


@functools.lru_cache(maxsize=None)
def inverse_newton_residual(p: int) -> AlphaPoly:
    """Residual poly of PRISM coupled inverse Newton for A^{-1/p} (App. A.3).

    h(x; alpha) = x + sum_{i=1}^{p} binom(p, i) alpha^i (x^{i+1} - x^i).
    """
    from math import comb

    polys = [monomial(1)]
    for i in range(1, p + 1):
        polys.append(poly_scale(poly_add(monomial(i + 1), poly_scale(monomial(i), -1.0)), comb(p, i)))
    return AlphaPoly.make(polys)


@functools.lru_cache(maxsize=None)
def chebyshev_residual() -> AlphaPoly:
    """Residual poly of PRISM Chebyshev inverse iteration (App. A.4).

    h(x; alpha) = x^2 - alpha (x^2 - x^3).
    """
    p0 = monomial(2)
    p1 = poly_add(monomial(3), poly_scale(monomial(2), -1.0))
    return AlphaPoly.make([p0, p1])


# ---------------------------------------------------------------------------
# m(alpha) coefficients as a fixed linear map of power traces
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def trace_weight_matrix(apoly: AlphaPoly) -> np.ndarray:
    """W such that  m_coeffs[k] = sum_i W[k, i] * t_i,  t_i = tr(S R^i S^T).

    m(alpha) = tr(S h(R;alpha)^2 S^T)
             = sum_{p,q} alpha^{p+q} tr(S P_p(R) P_q(R) S^T)
    and P_p(R) P_q(R) expands over powers of R via coefficient convolution.
    Shape: [2s+1, 2*x_degree+1].
    """
    polys = apoly.np_polys()
    s = apoly.alpha_degree
    max_pow = 2 * apoly.x_degree
    W = np.zeros((2 * s + 1, max_pow + 1), dtype=np.float64)
    for p in range(s + 1):
        for q in range(s + 1):
            conv = poly_mul(polys[p], polys[q])
            W[p + q, : len(conv)] += conv
    return W


def max_trace_power(apoly: AlphaPoly) -> int:
    return 2 * apoly.x_degree


# ---------------------------------------------------------------------------
# Jittable constrained polynomial minimization on [l, u]
# ---------------------------------------------------------------------------


def _polyval_asc(coeffs, x):
    """Evaluate sum_k coeffs[..., k] x^k with broadcasting over leading dims."""
    out = jnp.zeros_like(x)
    for k in range(coeffs.shape[-1] - 1, -1, -1):
        out = out * x + coeffs[..., k]
    return out


def _cbrt(x):
    return jnp.sign(x) * jnp.abs(x) ** (1.0 / 3.0)


def cubic_roots(a, b, c, d):
    """Real roots of a x^3 + b x^2 + c x + d = 0, branchless.

    Returns three candidates (may repeat / fall back to NaN-free copies of the
    single real root when the other two are complex).  Degenerate leading
    coefficients are handled by the caller via extra quadratic candidates.
    """
    eps = 1e-30
    a = jnp.where(jnp.abs(a) < eps, eps, a)
    # depressed cubic t^3 + p t + q,  x = t - b/(3a)
    p = (3 * a * c - b * b) / (3 * a * a)
    q = (2 * b ** 3 - 9 * a * b * c + 27 * a * a * d) / (27 * a ** 3)
    shift = -b / (3 * a)
    disc = (q / 2) ** 2 + (p / 3) ** 3
    # --- one real root (disc > 0): Cardano
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    r_single = _cbrt(-q / 2 + sq) + _cbrt(-q / 2 - sq) + shift
    # --- three real roots (disc <= 0): trigonometric method
    pm = jnp.minimum(p, -eps)  # p < 0 in this branch
    m = 2 * jnp.sqrt(-pm / 3)
    den = pm * m  # can underflow to -0.0 in fp32 (triple root at 0)
    den = jnp.where(jnp.abs(den) < 1e-20, -1e-20, den)
    arg = jnp.clip(3 * q / den, -1.0, 1.0)
    theta = jnp.arccos(arg) / 3
    two_pi_3 = 2 * jnp.pi / 3
    r0 = m * jnp.cos(theta) + shift
    r1 = m * jnp.cos(theta - two_pi_3) + shift
    r2 = m * jnp.cos(theta - 2 * two_pi_3) + shift
    single = disc > 0
    return (
        jnp.where(single, r_single, r0),
        jnp.where(single, r_single, r1),
        jnp.where(single, r_single, r2),
    )


def minimize_quartic(coeffs, lo: float, hi: float):
    """argmin over [lo, hi] of quartic m(a) = sum_k coeffs[..., k] a^k.

    Closed form: stationary points from the cubic m'(a) = 0 (Cardano +
    trigonometric branch), plus quadratic/linear candidates for degenerate
    leading coefficients, plus the interval endpoints.  Fully branchless and
    batched over leading dims of ``coeffs``.
    """
    c1 = coeffs[..., 1]
    c2 = coeffs[..., 2]
    c3 = coeffs[..., 3] if coeffs.shape[-1] > 3 else jnp.zeros_like(c1)
    c4 = coeffs[..., 4] if coeffs.shape[-1] > 4 else jnp.zeros_like(c1)
    # m'(a) = c1 + 2 c2 a + 3 c3 a^2 + 4 c4 a^3
    r0, r1, r2 = cubic_roots(4 * c4, 3 * c3, 2 * c2, c1)
    # quadratic fallback (c4 ~ 0): 3 c3 a^2 + 2 c2 a + c1 = 0
    qa, qb, qc = 3 * c3, 2 * c2, c1
    qdisc = jnp.maximum(qb * qb - 4 * qa * qc, 0.0)
    qden = jnp.where(jnp.abs(qa) < 1e-30, 1e-30, 2 * qa)
    q0 = (-qb + jnp.sqrt(qdisc)) / qden
    q1 = (-qb - jnp.sqrt(qdisc)) / qden
    # linear fallback (c3 ~ c4 ~ 0)
    lden = jnp.where(jnp.abs(qb) < 1e-30, 1e-30, qb)
    lin = -qc / lden
    lo_a = jnp.full_like(c1, lo)
    hi_a = jnp.full_like(c1, hi)
    cands = jnp.stack([lo_a, hi_a, r0, r1, r2, q0, q1, lin], axis=-1)
    cands = jnp.clip(cands, lo, hi)
    cands = jnp.where(jnp.isfinite(cands), cands, lo)
    vals = _polyval_asc(coeffs[..., None, :], cands)
    best = jnp.argmin(vals, axis=-1)
    return jnp.take_along_axis(cands, best[..., None], axis=-1)[..., 0]


def minimize_poly_grid(coeffs, lo: float, hi: float, num: int = 257,
                       newton_iters: int = 2):
    """Generic argmin of an arbitrary-degree poly on [lo, hi].

    Dense grid scan + a few Newton refinements on m'.  Used for
    inverse-Newton with p >= 3 (degree-2p objective) and as a property-test
    oracle for the closed-form quartic path.
    """
    grid = jnp.linspace(lo, hi, num)
    vals = _polyval_asc(coeffs[..., None, :], grid)
    best = jnp.argmin(vals, axis=-1)
    a = grid[best]
    K = coeffs.shape[-1]
    # derivative coefficients (ascending): dm[k] = (k+1) coeffs[k+1]
    dm = coeffs[..., 1:] * jnp.arange(1, K, dtype=coeffs.dtype)
    ddm = dm[..., 1:] * jnp.arange(1, K - 1, dtype=coeffs.dtype) if K > 2 else None
    for _ in range(newton_iters):
        if ddm is None:
            break
        g = _polyval_asc(jnp.broadcast_to(dm, a.shape + (dm.shape[-1],)), a)
        h = _polyval_asc(jnp.broadcast_to(ddm, a.shape + (ddm.shape[-1],)), a)
        step = jnp.where(h > 0, g / jnp.where(jnp.abs(h) < 1e-30, 1e-30, h), 0.0)
        a = jnp.clip(a - step, lo, hi)
    return a


def minimize_alpha_poly(coeffs, lo: float, hi: float):
    """Dispatch: closed form for degree <= 4, grid otherwise."""
    if coeffs.shape[-1] <= 5:
        pad = 5 - coeffs.shape[-1]
        if pad:
            coeffs = jnp.concatenate(
                [coeffs, jnp.zeros(coeffs.shape[:-1] + (pad,), coeffs.dtype)], axis=-1)
        return minimize_quartic(coeffs, lo, hi)
    return minimize_poly_grid(coeffs, lo, hi)
