"""Unified matrix-function API — the framework's public primitive layer.

Every consumer (Muon, Shampoo, examples, benchmarks) goes through these
entry points; ``method`` selects the algorithm:

  polar:     prism | newton_schulz | polar_express | svd
  sqrtm:     prism | newton_schulz | polar_express | newton(DB) | eigh
  inv_sqrtm: same as sqrtm (coupled Y output) + inverse_newton
  signm:     prism | newton_schulz | eigh
  inv:       prism_chebyshev | chebyshev | inverse_newton | solve
  inv_proot: prism | inverse_newton | eigh

"prism" methods adapt alpha per iteration from the sketched spectrum —
distribution-free, no sigma_min estimate — per the paper.

Precision (DESIGN.md §9): ``cfg.dtype`` (or the ``dtype`` kwarg of the
cfg-free families) is the COMPUTE dtype threaded into every iteration;
accumulation and the PRISM alpha fit are pinned fp32 by MatfnPrecision.
The LAPACK baselines (svd / eigh / solve / DB-Newton's Cholesky) always
run fp32 — bf16 inputs upcast in, results round back out.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import PrismConfig
from repro.core import chebyshev as _cheb
from repro.core import inverse_newton as _invnewton
from repro.core import newton as _newton
from repro.core import newton_schulz as _ns
from repro.core import polar_express as _pe

_DEF = PrismConfig()


def polar(A: jax.Array, method: str = "prism", cfg: PrismConfig = _DEF,
          iters: Optional[int] = None, key: Optional[jax.Array] = None,
          **kw):
    """Polar factor U V^T (orthogonalization) of A [..., m, n]."""
    if method == "svd":
        U, _, Vt = jnp.linalg.svd(A.astype(jnp.float32), full_matrices=False)
        return (U @ Vt).astype(A.dtype)
    if method == "polar_express":
        kw.setdefault("dtype", cfg.dtype)
        return _pe.polar(A, iters=iters or 8, **kw)
    return _ns.polar(A, cfg=cfg, method=method, iters=iters, key=key, **kw)


def sqrtm(A: jax.Array, method: str = "prism", cfg: PrismConfig = _DEF,
          iters: Optional[int] = None, key: Optional[jax.Array] = None,
          **kw):
    """(A^{1/2}, A^{-1/2}) for symmetric PSD A."""
    if method == "eigh":
        w, V = jnp.linalg.eigh(A.astype(jnp.float32))
        w = jnp.maximum(w, 0.0)
        s = jnp.sqrt(w)
        si = jnp.where(s > 0, 1.0 / jnp.maximum(s, 1e-30), 0.0)
        Vt = jnp.swapaxes(V, -1, -2)
        return ((V * s[..., None, :]) @ Vt).astype(A.dtype), \
            ((V * si[..., None, :]) @ Vt).astype(A.dtype)
    if method == "polar_express":
        kw.setdefault("dtype", cfg.dtype)
        return _pe.sqrtm(A, iters=iters or 8, **kw)
    if method == "newton":
        return _newton.sqrtm(A, iters=iters or 12, method="prism", **kw)
    if method == "newton_classical":
        return _newton.sqrtm(A, iters=iters or 12, method="newton", **kw)
    return _ns.sqrtm(A, cfg=cfg, method=method, iters=iters, key=key, **kw)


def inv_sqrtm(A: jax.Array, method: str = "prism", **kw):
    """A^{-1/2} for symmetric PSD A (coupled-iteration Y output)."""
    if method == "inverse_newton":
        return _invnewton.inv_proot(A, p=2, **kw)
    return sqrtm(A, method=method, **kw)[1]


def signm(A: jax.Array, method: str = "prism", cfg: PrismConfig = _DEF,
          iters: Optional[int] = None, key: Optional[jax.Array] = None,
          **kw):
    """sign(A) for A with A^2 symmetric."""
    if method == "eigh":
        w, V = jnp.linalg.eigh(A.astype(jnp.float32))
        Vt = jnp.swapaxes(V, -1, -2)
        return ((V * jnp.sign(w)[..., None, :]) @ Vt).astype(A.dtype)
    return _ns.signm(A, cfg=cfg, method=method, iters=iters, key=key, **kw)


def inv(A: jax.Array, method: str = "prism_chebyshev",
        iters: Optional[int] = None, key: Optional[jax.Array] = None, **kw):
    """A^{-1} for full-rank square A."""
    if method == "solve":
        A32 = A.astype(jnp.float32)
        eye = jnp.broadcast_to(jnp.eye(A.shape[-1], dtype=jnp.float32),
                               A.shape)
        return jnp.linalg.solve(A32, eye).astype(A.dtype)
    if method == "inverse_newton":
        return _invnewton.inv_proot(A, p=1, iters=iters or 20, key=key, **kw)
    m = "prism" if method == "prism_chebyshev" else "chebyshev"
    return _cheb.inv(A, iters=iters or 20,
                     method="prism" if m == "prism" else "classical",
                     key=key, **kw)


def inv_proot(A: jax.Array, p: int, method: str = "prism",
              iters: Optional[int] = None, key: Optional[jax.Array] = None,
              **kw):
    """A^{-1/p} for SPD A."""
    if method == "eigh":
        w, V = jnp.linalg.eigh(A.astype(jnp.float32))
        w = jnp.maximum(w, 1e-30)
        Vt = jnp.swapaxes(V, -1, -2)
        return ((V * (w ** (-1.0 / p))[..., None, :]) @ Vt).astype(A.dtype)
    meth = "prism" if method == "prism" else "classical"
    return _invnewton.inv_proot(A, p=p, iters=iters or 20, method=meth,
                                key=key, **kw)
