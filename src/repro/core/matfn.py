"""Unified matrix-function API — the framework's public primitive layer.

Every consumer (Muon, Shampoo, examples, benchmarks) goes through these
entry points; ``method`` selects the algorithm:

  polar:     prism | newton_schulz | polar_express | svd
  sqrtm:     prism | newton_schulz | polar_express | newton(DB) | eigh
  inv_sqrtm: same as sqrtm (coupled Y output) + inverse_newton
  signm:     prism | newton_schulz | eigh
  inv:       prism_chebyshev | chebyshev | inverse_newton | solve
  inv_proot: prism | inverse_newton | eigh

"prism" methods adapt alpha per iteration from the sketched spectrum —
distribution-free, no sigma_min estimate — per the paper.

Precision (DESIGN.md §9): ``cfg.dtype`` (or the ``dtype`` kwarg of the
cfg-free families) is the COMPUTE dtype threaded into every iteration;
accumulation and the PRISM alpha fit are pinned fp32 by MatfnPrecision.
The LAPACK baselines (svd / eigh / solve / DB-Newton's Cholesky) always
run fp32 — bf16 inputs upcast in, results round back out.

Adaptive early stopping (DESIGN.md §11): ``cfg.tol`` (or the ``tol``
kwarg of the cfg-free families) turns the fixed iteration count into a
BUDGET for every prism-fitted chain — each fitted step reads the
certificate est_r ~ ||R_k||_F off the sketched trace chain it already
computes, and a batch slice freezes (bit-stably) the moment it
certifies.  Caveats, uniform across families:
  * the certificate is an unbiased sketch ESTIMATE (relative std
    ~sqrt(2/sketch_dim)), not a bound — a slice can certify with its
    true ||R||_F slightly above tol; ``sketch_dim=0`` (exact traces)
    makes the certificate exact at O(n^3) per check;
  * tol=None (default) reproduces the pre-§11 fixed-``iters`` chains
    bit-for-bit, stays reverse-differentiable (lax.while_loop is not),
    and is what ``return_info`` diagnostics always use;
  * classical (fit-free) methods compute no traces and therefore run
    their fixed schedule regardless of tol.
Every NS-family entry point accepts ``return_iters=True`` to append the
per-matrix realized iteration counts (int32, shape ``A.shape[:-2]``),
and ``return_status=True`` to append the per-matrix int8 guardian
status (prism.STATUS_OK / STATUS_MAXITER / STATUS_QUARANTINED — the
§15 divergence detector riding the same certificate; all-zeros for
methods that certify nothing).

Config aliasing: entry points default ``cfg=None`` and construct a fresh
``PrismConfig()`` per call — there is no module-level shared default
instance for callers to alias (or observe each other through).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import PrismConfig
from repro.core import chebyshev as _cheb
from repro.core import inverse_newton as _invnewton
from repro.core import newton as _newton
from repro.core import newton_schulz as _ns
from repro.core import polar_express as _pe


def _telemetry_shim(out, A, kw, method: str):
    """Uniform telemetry contract for methods without fitted iterations
    (LA oracles, fixed-schedule baselines): ``return_iters`` appends
    zeros — they certify nothing, matching optim/shampoo's convention —
    ``return_status`` appends int8 zeros (no certificate => no guardian
    verdict, DESIGN.md §15), and ``return_info`` (a per-iteration
    trajectory these methods never produce) raises instead of silently
    returning garbage.  MUTATES kw (pops the telemetry keys) so
    remaining kwargs can pass through."""
    if kw.pop("return_info", False):
        raise ValueError(f"return_info is not supported by "
                         f"method={method!r} (no iteration trajectory)")
    ri = kw.pop("return_iters", False)
    rs = kw.pop("return_status", False)
    res = (out,)
    if ri:
        res = res + (jnp.zeros(A.shape[:-2], jnp.int32),)
    if rs:
        res = res + (jnp.zeros(A.shape[:-2], jnp.int8),)
    return res if len(res) > 1 else out


def _run_fixed_schedule(fn, A, kw):
    """Run a fixed-schedule (fit-free) iteration family that supports
    ``return_info`` but not ``return_iters``/``return_status``
    (polar_express, DB-newton): pops those keys and appends zero counts
    / zero statuses FLAT after the family's (out[, info]) result,
    keeping the documented (out[, info][, iters][, status]) shape."""
    ri = kw.pop("return_iters", False)
    rs = kw.pop("return_status", False)
    res = fn(**kw)
    if not (ri or rs):
        return res
    if not kw.get("return_info"):
        res = (res,)
    if ri:
        res = res + (jnp.zeros(A.shape[:-2], jnp.int32),)
    if rs:
        res = res + (jnp.zeros(A.shape[:-2], jnp.int8),)
    return res


def polar(A: jax.Array, method: str = "prism",
          cfg: Optional[PrismConfig] = None,
          iters: Optional[int] = None, key: Optional[jax.Array] = None,
          **kw):
    """Polar factor U V^T (orthogonalization) of A [..., m, n].

    kw passthrough (NS family): ``return_info``, ``return_iters``,
    ``n_real`` — see ``newton_schulz.polar``.  ``cfg.tol`` enables
    adaptive early stopping (module docstring).
    """
    cfg = PrismConfig() if cfg is None else cfg
    if method == "svd":
        U, _, Vt = jnp.linalg.svd(A.astype(jnp.float32), full_matrices=False)
        return _telemetry_shim((U @ Vt).astype(A.dtype), A, kw, method)
    if method == "polar_express":
        kw.setdefault("dtype", cfg.dtype)
        return _run_fixed_schedule(
            lambda **k: _pe.polar(A, iters=iters or 8, **k), A, kw)
    return _ns.polar(A, cfg=cfg, method=method, iters=iters, key=key, **kw)


def sqrtm(A: jax.Array, method: str = "prism",
          cfg: Optional[PrismConfig] = None,
          iters: Optional[int] = None, key: Optional[jax.Array] = None,
          **kw):
    """(A^{1/2}, A^{-1/2}) for symmetric PSD A.

    kw passthrough (NS family): ``return_info``, ``return_iters``;
    ``cfg.tol`` freezes both coupled iterates per slice on certification
    (module docstring).
    """
    cfg = PrismConfig() if cfg is None else cfg
    if method == "eigh":
        w, V = jnp.linalg.eigh(A.astype(jnp.float32))
        w = jnp.maximum(w, 0.0)
        s = jnp.sqrt(w)
        si = jnp.where(s > 0, 1.0 / jnp.maximum(s, 1e-30), 0.0)
        Vt = jnp.swapaxes(V, -1, -2)
        out = (((V * s[..., None, :]) @ Vt).astype(A.dtype),
               ((V * si[..., None, :]) @ Vt).astype(A.dtype))
        return _telemetry_shim(out, A, kw, method)
    if method == "polar_express":
        kw.setdefault("dtype", cfg.dtype)
        return _run_fixed_schedule(
            lambda **k: _pe.sqrtm(A, iters=iters or 8, **k), A, kw)
    if method in ("newton", "newton_classical"):
        return _run_fixed_schedule(
            lambda **k: _newton.sqrtm(
                A, iters=iters or 12,
                method="prism" if method == "newton" else "newton", **k),
            A, kw)
    return _ns.sqrtm(A, cfg=cfg, method=method, iters=iters, key=key, **kw)


def inv_sqrtm(A: jax.Array, method: str = "prism", **kw):
    """A^{-1/2} for symmetric PSD A (coupled-iteration Y output).

    With ``return_info``/``return_iters`` the telemetry rides along:
    returns (A^{-1/2}[, info][, iters_used]).
    """
    if method == "inverse_newton":
        return _invnewton.inv_proot(A, p=2, **kw)
    res = sqrtm(A, method=method, **kw)
    if kw.get("return_info") or kw.get("return_iters") \
            or kw.get("return_status"):
        return (res[0][1],) + tuple(res[1:])
    return res[1]


def signm(A: jax.Array, method: str = "prism",
          cfg: Optional[PrismConfig] = None,
          iters: Optional[int] = None, key: Optional[jax.Array] = None,
          **kw):
    """sign(A) for A with A^2 symmetric.

    kw passthrough (NS family): ``return_info``, ``return_iters``;
    ``cfg.tol`` enables adaptive early stopping (module docstring).
    """
    cfg = PrismConfig() if cfg is None else cfg
    if method == "eigh":
        w, V = jnp.linalg.eigh(A.astype(jnp.float32))
        Vt = jnp.swapaxes(V, -1, -2)
        out = ((V * jnp.sign(w)[..., None, :]) @ Vt).astype(A.dtype)
        return _telemetry_shim(out, A, kw, method)
    return _ns.signm(A, cfg=cfg, method=method, iters=iters, key=key, **kw)


def inv(A: jax.Array, method: str = "prism_chebyshev",
        iters: Optional[int] = None, key: Optional[jax.Array] = None, **kw):
    """A^{-1} for full-rank square A.

    kw passthrough (Chebyshev family): ``tol`` (adaptive early stopping
    for the prism method — module docstring), ``return_iters``,
    ``return_info``, ``dtype``, ``sketch_dim``, ``alpha_bounds``.
    """
    if method == "solve":
        kw.pop("tol", None)  # no iterations to stop early
        A32 = A.astype(jnp.float32)
        eye = jnp.broadcast_to(jnp.eye(A.shape[-1], dtype=jnp.float32),
                               A.shape)
        return _telemetry_shim(jnp.linalg.solve(A32, eye).astype(A.dtype),
                               A, kw, method)
    if method == "inverse_newton":
        return _invnewton.inv_proot(A, p=1, iters=iters or 20, key=key, **kw)
    m = "prism" if method == "prism_chebyshev" else "chebyshev"
    return _cheb.inv(A, iters=iters or 20,
                     method="prism" if m == "prism" else "classical",
                     key=key, **kw)


def inv_proot(A: jax.Array, p: int, method: str = "prism",
              iters: Optional[int] = None, key: Optional[jax.Array] = None,
              **kw):
    """A^{-1/p} for SPD A.

    kw passthrough (inverse-Newton family): ``tol`` (adaptive early
    stopping for the prism method — module docstring), ``return_iters``,
    ``return_info``, ``dtype``, ``sketch_dim``, ``alpha_bounds``.
    """
    if method == "eigh":
        kw.pop("tol", None)  # no iterations to stop early
        w, V = jnp.linalg.eigh(A.astype(jnp.float32))
        w = jnp.maximum(w, 1e-30)
        Vt = jnp.swapaxes(V, -1, -2)
        out = ((V * (w ** (-1.0 / p))[..., None, :]) @ Vt).astype(A.dtype)
        return _telemetry_shim(out, A, kw, method)
    meth = "prism" if method == "prism" else "classical"
    return _invnewton.inv_proot(A, p=p, iters=iters or 20, method=meth,
                                key=key, **kw)
