"""Chebyshev iteration for the matrix inverse (paper App. A.4).

  X_0 = A^T / ||A||_F^2-free normalization (we scale A by ||A||_F first),
  R_k = I - A X_k,
  X_{k+1} = X_k (I + R_k + a_k R_k^2).

Classical Chebyshev is a_k = 1; PRISM fits a_k over [1/2, 2] by minimizing
||S (R^2 - a (R^2 - R^3))||_F^2, a closed-form quadratic in a.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import polynomials as poly
from repro.core import prism
from repro.core.newton_schulz import IterInfo, _mm, _safe_fro


def inv(A: jax.Array, iters: int = 20, method: str = "prism",
        sketch_dim: int = 8, key: Optional[jax.Array] = None,
        dtype=jnp.float32, alpha_bounds=(0.5, 2.0),
        return_info: bool = False, tol: Optional[float] = None,
        return_iters: bool = False, return_status: bool = False,
        divergence_factor: float = 10.0):
    """A^{-1} for full-rank square A via (PRISM-)Chebyshev iteration.

    tol: adaptive early-stopping certificate (DESIGN.md §11): with
      ``method="prism"`` the whole chain runs as one ``lax.while_loop``
      that freezes each batch slice (bit-stably, masked identity update)
      once its sketched residual estimate est_r ~ ||I - A X_k||_F drops
      to tol, exiting when the slowest slice certifies; ``iters`` is
      then a budget.  The classical method has no trace chain to read a
      certificate from, so it ignores tol and runs the fixed ``iters``
      (as does ``return_info``, which must stack per-iteration values).
    return_iters: also return per-matrix ``iters_used`` (int32,
      shape ``A.shape[:-2]``).
    return_status: also return the per-matrix int8 guardian status
      (prism.STATUS_*, DESIGN.md §15); ``divergence_factor`` is the
      detector threshold of the adaptive loop.  All-zeros on the
      non-adaptive paths, which carry no certificate to read.
    """
    in_dtype = A.dtype
    n = A.shape[-1]
    # zero-slice guard (§15): 0/0 normalization would poison X_0 before
    # the certificate ever runs — clamp like the NS entry points do
    c = _safe_fro(A).astype(dtype)
    Ah = A.astype(dtype) / c
    X = jnp.swapaxes(Ah, -1, -2)
    apoly = poly.chebyshev_residual()
    batch = A.shape[:-2]
    adaptive = tol is not None and method == "prism" and not return_info

    def residual(X_):
        # fp32-accumulated products, rounded once to the compute dtype
        # (matches the kernel accumulation contract, DESIGN.md §9)
        return (jnp.eye(n, dtype=jnp.float32)
                - jnp.matmul(Ah, X_, preferred_element_type=jnp.float32)
                ).astype(dtype)

    def fit(R, k):
        # R = I - A X is NOT symmetric in general; the trace machinery
        # needs symmetric R, which holds here because X_0 = A^T makes
        # every X_k a polynomial in A^T A times A^T => A X_k symmetric.
        kk = prism.alpha_schedule_key(key, k) if key is not None else None
        return prism.fit_alpha(R, apoly, *alpha_bounds, key=kk,
                               sketch_dim=sketch_dim, return_est_r=True)

    def step(X_, R, a):
        ab = a.astype(dtype)[..., None, None]
        XR = _mm(X_, R)
        return X_ + XR + ab * _mm(XR, R)

    if adaptive:
        out_it, used, status = prism.adaptive_masked_loop(
            {"X": X},
            lambda it, k: (lambda R: (R,) + fit(R, k))(residual(it["X"])),
            lambda it, R, a: {"X": step(it["X"], R, a)},
            tol, 0, iters, batch, divergence_factor=divergence_factor)
        X = out_it["X"]
    else:
        alphas, fros = [], []
        for k in range(iters):
            R = residual(X)
            if method == "prism":
                a, _ = fit(R, k)
            else:
                a = jnp.full(batch, 1.0, dtype=jnp.float32)
            if return_info:
                alphas.append(a)
                fros.append(_fro(R)[..., 0, 0])
            X = step(X, R, a)
        used = jnp.full(batch, iters, jnp.int32)
        status = jnp.zeros(batch, jnp.int8)
    out = (X / c).astype(in_dtype)
    res = (out,)
    if return_info:
        res = res + (IterInfo(jnp.stack(alphas), jnp.stack(fros)),)
    if return_iters:
        res = res + (used,)
    if return_status:
        res = res + (status,)
    return res if len(res) > 1 else res[0]
