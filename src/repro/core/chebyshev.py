"""Chebyshev iteration for the matrix inverse (paper App. A.4).

  X_0 = A^T / ||A||_F^2-free normalization (we scale A by ||A||_F first),
  R_k = I - A X_k,
  X_{k+1} = X_k (I + R_k + a_k R_k^2).

Classical Chebyshev is a_k = 1; PRISM fits a_k over [1/2, 2] by minimizing
||S (R^2 - a (R^2 - R^3))||_F^2, a closed-form quadratic in a.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import polynomials as poly
from repro.core import prism
from repro.core.newton_schulz import IterInfo, _fro, _mm


def inv(A: jax.Array, iters: int = 20, method: str = "prism",
        sketch_dim: int = 8, key: Optional[jax.Array] = None,
        dtype=jnp.float32, alpha_bounds=(0.5, 2.0),
        return_info: bool = False):
    """A^{-1} for full-rank square A via (PRISM-)Chebyshev iteration."""
    in_dtype = A.dtype
    n = A.shape[-1]
    c = _fro(A).astype(dtype)
    Ah = A.astype(dtype) / c
    X = jnp.swapaxes(Ah, -1, -2)
    apoly = poly.chebyshev_residual()
    alphas, fros = [], []
    for k in range(iters):
        # fp32-accumulated products, rounded once to the compute dtype
        # (matches the kernel accumulation contract, DESIGN.md §9)
        R = (jnp.eye(n, dtype=jnp.float32)
             - jnp.matmul(Ah, X, preferred_element_type=jnp.float32)
             ).astype(dtype)
        if method == "prism":
            # R = I - A X is NOT symmetric in general; the trace machinery
            # needs symmetric R, which holds here because X_0 = A^T makes
            # every X_k a polynomial in A^T A times A^T => A X_k symmetric.
            kk = prism.alpha_schedule_key(key, k) if key is not None else None
            a = prism.fit_alpha(R, apoly, *alpha_bounds, key=kk,
                                sketch_dim=sketch_dim)
        else:
            a = jnp.full(A.shape[:-2], 1.0, dtype=jnp.float32)
        if return_info:
            alphas.append(a)
            fros.append(_fro(R)[..., 0, 0])
        ab = a.astype(dtype)[..., None, None]
        XR = _mm(X, R)
        X = X + XR + ab * _mm(XR, R)
    out = (X / c).astype(in_dtype)
    if return_info:
        return out, IterInfo(jnp.stack(alphas), jnp.stack(fros))
    return out
