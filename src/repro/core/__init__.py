"""PRISM core: distribution-free adaptive matrix-function computation."""
from repro.core import (chebyshev, inverse_newton, matfn, newton,
                        newton_schulz, polar_express, polynomials,
                        random_matrices, sketch)
from repro.core.matfn import inv, inv_proot, inv_sqrtm, polar, signm, sqrtm
from repro.core.prism import fit_alpha

__all__ = [
    "chebyshev", "inverse_newton", "matfn", "newton", "newton_schulz",
    "polar_express", "polynomials", "random_matrices", "sketch",
    "inv", "inv_proot", "inv_sqrtm", "polar", "signm", "sqrtm", "fit_alpha",
]
