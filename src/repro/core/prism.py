"""The PRISM alpha-fitting engine (meta-algorithm Part II).

Given the residual matrix R_k of any Table-1 iteration, the engine

  1. sketches power traces t_i = tr(S_k R_k^i S_k^T)     (core/sketch.py)
  2. maps them through the algorithm's fixed trace-weight matrix W to get
     the coefficients of the quartic (degree-2s) objective m(alpha)
  3. minimizes m over the constraint interval [l, u] in closed form.

Everything is jittable, batched over leading dims of R, and costs
O(n^2 p) — the paper's headline overhead bound.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import PrismConfig
from repro.core import polynomials as poly
from repro.core import sketch as sk


def fit_alpha(
    R: jax.Array,
    apoly: poly.AlphaPoly,
    lo: float,
    hi: float,
    key: Optional[jax.Array] = None,
    sketch_dim: int = 8,
    use_kernels: bool = False,
) -> jax.Array:
    """alpha~_k = argmin_{alpha in [lo, hi]} || S h(R; alpha) ||_F^2.

    Args:
      R: residual matrix [..., n, n], symmetric.
      apoly: the iteration's residual polynomial h(x; alpha).
      lo, hi: the constraint interval [l, u].
      key: PRNG key for the sketch; None => exact (unsketched) traces.
      sketch_dim: p; 0 => exact traces regardless of key.

    Returns alpha with shape R.shape[:-2].
    """
    max_pow = poly.max_trace_power(apoly)
    if key is None or sketch_dim == 0:
        t = sk.exact_power_traces(R, max_pow)
    else:
        S = sk.gaussian_sketch(key, sketch_dim, R.shape[-1], dtype=R.dtype)
        t = sk.sketched_power_traces(R, S, max_pow, use_kernels=use_kernels)
    W = jnp.asarray(poly.trace_weight_matrix(apoly), dtype=jnp.float32)
    coeffs = jnp.einsum("ki,...i->...k", W, t)
    return poly.minimize_alpha_poly(coeffs, lo, hi)


def objective_value(R: jax.Array, apoly: poly.AlphaPoly, alpha) -> jax.Array:
    """Exact m(alpha) = ||h(R; alpha)||_F^2 (test/diagnostic helper)."""
    max_pow = poly.max_trace_power(apoly)
    t = sk.exact_power_traces(R, max_pow)
    W = jnp.asarray(poly.trace_weight_matrix(apoly), dtype=jnp.float32)
    coeffs = jnp.einsum("ki,...i->...k", W, t)
    return poly._polyval_asc(coeffs, jnp.asarray(alpha, jnp.float32))


def alpha_schedule_key(key: jax.Array, k: jax.Array) -> jax.Array:
    """Per-iteration sketch key (fresh S_k each iteration, as in Thm 2)."""
    return jax.random.fold_in(key, k)


def resolve_alpha(
    k: jax.Array,
    R: jax.Array,
    apoly: poly.AlphaPoly,
    cfg: PrismConfig,
    key: Optional[jax.Array],
) -> jax.Array:
    """alpha_k per the config: warm iterations pin alpha = u (paper Sec. C),
    later iterations fit via the sketched objective."""
    lo, hi = cfg.bounds
    if key is not None:
        key = alpha_schedule_key(key, k)
    fitted = fit_alpha(R, apoly, lo, hi, key=key, sketch_dim=cfg.sketch_dim,
                       use_kernels=cfg.use_kernels)
    if cfg.warm_alpha_iters <= 0:
        return fitted
    warm = jnp.full_like(fitted, hi)
    return jnp.where(k < cfg.warm_alpha_iters, warm, fitted)
