"""The PRISM alpha-fitting engine (meta-algorithm Part II).

Given the residual matrix R_k of any Table-1 iteration, the engine

  1. sketches power traces t_i = tr(S_k R_k^i S_k^T)     (core/sketch.py)
  2. maps them through the algorithm's fixed trace-weight matrix W to get
     the coefficients of the quartic (degree-2s) objective m(alpha)
  3. minimizes m over the constraint interval [l, u] in closed form.

Everything is jittable, batched over leading dims of R, and costs
O(n^2 p) — the paper's headline overhead bound.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import PrismConfig
from repro.core import polynomials as poly
from repro.core import sketch as sk


def fit_alpha(
    R: jax.Array,
    apoly: poly.AlphaPoly,
    lo: float,
    hi: float,
    key: Optional[jax.Array] = None,
    sketch_dim: int = 8,
    use_kernels: bool = False,
    n_real: Optional[jax.Array] = None,
    vmem_budget: int = 0,
    return_est_r: bool = False,
):
    """alpha~_k = argmin_{alpha in [lo, hi]} || S h(R; alpha) ||_F^2.

    Args:
      R: residual matrix [..., n, n], symmetric.
      apoly: the iteration's residual polynomial h(x; alpha).
      lo, hi: the constraint interval [l, u].
      key: PRNG key for the sketch; None => exact (unsketched) traces.
      sketch_dim: p; 0 => exact traces regardless of key.
      n_real: per-matrix count of REAL dimensions when R comes from a
        zero-padded pad-to-bucket matrix (shape R.shape[:-2]); None => no
        padding.  For zero-padded polar NS the residual is exactly
        block-diagonal, R = diag(R_real, I_pad), so every power trace picks
        up the SAME pad contribution c = sum_{j >= n_real} ||S[:, j]||^2
        (identity block, i-independent).  Subtracting c from every t_i
        recovers the traces of R_real exactly — the fitted alpha is
        bit-identical to the unpadded fit with sketch S[:, :n_real]
        (DESIGN.md §7).
      vmem_budget: override (bytes) for the chain kernel's VMEM guard on
        the use_kernels path (DESIGN.md §10); threaded from
        PrismConfig.vmem_budget by resolve_alpha.
      return_est_r: also return the convergence certificate est_r (see
        ``fit_alpha_from_traces``) read off the same trace chain.

    Returns alpha with shape R.shape[:-2]; with ``return_est_r`` the
    tuple (alpha, est_r), est_r of the same shape (fp32).
    """
    n = R.shape[-1]
    max_pow = poly.max_trace_power(apoly)
    # Precision (DESIGN.md §9): the sketch S lives in the COMPUTE dtype of
    # R (its products are chain GEMMs), but everything downstream of the
    # trace accumulators — t, the pad-trace correction c, the W map, the
    # closed-form minimization — is pinned fp32 (MatfnPrecision.fit).  In
    # particular c must be reduced in fp32 from the same (possibly
    # bf16-rounded) S values the chain consumed: the pad block of R is
    # exactly I in any dtype, so the fp32-accumulated trace picks up
    # exactly the fp32 sum of squared pad columns, and the correction
    # stays exact under bf16 compute.
    if key is None or sketch_dim == 0:
        t = sk.exact_power_traces(R, max_pow)
        if n_real is not None:
            # exact traces: the I_pad block adds (n - n_real) to every tr(R^i)
            pad_tr = (n - n_real).astype(jnp.float32)
            t = t - pad_tr[..., None]
        return fit_alpha_from_traces(t, apoly, lo, hi,
                                     return_est_r=return_est_r)
    S = sk.gaussian_sketch(key, sketch_dim, n, dtype=R.dtype)
    t = sk.sketched_power_traces(R, S, max_pow, use_kernels=use_kernels,
                                 vmem_budget=vmem_budget)
    return fit_alpha_from_traces(t, apoly, lo, hi, S=S, n_real=n_real,
                                 return_est_r=return_est_r)


def sketch_pad_trace_correction(S: jax.Array, n_real: jax.Array) -> jax.Array:
    """c = sum_{j >= n_real} ||S[:, j]||^2 — the i-independent contribution
    the residual's identity pad block adds to EVERY sketched power trace
    of a zero-padded polar iterate (DESIGN.md §7).  fp32 end-to-end: the
    correction must be reduced in fp32 from the same (possibly
    bf16-rounded) sketch values the chain consumed (§9)."""
    n = S.shape[-1]
    s2 = jnp.sum(jnp.square(S.astype(jnp.float32)), axis=0)  # [n]
    pad_mask = jnp.arange(n) >= n_real[..., None]
    return jnp.sum(s2 * pad_mask, axis=-1)


def fit_alpha_from_traces(
    t: jax.Array,
    apoly: poly.AlphaPoly,
    lo: float,
    hi: float,
    S: Optional[jax.Array] = None,
    n_real: Optional[jax.Array] = None,
    return_est_r: bool = False,
):
    """Closed-form alpha fit from PRECOMPUTED power traces.

    The back half of ``fit_alpha``, split out so the fused
    residual+chain kernel tier (kernels/ops.residual_chain, DESIGN.md
    §10) — which reduces the traces inside the residual launch — feeds
    the identical W-map + constrained minimization.  ``t`` holds powers
    0..max_trace_power (fp32); with ``n_real`` the sketched pad-trace
    correction (requires ``S``) is applied first.

    ``return_est_r`` additionally returns the convergence certificate

        est_r = sqrt(max(t_2, 0)),   t_2 = tr(S R^2 S^T)  (pad-corrected)

    — an unbiased estimate of ||R||_F for symmetric R (E[S^T S] = I for
    the N(0, 1/p) sketch), read off the SAME trace chain the fit already
    consumed, so a per-iteration stopping certificate costs zero extra
    launches (DESIGN.md §11).  fp32 end-to-end like the fit itself; the
    §7 n_real correction keeps it exact for zero-padded bucket slices.
    With exact traces (sketch_dim=0) est_r == ||R||_F exactly; with a
    p-row sketch its relative std is ~sqrt(2/p) — a certificate, not a
    bound (see PrismConfig.tol).
    """
    if n_real is not None:
        t = t - sketch_pad_trace_correction(S, n_real)[..., None]
    W = jnp.asarray(poly.trace_weight_matrix(apoly), dtype=jnp.float32)
    coeffs = jnp.einsum("ki,...i->...k", W, t)
    alpha = poly.minimize_alpha_poly(coeffs, lo, hi)
    if return_est_r:
        return alpha, jnp.sqrt(jnp.maximum(t[..., 2], 0.0))
    return alpha


def objective_value(R: jax.Array, apoly: poly.AlphaPoly, alpha) -> jax.Array:
    """Exact m(alpha) = ||h(R; alpha)||_F^2 (test/diagnostic helper)."""
    max_pow = poly.max_trace_power(apoly)
    t = sk.exact_power_traces(R, max_pow)
    W = jnp.asarray(poly.trace_weight_matrix(apoly), dtype=jnp.float32)
    coeffs = jnp.einsum("ki,...i->...k", W, t)
    return poly._polyval_asc(coeffs, jnp.asarray(alpha, jnp.float32))


def alpha_schedule_key(key: jax.Array, k: jax.Array) -> jax.Array:
    """Per-iteration sketch key (fresh S_k each iteration, as in Thm 2)."""
    return jax.random.fold_in(key, k)


# Per-slice guardian status codes (DESIGN.md §15), int8.  Ordered by
# severity so multi-phase runs (and the optimizer's per-leaf telemetry)
# aggregate with a plain ``maximum``.
STATUS_OK = np.int8(0)           # certified: est_r <= tol before budget
STATUS_MAXITER = np.int8(1)      # budget exhausted without certifying
STATUS_QUARANTINED = np.int8(2)  # divergence detected; rolled back


def adaptive_masked_loop(iterates, fit, step, tol: float, k0: int,
                         budget: int, batch,
                         divergence_factor: float = 10.0):
    """The §11 certify-then-freeze loop driver, shared by every adaptive
    iteration family (newton_schulz fit runs, chebyshev, inverse newton).

    Runs ``lax.while_loop`` over iteration index k in [k0, k0+budget):

      aux, alpha, est_r = fit(iterates, k)     # reads the certificate
      done |= est_r <= tol                     # certify BEFORE updating
      new = step(iterates, aux, alpha)         # one family iteration
      iterates = where(~done, new, iterates)   # frozen slices: bitwise

    exiting when every batch slice is certified or the budget runs out.

    Divergence containment (DESIGN.md §15): the same free certificate
    doubles as a divergence detector.  Each slice tracks its best
    (smallest) est_r so far together with a snapshot of the iterates
    that achieved it; the step est_r goes non-finite or exceeds
    ``divergence_factor ×`` that best, the slice is QUARANTINED —
    rolled back to the best-so-far snapshot (a ``jnp.where`` select,
    bitwise like the freeze masks, zero extra launches) and withdrawn
    from further updates.  Certification wins ties: a slice whose est_r
    clears tol freezes as OK even if the detector would also fire.

    Args:
      iterates: dict of same-batch [..., n, n] iterate arrays (e.g.
        {"X": X} or the coupled {"X": X, "Y": Y} / {"X": X, "M": M}).
      fit: (iterates, k) -> (aux, alpha, est_r); ``aux`` is whatever
        ``step`` needs (typically the residual R), est_r fp32 of shape
        ``batch``.
      step: (iterates, aux, alpha) -> dict of updated iterates.
      tol, k0, budget: certificate threshold and the static run bounds.
      batch: the shared leading batch shape of every iterate.
      divergence_factor: the §15 detector threshold (> 1), see
        ``PrismConfig.divergence_factor``.

    Returns (iterates, used, status): the frozen/final iterates, the
    int32 per-slice count of updates actually applied, and the int8
    per-slice STATUS_OK / STATUS_MAXITER / STATUS_QUARANTINED code.
    """
    names = tuple(iterates)

    def cond(c):
        return (c["k"] < k0 + budget) & ~jnp.all(c["done"])

    def body(c):
        cur = {n: c[n] for n in names}
        aux, a, est = fit(cur, c["k"])
        certified = est <= tol                 # NaN est never certifies
        diverged = ~jnp.isfinite(est) | (est > divergence_factor * c["best"])
        quarantine = diverged & ~certified & ~c["done"]
        done = c["done"] | certified | quarantine
        active = ~done
        improved = est < c["best"]             # finite: NaN compares False
        keep = (improved & active)[..., None, None]
        new = step(cur, aux, a)
        mask = active[..., None, None]
        qmask = quarantine[..., None, None]
        out = dict(c, k=c["k"] + 1, done=done,
                   used=c["used"] + active.astype(jnp.int32),
                   best=jnp.where(improved & active, est, c["best"]),
                   status=jnp.where(quarantine, STATUS_QUARANTINED,
                                    c["status"]))
        for n in names:
            # snapshot BEFORE rollback: the iterate the best est_r was
            # measured on is the pre-step `cur`, not `new`
            snap = jnp.where(keep, cur[n], c["snap." + n])
            out["snap." + n] = snap
            out[n] = jnp.where(qmask, snap, jnp.where(mask, new[n], c[n]))
        return out

    carry = dict(iterates, k=jnp.asarray(k0, jnp.int32),
                 done=jnp.zeros(batch, bool),
                 used=jnp.zeros(batch, jnp.int32),
                 best=jnp.full(batch, jnp.inf, jnp.float32),
                 status=jnp.zeros(batch, jnp.int8))
    for n in names:
        carry["snap." + n] = iterates[n]
    out = jax.lax.while_loop(cond, body, carry)
    status = jnp.where(out["done"], out["status"],
                       jnp.asarray(STATUS_MAXITER))
    return {n: out[n] for n in names}, out["used"], status


def resolve_alpha(
    k: int,
    R: jax.Array,
    apoly: poly.AlphaPoly,
    cfg: PrismConfig,
    key: Optional[jax.Array],
    n_real: Optional[jax.Array] = None,
) -> jax.Array:
    """alpha_k per the config: warm iterations pin alpha = u (paper Sec. C),
    later iterations fit via the sketched objective.

    ``k`` is a STATIC Python int (the Table-1 iterations unroll), so warm
    iterations compile to a constant — no sketch, no fit, zero overhead.
    The Newton-Schulz family (polar / sqrtm / signm) routes through here;
    chebyshev and inverse_newton carry their own bounds and no warm
    schedule, so they call fit_alpha directly.
    """
    lo, hi = cfg.bounds
    if k < cfg.warm_alpha_iters:
        return jnp.full(R.shape[:-2], hi, dtype=jnp.float32)
    if key is not None:
        key = alpha_schedule_key(key, k)
    return fit_alpha(R, apoly, lo, hi, key=key, sketch_dim=cfg.sketch_dim,
                     use_kernels=cfg.use_kernels, n_real=n_real,
                     vmem_budget=cfg.vmem_budget)
