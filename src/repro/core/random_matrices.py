"""Random-matrix generators used by the paper's experiments.

* standard Gaussian matrices with varying aspect ratio (Fig. 3 / D.1),
* matrices with a prescribed / log-uniform spectrum (Fig. 1 sigma sweep),
* Wishart matrices (Fig. D.3),
* HTMP — high-temperature Marchenko-Pastur (Hodgkinson et al. 2025) —
  heavy-tailed spectra mimicking well-trained network gradients (Fig. 4).

HTMP note (DESIGN.md §6): we reimplement HTMP from its mixing definition —
Marchenko-Pastur bulk singular values with an inverse-gamma temperature
multiplier of mean one; kappa -> inf recovers pure MP, small kappa gives a
heavy upper tail.  This is an approximation of the reference sampler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gaussian(key: jax.Array, n: int, m: int, dtype=jnp.float32) -> jax.Array:
    """Standard Gaussian N(0, 1) entries (the paper's Fig. 3 inputs)."""
    return jax.random.normal(key, (n, m), dtype=dtype)


def haar_pair(key: jax.Array, n: int, m: int, dtype=jnp.float32):
    """Haar-ish orthonormal U [n, r], V [m, r] with r = min(n, m) via QR."""
    r = min(n, m)
    ku, kv = jax.random.split(key)
    U, _ = jnp.linalg.qr(jax.random.normal(ku, (n, r), dtype=dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(kv, (m, r), dtype=dtype))
    return U, V


def with_spectrum(key: jax.Array, n: int, m: int, sigmas: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """A = U diag(sigmas) V^T with Haar factors; len(sigmas) = min(n, m)."""
    U, V = haar_pair(key, n, m, dtype)
    return (U * sigmas.astype(dtype)) @ V.T


def log_uniform_spectrum(key: jax.Array, n: int, m: int, smin: float,
                         smax: float = 1.0, dtype=jnp.float32) -> jax.Array:
    """Singular values log-uniform in [smin, smax] (Fig. 1 sweep inputs)."""
    kspec, kuv = jax.random.split(key)
    r = min(n, m)
    lo, hi = jnp.log(smin), jnp.log(smax)
    s = jnp.exp(jax.random.uniform(kspec, (r,), minval=lo, maxval=hi))
    s = s.at[0].set(smax).at[-1].set(smin)  # pin the extremes exactly
    return with_spectrum(kuv, n, m, s, dtype)


def wishart(key: jax.Array, n: int, m: int, dtype=jnp.float32) -> jax.Array:
    """A = G^T G with G [n, m] Gaussian => Wishart [m, m] (Fig. D.3)."""
    G = gaussian(key, n, m, dtype)
    return G.T @ G


def htmp(key: jax.Array, n: int, m: int, kappa: float,
         dtype=jnp.float32) -> jax.Array:
    """High-temperature Marchenko-Pastur matrix [n, m].

    Singular values: MP bulk (from an actual Gaussian matrix) with squared
    values multiplied by i.i.d. inverse-gamma(shape=kappa+1, scale=kappa)
    weights (mean 1; heavy tail as kappa -> 0).
    """
    kg, kw, kuv = jax.random.split(key, 3)
    G = gaussian(kg, n, m, dtype)
    s = jnp.linalg.svd(G, compute_uv=False)  # MP bulk
    # inverse-gamma(kappa+1, kappa): kappa / Gamma(kappa+1, 1)
    g = jax.random.gamma(kw, kappa + 1.0, (s.shape[0],), dtype=jnp.float32)
    w = kappa / jnp.maximum(g, 1e-12)
    s_heavy = s * jnp.sqrt(w).astype(dtype)
    s_heavy = jnp.sort(s_heavy)[::-1]
    A = with_spectrum(kuv, n, m, s_heavy, dtype)
    return A / jnp.max(s_heavy)  # normalize sigma_max to 1 like the paper


def spd_with_eigs(key: jax.Array, n: int, eigs: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Symmetric PD matrix with prescribed eigenvalues."""
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n), dtype=dtype))
    return (Q * eigs.astype(dtype)) @ Q.T
