"""Low-rank sketched orthogonalization (DESIGN.md §14).

The cubic polar path costs O(m n^2) per Newton-Schulz iteration, so the
views that dominate a foundation-scale model — embedding / LM-head /
MoE-expert tables, m >> n or m ~ 10^5 — historically bypassed the PRISM
engine.  Per He et al. (arXiv 2509.11983), Muon's convergence survives
orthogonalizing only the dominant rank-k subspace of the momentum; this
module computes that at O(mnl) with l = k + oversample << min(m, n):

  1. rangefinder: Y = M @ Omega with a Gaussian test matrix Omega in
     R^{n x l} (core/sketch.py Gaussians, shared per bucket through the
     PRNG key), optionally refined by power iterations Y <- M (M^T Y),
     and orthonormalized into Q in R^{m x l} by the SAME fitted PRISM-NS
     polar the engine already runs — Gram side l, so O(m l^2) per
     iteration and LAPACK-free (batched, kernel-tiered, bf16-capable);
  2. subspace fit: B = Q^T M in R^{l x n} runs the existing fitted polar
     — alpha fit, §11 adaptive early stopping and the §9 precision
     policy apply unchanged at l << m;
  3. lift: O = Q @ polar(B), one [m, l] x [l, n] GEMM.

Exactness: when M carries a genuine l-dimensional spectrum (rank ~= l,
no crossing of the fp32 rounding floor) every sketched direction is
real and the composition matches the top-l SVD orthogonalization
U_l V_l^T to NS-convergence precision; for general M it approximates
the top-l truncated polar with the classical rangefinder error, shrunk
by power iterations.  Caveat shared with every msign-style scheme: the
NS chain amplifies rounding-level singular values toward 1, so if
rank(M) < l the (l - rank) surplus sketch directions contribute unit
noise — pick l at or below the expected momentum rank, never far above.

Pad-exactness (§7 composition): zero pad rows/cols of M keep Y's pad
rows, Q's pad rows and B's pad cols identically zero through every
right-multiplied NS chain, and the Gram residuals live on the l side —
which is never padded — so the alpha fits need NO n_real correction.

Everything broadcasts over leading batch dims (M: [..., m, n]) so the
§7 bucketed engine and the §8 batch-dim shard_map dispatch it unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import PrismConfig
from repro.core import sketch as sk
from repro.core.newton_schulz import _fro, _mm


def _gaussian_test_matrix(key: jax.Array, n: int, l: int,
                          dtype) -> jax.Array:
    """Omega in R^{n x l}: the core/sketch.py Gaussian, transposed.  The
    1/sqrt(l) OSE scaling is irrelevant here (Q re-orthonormalizes) but
    keeps Y's magnitude tame for the fro-normalized polar."""
    return sk.gaussian_sketch(key, l, n, dtype=dtype).T


def rangefinder(M: jax.Array, l: int, key: jax.Array,
                cfg: Optional[PrismConfig] = None, method: str = "prism",
                power_iters: int = 1) -> jax.Array:
    """Sketched orthonormal range basis Q in R^{..., m, l} of M [..., m, n].

    Randomized rangefinder (Halko/Martinsson/Tropp): Y = M Omega captures
    the dominant column space; ``power_iters`` rounds of Y <- M (M^T Y)
    sharpen the capture to the (2q+1)-th power of the spectrum.  The
    orthonormalization is the engine's own NS polar (fitted when
    method="prism") instead of a LAPACK QR: batched, kernel-tiered, and
    exact on rank-deficient Y (zero singular values stay zero, yielding
    a partial isometry spanning range(M)).
    """
    cfg = PrismConfig() if cfg is None else cfg
    from repro.core import matfn

    n = M.shape[-1]
    Om = _gaussian_test_matrix(jax.random.fold_in(key, 0), n, l, M.dtype)
    Y = _mm(M, Om, cfg.use_kernels)
    Mt = jnp.swapaxes(M, -1, -2)
    for _ in range(power_iters):
        # per-slice fro rescale between products: keeps the power
        # iterates away from bf16 overflow without touching directions
        Y = Y / jnp.maximum(_fro(Y).astype(Y.dtype), 1e-30)
        Y = _mm(M, _mm(Mt, Y, cfg.use_kernels), cfg.use_kernels)
    return matfn.polar(Y, method=method, cfg=cfg,
                       key=jax.random.fold_in(key, 1))


def polar_lowrank(M: jax.Array, rank: int, oversample: int,
                  cfg: Optional[PrismConfig] = None,
                  key: Optional[jax.Array] = None, method: str = "prism",
                  power_iters: int = 1, return_iters: bool = False,
                  return_status: bool = False):
    """Rank-l orthogonalization O ~ U_l V_l^T of M [..., m, n] (§14).

    l = min(rank + oversample, min(m, n)).  Orientation-equivariant: a
    wide M is processed through its transpose (polar(M^T) = polar(M)^T),
    so the rangefinder always sketches the long side.  ``return_iters``
    surfaces the realized per-slice iteration count of the SUBSPACE
    fitted chain (the §11 telemetry consumers' contract — the
    rangefinder's auxiliary polar is not the certified product).
    """
    cfg = PrismConfig() if cfg is None else cfg
    from repro.core import matfn

    if key is None:
        key = jax.random.PRNGKey(0)
    transpose = M.shape[-2] < M.shape[-1]
    X = jnp.swapaxes(M, -1, -2) if transpose else M
    m, n = X.shape[-2], X.shape[-1]
    l = min(rank + oversample, n)
    Q = rangefinder(X, l, jax.random.fold_in(key, 0), cfg=cfg,
                    method=method, power_iters=power_iters)
    B = _mm(jnp.swapaxes(Q, -1, -2), X, cfg.use_kernels)  # [..., l, n]
    P = matfn.polar(B, method=method, cfg=cfg,
                    key=jax.random.fold_in(key, 1),
                    return_iters=return_iters,
                    return_status=return_status)
    if return_iters or return_status:
        P, *aux = P
    O = _mm(Q, P, cfg.use_kernels)
    O = jnp.swapaxes(O, -1, -2) if transpose else O
    if return_iters or return_status:
        return (O, *aux)
    return O


def svd_topk(M: jax.Array, k: int) -> jax.Array:
    """Oracle: exact top-k truncated orthogonalization U_k V_k^T (the
    target ``polar_lowrank`` approximates; tests/benchmarks only)."""
    U, _, Vt = jnp.linalg.svd(M.astype(jnp.float32), full_matrices=False)
    return (U[..., :, :k] @ Vt[..., :k, :]).astype(M.dtype)
