"""Randomized sketching primitives (PRISM Part II, step 5).

A Gaussian matrix S in R^{p x n} with i.i.d. N(0, 1/p) entries is an
oblivious subspace embedding; PRISM only needs the sketched power traces

    t_i = tr(S R^i S^T),  i = 0..max_power,

computed by the chained products V_i = R V_{i-1} with V_0 = S^T, so the
total cost is O(n^2 p max_power) — negligible next to the O(n^3) GEMMs of
one Newton-Schulz iteration.

Note: the paper's Theorem 2 types the entries as N(1, 1/p); the OSE
literature it cites (Balabanov & Nouy 2019, Prop. 3.7) uses mean-zero
N(0, 1/p), which is what we implement (see DESIGN.md §6).

All functions broadcast over leading batch dimensions of R
(R: [..., n, n], S: [p, n]) so the PRISM engine can run vmapped/stacked
over scanned-layer parameter stacks without per-matrix dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gaussian_sketch(key: jax.Array, p: int, n: int, dtype=jnp.float32) -> jax.Array:
    """S in R^{p x n} with i.i.d. N(0, 1/p) entries."""
    return jax.random.normal(key, (p, n), dtype=dtype) / jnp.sqrt(
        jnp.asarray(p, dtype=dtype))


def sketched_power_traces(R: jax.Array, S: jax.Array, max_power: int,
                          use_kernels: bool = False,
                          vmem_budget: int = 0) -> jax.Array:
    """t_i = tr(S R^i S^T) for i = 0..max_power.

    Args:
      R: residual matrix [..., n, n] (symmetric).
      S: sketch [p, n].
      max_power: largest power (4d+2 for Newton-Schulz degree d).
      use_kernels: route the chained R @ V products + trace epilogue through
        the Pallas ``sketch_traces`` kernel.
      vmem_budget: override (bytes) for the chain kernel's VMEM guard
        (DESIGN.md §10); 0 defers to REPRO_VMEM_BUDGET / the default.

    Returns: [..., max_power + 1] stacked traces (fp32).
    """
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.sketch_traces(R, S, max_power, budget=vmem_budget)
    # Accumulation semantics match the fused chain kernel (DESIGN.md §9):
    # each product R @ V accumulates fp32, the trace epilogue reduces the
    # fp32 accumulator (NOT the rounded V'), and only the V that feeds the
    # next power rounds back to the compute dtype.
    St = S.T.astype(R.dtype)  # [n, p]
    St32 = St.astype(jnp.float32)
    V = jnp.broadcast_to(St, R.shape[:-2] + St.shape)
    traces = [jnp.sum(St32 * St32)
              * jnp.ones(R.shape[:-2], dtype=jnp.float32)]
    for _ in range(max_power):
        Vacc = jnp.matmul(R, V, preferred_element_type=jnp.float32)
        # tr(S R^i S^T) = sum_{jk} S^T[j,k] * (R^i S^T)[j,k]
        traces.append(jnp.sum(St32 * Vacc, axis=(-2, -1)))
        V = Vacc.astype(R.dtype)
    return jnp.stack(traces, axis=-1)


def exact_power_traces(R: jax.Array, max_power: int) -> jax.Array:
    """Unsketched t_i = tr(R^i) (the paper's Eq. (3) objective); O(n^3).

    Used by tests and by the ``sketch_dim=0`` exact-fit mode.
    """
    n = R.shape[-1]
    eye = jnp.eye(n, dtype=R.dtype)
    P = jnp.broadcast_to(eye, R.shape)
    traces = [jnp.asarray(n, jnp.float32) * jnp.ones(R.shape[:-2], jnp.float32)]
    for _ in range(max_power):
        # fp32 accumulation + fp32 trace epilogue, powers rounded to the
        # compute dtype between steps (same policy as the sketched chain)
        Pacc = jnp.matmul(R, P, preferred_element_type=jnp.float32)
        traces.append(jnp.trace(Pacc, axis1=-2, axis2=-1))
        P = Pacc.astype(R.dtype)
    return jnp.stack(traces, axis=-1)
