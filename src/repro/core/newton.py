"""DB-Newton iteration for matrix square roots (paper App. A.2).

Product-form Denman-Beavers with PRISM acceleration.  The key structural
difference from Newton-Schulz: the alpha objective ||I - M_{k+1}||_F^2 has
*closed-form* coefficients computable in O(n^2) from entrywise sums of M
and M^{-1} — no sketching needed — and Newton for the square root is
globally convergent, so no interval constraint is required (we still clip
to a wide [0, 2] for numerical sanity; the classical alpha = 1/2 is
interior, so PRISM is never worse in Frobenius norm per iteration).

One Cholesky solve per iteration supplies M^{-1} (trailing-batch aware).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import IterInfo, _fro
from repro.core.polynomials import minimize_quartic


def _inv_spd(M: jax.Array) -> jax.Array:
    """M^{-1} for symmetric positive definite M via Cholesky."""
    L = jnp.linalg.cholesky(M)
    eye = jnp.broadcast_to(jnp.eye(M.shape[-1], dtype=M.dtype), M.shape)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    return jnp.swapaxes(Linv, -1, -2) @ Linv


def _tr(M):
    return jnp.trace(M, axis1=-2, axis2=-1).astype(jnp.float32)


def sqrtm(A: jax.Array, iters: int = 12, method: str = "prism",
          dtype=jnp.float32, alpha_bounds=(0.0, 2.0),
          return_info: bool = False):
    """(A^{1/2}, A^{-1/2}) for SPD A via (PRISM-)DB-Newton, product form.

      M_{k+1} = 2a(1-a) I + (1-a)^2 M_k + a^2 M_k^{-1}
      X_{k+1} = (1-a) X_k + a X_k M_k^{-1}
      Y_{k+1} = (1-a) Y_k + a Y_k M_k^{-1}

    with a = 1/2 classical ("newton") or the closed-form PRISM fit.
    """
    in_dtype = A.dtype
    c = _fro(A).astype(dtype)
    M = A.astype(dtype) / c  # normalize for conditioning (exact-arith no-op)
    X = M
    Y = jnp.broadcast_to(jnp.eye(M.shape[-1], dtype=dtype), M.shape)
    n = M.shape[-1]
    alphas, fros = [], []
    for _ in range(iters):
        Minv = _inv_spd(M)
        if method == "prism":
            # ||I - M_{k+1}||_F^2 = c0 + c1 a + c2 a^2 + c3 a^3 + c4 a^4
            # (paper App. A.2); traces of M^2, M^{-2} are entrywise sums.
            trI = jnp.asarray(float(n), jnp.float32)
            trM = _tr(M)
            trM2 = jnp.sum(jnp.square(M.astype(jnp.float32)), axis=(-2, -1))
            trMi = _tr(Minv)
            trMi2 = jnp.sum(jnp.square(Minv.astype(jnp.float32)), axis=(-2, -1))
            c0 = trI - 2 * trM + trM2
            c1 = -4 * trI + 8 * trM - 4 * trM2
            c2 = 10 * trI - 14 * trM + 6 * trM2 - 2 * trMi
            c3 = -12 * trI + 12 * trM - 4 * trM2 + 4 * trMi
            c4 = 6 * trI - 4 * trM + trM2 - 4 * trMi + trMi2
            coeffs = jnp.stack([c0, c1, c2, c3, c4], axis=-1)
            a = minimize_quartic(coeffs, *alpha_bounds)
        else:
            a = jnp.full(M.shape[:-2], 0.5, dtype=jnp.float32)
        if return_info:
            alphas.append(a)
            fros.append(_fro(jnp.eye(n, dtype=dtype) - M)[..., 0, 0])
        ab = a.astype(dtype)[..., None, None]
        X = (1 - ab) * X + ab * (X @ Minv)
        Y = (1 - ab) * Y + ab * (Y @ Minv)
        M = (2 * ab * (1 - ab)) * jnp.eye(n, dtype=dtype) \
            + jnp.square(1 - ab) * M + jnp.square(ab) * Minv
    sc = jnp.sqrt(c)
    out = (X * sc).astype(in_dtype), (Y / sc).astype(in_dtype)
    if return_info:
        return out, IterInfo(jnp.stack(alphas), jnp.stack(fros))
    return out
