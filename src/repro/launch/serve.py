"""Serving launcher CLI — batched decode against the production mesh.

  # single-stream batched decode (one prefill launch + greedy loop)
  python -m repro.launch.serve --arch qwen3-14b --smoke --batch 4 \
      --prompt_len 16 --gen_len 32
  python -m repro.launch.serve --arch mixtral-8x7b --mesh production \
      --cache_len 32768            # fleet mode (TPU)

  # continuous batching (DESIGN.md §16): slot-table engine driven by a
  # seeded Poisson trace at the offered QPS
  python -m repro.launch.serve --arch qwen3-14b --smoke --continuous \
      --qps 20 --slots 8 --requests 64

  # train -> serve handoff: restore params from a training checkpoint
  python -m repro.launch.serve --arch qwen3-14b --smoke --continuous \
      --ckpt_dir /tmp/run/ckpt

Prefill is ONE ``model.prefill_cache`` launch for the whole prompt
batch (the §16 flash-prefill path — the old launcher streamed the
prompt through ``prompt_len`` per-token decode steps and called that
"prefill"); compile time is reported separately so prefill tokens/s is
an honest steady-state number.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_params
from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.models.inputs import make_train_batch
from repro.serving import Engine, EngineConfig, make_serve_step, make_trace
from repro.sharding_ctx import activation_sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--gen_len", type=int, default=32)
    ap.add_argument("--cache_len", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=["none", "production"])
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--ckpt_dir", default="",
                    help="restore params from a training checkpoint "
                         "(train->serve handoff, §15/§16 integrity rules)")
    # continuous-batching engine mode
    ap.add_argument("--continuous", action="store_true",
                    help="slot-table continuous batching (§16)")
    ap.add_argument("--qps", type=float, default=20.0,
                    help="offered load for --continuous")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    cache_len = args.cache_len or (args.prompt_len + args.gen_len)

    def load_params():
        if args.ckpt_dir:
            step, params = restore_params(args.ckpt_dir,
                                          model.param_shapes())
            print(f"restored params from {args.ckpt_dir} step {step}")
            return params
        return model.init(key)

    if args.continuous:
        if args.mesh == "production":
            raise SystemExit("--continuous runs single-host for now; "
                             "drop --mesh production")
        _serve_continuous(model, cfg, load_params(), args)
        return

    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = sh.param_rules(cfg, mesh)
        pshapes = model.param_shapes()
        pshard = sh.tree_shardings(mesh, model.logical_axes(), rules,
                                   pshapes)
        ctx = activation_sharding(mesh, sh.activation_rules(cfg, mesh))
        with mesh, ctx:
            params = jax.jit(model.init, out_shardings=pshard)(key)
            serve_step = jax.jit(make_serve_step(model),
                                 donate_argnums=(1,))
            _loop(model, cfg, params, cache_len, serve_step, args, key)
    else:
        params = load_params()
        serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))
        _loop(model, cfg, params, cache_len, serve_step, args, key)


def _serve_continuous(model, cfg, params, args):
    cache_len = args.cache_len or 64
    eng = Engine(model, params, EngineConfig(
        slots=args.slots, cache_len=cache_len, greedy=True,
        eos_id=0, seed=args.seed))
    trace = make_trace(args.seed, n_requests=args.requests, qps=args.qps,
                       vocab_size=cfg.vocab_size)
    res = eng.run(trace)  # wall clock: offered-load mode
    lat = res.latency_percentiles()
    print(f"arch={cfg.name} slots={args.slots} qps={args.qps} "
          f"requests={args.requests}")
    print(f"completed={len(res.completions)} "
          f"tokens={res.generated_tokens} "
          f"tok/s={res.tokens_per_s:.1f} "
          f"p50={lat['p50'] * 1e3:.1f}ms p99={lat['p99'] * 1e3:.1f}ms "
          f"decode_shapes={res.decode_step_shapes} "
          f"prefill_launches={res.n_prefill_launches}")


def _loop(model, cfg, params, cache_len, serve_step, args, key):
    B = args.batch
    batch = make_train_batch(key, cfg, B, args.prompt_len)
    prompts = batch["tokens"]
    # vlm prompts carry a patch prefix: positions (and the cache) include
    # it, so decode starts after prompt + patches
    extra = batch["patches"].shape[1] if cfg.family == "vlm" else 0
    cache_len += extra
    start = args.prompt_len + extra

    # ---- prefill: ONE launch for the whole prompt batch; compile timed
    # separately so tokens/s reflects steady-state, not tracing
    prefill = jax.jit(
        lambda p, b: model.prefill_cache(p, b, cache_len))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    tok = nxt.reshape(prompts[..., :1].shape)
    t0 = time.perf_counter()
    for t in range(start, start + args.gen_len):
        pos = jnp.full((B, 1), t, jnp.int32)
        _, nxt, cache = serve_step(params, cache, tok, pos)
        tok = nxt.reshape(tok.shape)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    n_prompt = B * args.prompt_len
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen_len}")
    print(f"prefill {prefill_s * 1e3:.1f} ms (1 launch, "
          f"{n_prompt / prefill_s:.1f} tok/s; compile "
          f"{compile_s:.2f}s) | {decode_s / args.gen_len * 1e3:.1f} "
          f"ms/step | {B * args.gen_len / decode_s:.1f} tok/s")


if __name__ == "__main__":
    main()
