"""Serving launcher CLI — batched decode against the production mesh.

  python -m repro.launch.serve --arch qwen3-14b --smoke --batch 4 \
      --prompt_len 16 --gen_len 32
  python -m repro.launch.serve --arch mixtral-8x7b --mesh production \
      --cache_len 32768            # fleet mode (TPU)

Builds the same sharded serve_step the dry-run lowers for the decode
cells: params + rolling KV/state cache sharded per launch/sharding.py,
greedy sampling, tokens/s accounting.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.models.inputs import make_train_batch
from repro.serving import make_serve_step
from repro.sharding_ctx import activation_sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--gen_len", type=int, default=32)
    ap.add_argument("--cache_len", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=["none", "production"])
    ap.add_argument("--multi_pod", action="store_true")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    B = args.batch
    cache_len = args.cache_len or (args.prompt_len + args.gen_len)

    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = sh.param_rules(cfg, mesh)
        pshapes = model.param_shapes()
        pshard = sh.tree_shardings(mesh, model.logical_axes(), rules,
                                   pshapes)
        ctx = activation_sharding(mesh, sh.activation_rules(cfg, mesh))
        with mesh, ctx:
            params = jax.jit(model.init, out_shardings=pshard)(key)
            cache = model.init_cache(B, cache_len)
            cshard = sh.cache_shardings(mesh, cfg, cache, B)
            cache = jax.device_put(cache, cshard)
            serve_step = jax.jit(make_serve_step(model),
                                 donate_argnums=(1,))
            _loop(model, cfg, params, cache, serve_step, args, key)
    else:
        params = model.init(key)
        cache = model.init_cache(B, cache_len)
        serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))
        _loop(model, cfg, params, cache, serve_step, args, key)


def _loop(model, cfg, params, cache, serve_step, args, key):
    B = args.batch
    prompts = make_train_batch(key, cfg, B, args.prompt_len)["tokens"]
    nxt = None
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        tok = prompts[..., t:t + 1]
        pos = jnp.full((B, 1), t, jnp.int32)
        _, nxt, cache = serve_step(params, cache, tok, pos)
    jax.block_until_ready(nxt)
    prefill_s = time.perf_counter() - t0
    tok = nxt.reshape(prompts[..., :1].shape)
    t0 = time.perf_counter()
    for t in range(args.prompt_len, args.prompt_len + args.gen_len):
        pos = jnp.full((B, 1), t, jnp.int32)
        _, nxt, cache = serve_step(params, cache, tok, pos)
        tok = nxt.reshape(tok.shape)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen_len}")
    print(f"prompt streaming {prefill_s:.2f}s | "
          f"{decode_s / args.gen_len * 1e3:.1f} ms/step | "
          f"{B * args.gen_len / decode_s:.1f} tok/s")


if __name__ == "__main__":
    main()
