import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate step function is lowered with production
shardings and compiled; we record memory_analysis, cost_analysis (FLOPs /
bytes), and the post-SPMD collective inventory for §Dry-run / §Roofline.

  train_4k     -> train_step  (fwd + bwd + Muon/PRISM update)
  prefill_32k  -> prefill_step (backbone + last-token logits)
  decode_32k / long_500k -> serve_step (1 token vs seq_len state)

long_500k only lowers for sub-quadratic archs (SSM / hybrid / SWA); pure
full-attention archs are skipped by design (DESIGN.md §5).

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi_pod] [--out results/dryrun]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis import hlo as hlo_lib  # noqa: E402
from repro.analysis import roofline as rl  # noqa: E402
from repro.config import (SHAPES, OptimizerConfig, PrismConfig,  # noqa: E402
                          ShapeConfig)
from repro.configs import arch_ids, get_config  # noqa: E402
from repro.launch import sharding as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build  # noqa: E402
from repro.models.inputs import decode_token_specs, train_batch_specs  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402
from repro.serving.decode import make_prefill_step, make_serve_step  # noqa: E402
from repro.sharding_ctx import activation_sharding  # noqa: E402
from repro.train.state import (make_train_step, opt_state_shardings)  # noqa: E402

OCFG = OptimizerConfig(
    name="muon", learning_rate=2e-2,
    matfn_method="prism",
    prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=1,
                      sketch_dim=8))

# §Perf knobs (paper-faithful baseline = all defaults)
STRATEGY = "tp"              # "tp" | "zero"
GRADS_DTYPE = "float32"      # "float32" | "bfloat16"
MUON_LOCAL_RESHARD = False


def runnable(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def count_params(shapes_tree) -> float:
    return float(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes_tree)))


def active_params(cfg, n_params: float) -> float:
    """Approximate active parameters for MoE archs (MODEL_FLOPS basis)."""
    if cfg.moe is None:
        return n_params
    m = cfg.moe
    # expert FFN params scale by k/E; everything else is dense
    f_expert = 3 * cfg.d_model * cfg.d_ff * m.num_experts * cfg.num_layers
    dense = n_params - f_expert
    return dense + f_expert * m.num_experts_per_tok / m.num_experts


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               strategy: str = None, ocfg: OptimizerConfig = None,
               loss_chunk: int = 0, moe_dispatch: str = None):
    strategy = strategy or STRATEGY
    ocfg = ocfg or OCFG
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    cfg = get_config(arch)
    if loss_chunk:
        cfg = cfg.replace(loss_chunk=loss_chunk)
    if moe_dispatch and cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SHAPES[shape_name]
    model = build(cfg)
    # "zero" strategy is a train-cell optimization; serving keeps TP.
    # It also requires global_batch >= chips (pure DP): on the multi-pod
    # mesh with batch 256 < 512 chips the model axis would idle, so fall
    # back to the TP baseline there (EXPERIMENTS.md §Perf scope note).
    if shape.kind == "train":
        cell_strategy = "tp" if strategy == "serve" else strategy
    else:
        # serving cells: "serve" (TP + data-replicated params) is the
        # decode optimization; anything else keeps the TP baseline
        cell_strategy = "serve" if strategy == "serve" else "tp"
    if cell_strategy == "zero" and shape.global_batch < chips:
        cell_strategy = "tp"
    rules = sh.param_rules(cfg, mesh, cell_strategy)
    axes = model.logical_axes()
    pshapes = model.param_shapes()
    pshard = sh.tree_shardings(mesh, axes, rules, pshapes)
    n_params = count_params(pshapes)

    act_rules = sh.activation_rules(cfg, mesh, cell_strategy)
    with mesh, activation_sharding(mesh, act_rules):
        if shape.kind == "train":
            master = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                pshapes)
            opt = make_optimizer(ocfg, axes)
            sshapes = jax.eval_shape(opt.init, master)
            sshard = opt_state_shardings(mesh, opt, master, pshard)
            bspecs = train_batch_specs(cfg, shape)
            bshard = sh.train_batch_shardings(mesh, cfg)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            train_step = make_train_step(model, opt, ocfg)
            lowered = jax.jit(
                train_step,
                in_shardings=(pshard, sshard, bshard, None),
                out_shardings=(pshard, sshard, None),
                donate_argnums=(0, 1),
            ).lower(master, sshapes, bspecs, step_spec)
            tokens_per_step = shape.global_batch * shape.seq_len
            mf = rl.model_flops(n_params, tokens_per_step, "train",
                                active_params(cfg, n_params))
        elif shape.kind == "prefill":
            bspecs = train_batch_specs(cfg, shape)
            bshard = sh.train_batch_shardings(mesh, cfg)
            prefill_step = make_prefill_step(model)
            lowered = jax.jit(
                prefill_step, in_shardings=(pshard, bshard),
            ).lower(pshapes, bspecs)
            tokens_per_step = shape.global_batch * shape.seq_len
            mf = rl.model_flops(n_params, tokens_per_step, "prefill",
                                active_params(cfg, n_params))
        else:  # decode
            B = shape.global_batch
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(B, shape.seq_len))
            cshard = sh.cache_shardings(mesh, cfg, cache_shapes, B)
            tspecs = decode_token_specs(cfg, B)
            tshard = sh.decode_input_shardings(mesh, cfg, B)
            serve_step = make_serve_step(model)
            lowered = jax.jit(
                serve_step,
                in_shardings=(pshard, cshard, tshard["tokens"],
                              tshard["pos"]),
                donate_argnums=(1,),
            ).lower(pshapes, cache_shapes, tspecs["tokens"], tspecs["pos"])
            tokens_per_step = B
            mf = rl.model_flops(n_params, tokens_per_step, "decode",
                                active_params(cfg, n_params))
    return lowered, mesh, chips, n_params, mf


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False, strategy: str = None,
             ocfg: OptimizerConfig = None, loss_chunk: int = 0,
             moe_dispatch: str = None):
    t0 = time.time()
    lowered, mesh, chips, n_params, model_fl = lower_cell(
        arch, shape_name, multi_pod, strategy=strategy, ocfg=ocfg,
        loss_chunk=loss_chunk, moe_dispatch=moe_dispatch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    # loop-aware analysis: xla's cost_analysis counts while bodies once
    # (under-reporting scanned-layer graphs by ~num_layers); analyze_module
    # re-derives flops/bytes/collectives with trip-count multiplicity.
    mod = hlo_lib.analyze_module(hlo_text)
    coll = {"wire_bytes_per_chip": mod["wire_bytes_per_chip"],
            "bytes_by_kind": mod["bytes_by_kind"],
            "count_by_kind": mod["count_by_kind"]}
    roof = rl.Roofline(
        flops_per_chip=mod["flops"],
        hbm_bytes_per_chip=mod["hbm_bytes"],
        wire_bytes_per_chip=mod["wire_bytes_per_chip"],
        model_flops_global=model_fl,
        chips=chips,
    )
    flops = mod["flops"]
    bytes_accessed = mod["hbm_bytes"]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "n_params": n_params,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": {"flops": flops, "bytes_accessed": bytes_accessed,
                 "bytes_accessed_upper": mod.get("hbm_bytes_upper"),
                 "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
                 "transcendentals": float(ca.get("transcendentals", 0.0))},
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        },
        "collectives": coll,
        "roofline": roof.as_dict(),
    }
    if keep_hlo:
        rec["hlo_len"] = len(hlo_text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both_meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--strategy", default=None, choices=["tp", "zero", "serve"])
    ap.add_argument("--grads_dtype", default=None,
                    choices=["float32", "bfloat16"])
    ap.add_argument("--muon_local_reshard", action="store_true")
    ap.add_argument("--loss_chunk", type=int, default=0)
    ap.add_argument("--moe_dispatch", default=None,
                    choices=["global", "per_sample"])
    args = ap.parse_args()

    cells = []
    archs = arch_ids() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shp in shapes:
            if not runnable(arch, shp):
                continue
            for mp in meshes:
                cells.append((arch, shp, mp))

    os.makedirs(args.out, exist_ok=True)
    n_ok = 0
    for arch, shp, mp in cells:
        tag = f"{arch}__{shp}__{'mp' if mp else 'sp'}"
        try:
            import dataclasses
            ocfg = OCFG
            if args.grads_dtype or args.muon_local_reshard:
                ocfg = dataclasses.replace(
                    OCFG,
                    grads_dtype=args.grads_dtype or OCFG.grads_dtype,
                    muon_local_reshard=args.muon_local_reshard)
            rec = run_cell(arch, shp, mp, strategy=args.strategy,
                           ocfg=ocfg, loss_chunk=args.loss_chunk,
                           moe_dispatch=args.moe_dispatch)
            n_ok += 1
            status = "OK"
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {"arch": arch, "shape": shp,
                   "mesh": "2x16x16" if mp else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            status = "FAIL"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        r = rec.get("roofline", {})
        print(f"[{status}] {tag} compile={rec.get('compile_s', '-')}s "
              f"dominant={r.get('dominant', '-')} "
              f"roofline={r.get('roofline_fraction', 0):.3f}",
              flush=True)
    print(f"done: {n_ok}/{len(cells)} cells OK")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
