"""Training launcher CLI.

  python -m repro.launch.train --arch gpt2-paper --steps 100 \
      --optimizer muon --method prism --seq 512 --batch 8

On a real TPU fleet the same entry point builds the production mesh
(--mesh production [--multi_pod]) and shards params/optimizer/batch with
the rules in launch/sharding.py; on this CPU container the default
--mesh none runs single-device (smoke/bench scale).
"""
from __future__ import annotations

import argparse

import jax

from repro.config import OptimizerConfig, PrismConfig, TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.sharding_ctx import activation_sharding
from repro.train import Trainer
from repro.train.state import opt_state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--optimizer", default="muon",
                    choices=["muon", "shampoo", "adamw"])
    ap.add_argument("--method", default="prism",
                    choices=["prism", "polar_express", "newton_schulz",
                             "eigh"])
    ap.add_argument("--lr", type=float, default=6e-3)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "production"])
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--ckpt_dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--precond_every", type=int, default=1,
                    help="staleness period K: refresh matrix "
                         "preconditioners every K steps (DESIGN.md §8)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = build(cfg)
    ocfg = OptimizerConfig(
        name=args.optimizer, learning_rate=args.lr,
        matfn_method=args.method, gradient_compression=args.compression,
        precond_every=args.precond_every,
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=3,
                          sketch_dim=8))
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every, log_every=10)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = sh.param_rules(cfg, mesh)
        pshapes = model.param_shapes()
        import jax.numpy as jnp
        master = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
        pshard = sh.tree_shardings(mesh, model.logical_axes(), rules,
                                   pshapes)
        from repro.optim import make_optimizer
        opt = make_optimizer(ocfg, model.logical_axes())
        sshard = opt_state_shardings(mesh, opt, master, pshard)
        shardings = {"params": pshard, "opt": sshard,
                     "batch": sh.train_batch_shardings(mesh, cfg)}
        with mesh, activation_sharding(mesh,
                                       sh.activation_rules(cfg, mesh)):
            trainer = Trainer(model, ocfg, tcfg, dcfg, mesh, shardings)
            trainer.run(seed=args.seed)
    else:
        trainer = Trainer(model, ocfg, tcfg, dcfg)
        trainer.run(seed=args.seed)


if __name__ == "__main__":
    main()
