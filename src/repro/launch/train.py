"""Training launcher CLI.

  python -m repro.launch.train --arch gpt2-paper --steps 100 \
      --optimizer muon --method prism --seq 512 --batch 8

On a real TPU fleet the same entry point builds the production mesh
(--mesh production [--multi_pod]) and shards params/optimizer/batch with
the rules in launch/sharding.py; on this CPU container the default
--mesh none runs single-device (smoke/bench scale).
"""
from __future__ import annotations

import argparse

import jax

from repro.config import OptimizerConfig, PrismConfig, TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.sharding_ctx import activation_sharding
from repro.train import Trainer
from repro.train.state import opt_state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--optimizer", default="muon",
                    choices=["muon", "shampoo", "adamw"])
    ap.add_argument("--method", default="prism",
                    choices=["prism", "polar_express", "newton_schulz",
                             "eigh"])
    ap.add_argument("--lr", type=float, default=6e-3)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "production"])
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--ckpt_dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--precond_every", type=int, default=1,
                    help="staleness period K: refresh matrix "
                         "preconditioners every K steps (DESIGN.md §8)")
    ap.add_argument("--precond_async", action="store_true",
                    help="drive refreshes from the host-side async "
                         "service (§12) — in pipeline runs the chains "
                         "land in the 1F1B bubbles")
    ap.add_argument("--pipeline_stages", type=int, default=1,
                    help="1F1B pipeline depth over the pod mesh axis "
                         "(DESIGN.md §13); >1 requires --mesh "
                         "production --multi_pod")
    ap.add_argument("--n_micro", type=int, default=4,
                    help="microbatches per step for the 1F1B schedule")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = build(cfg)
    ocfg = OptimizerConfig(
        name=args.optimizer, learning_rate=args.lr,
        matfn_method=args.method, gradient_compression=args.compression,
        precond_every=args.precond_every,
        precond_async=args.precond_async,
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=3,
                          sketch_dim=8))
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every, log_every=10,
                       pipeline_stages=args.pipeline_stages,
                       n_micro=args.n_micro)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    pipelined = args.pipeline_stages > 1
    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod or pipelined)
        rules = sh.param_rules(cfg, mesh)
        arules = sh.activation_rules(cfg, mesh)
        if pipelined:
            # 1F1B over pod (DESIGN.md §13): layer stack stage-sliced,
            # batch sharded over data only, async refreshes land in the
            # schedule bubbles
            assert mesh.shape.get("pod", 1) == args.pipeline_stages, \
                (dict(mesh.shape), args.pipeline_stages)
            rules = sh.pipeline_rules(rules)
            arules = sh.pipeline_rules(arules)
        pshapes = model.param_shapes()
        import jax.numpy as jnp
        master = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
        pshard = sh.tree_shardings(mesh, model.logical_axes(), rules,
                                   pshapes)
        from repro.optim import make_optimizer
        opt = make_optimizer(ocfg, model.logical_axes())
        sshard = opt_state_shardings(mesh, opt, master, pshard)
        shardings = {"params": pshard, "opt": sshard,
                     "batch": sh.train_batch_shardings(
                         mesh, cfg, pipeline=pipelined)}
        with mesh, activation_sharding(mesh, arules):
            trainer = Trainer(model, ocfg, tcfg, dcfg, mesh, shardings)
            trainer.run(seed=args.seed)
    else:
        assert not pipelined, "--pipeline_stages needs --mesh production"
        trainer = Trainer(model, ocfg, tcfg, dcfg)
        trainer.run(seed=args.seed)


if __name__ == "__main__":
    main()
