"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter/state/input dimension carries a logical name (see the
models' ``logical_axes``); this module maps those names to mesh axes:

  embed      -> data            (FSDP / ZeRO-3: params gathered on use)
  vocab/mlp/heads/expert_mlp -> model   (tensor parallelism)
  experts    -> model for EP archs (num_experts % model == 0), else None
  batch      -> (pod, data)     (data parallelism; pod axis is pure DP)
  kv_seq     -> model           (decode KV caches; (data, model) when the
                                 cell's batch=1, e.g. long_500k)
  layers / head_dim / codebooks -> never sharded

Dimensions that do not divide the mesh axis are padded by GSPMD (legal,
slightly wasteful — flagged in EXPERIMENTS.md where it matters).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig, ShapeConfig


def param_rules(cfg: ModelConfig, mesh: Mesh,
                strategy: str = "tp") -> Dict[str, Any]:
    """Parameter sharding rules.

    strategy="tp"   (baseline): FSDP over data x tensor-parallel over model
                    — activations all-reduce every layer (1D Megatron TP).
    strategy="zero" (beyond-paper, single-pod train cells): pure ZeRO-3 —
                    params flat-sharded over (data, model), batch over the
                    whole mesh, NO TP activation all-reduces; weights move
                    (all-gather on use) instead of activations.  Wins when
                    tokens_per_chip * d_model >> params_per_layer, which
                    holds for every train_4k cell (see EXPERIMENTS.md §Perf).
    """
    expert_ep = (cfg.moe is not None and cfg.moe.sharding == "expert")
    if strategy == "serve":
        # decode is latency-bound: FSDP weight gathers per token dominate
        # the step (measured: 1.3s/token of the 1.755s collective term on
        # command-r decode_32k).  Replicate params across data, shard over
        # model only — zero weight movement per step; params bf16 / 16-way
        # TP fit HBM for every assigned arch (command-r: 4.4 GB/chip).
        base = param_rules(cfg, mesh, "tp")
        base["embed"] = None
        return base
    if strategy == "zero":
        flat = tuple(mesh.axis_names)  # ("data","model") / ("pod",...)
        return {
            "embed": flat,
            "vocab": None,
            "mlp": None,
            # expert weights keep 2D EP/TP sharding: under pure ZeRO their
            # contraction dim is sharded and every expert einsum psums an
            # activation-sized tensor (measured: +21s on mixtral train).
            # Axis dedup in constrain_spec turns embed (data, model) into
            # (data,) for these tensors.
            "expert_mlp": None if expert_ep else "model",
            "experts": "model" if expert_ep else None,
            "heads": None,
            "kv_heads": None,
            "head_dim": None,
            "layers": None,
            "codebooks": None,
            None: None,
        }
    model_ax = "model"
    return {
        "embed": "data",
        "vocab": model_ax,
        "mlp": model_ax,
        "expert_mlp": None if expert_ep else model_ax,
        "experts": model_ax if expert_ep else None,
        "heads": model_ax,
        "kv_heads": None,   # kv heads < model axis on every GQA arch
        "head_dim": None,
        "layers": None,
        "codebooks": None,
        None: None,
    }


def spec_from_axes(axes: Tuple, rules: Dict[str, Any]) -> P:
    return P(*[rules.get(a, None) for a in axes])


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def constrain_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Make a spec legal for jit in_shardings:
    * drop mesh axes from dims they do not divide (granite's vocab
      49155 % 16 != 0 — production frameworks pad instead, DESIGN.md §4);
    * dedup mesh axes used by more than one dim (keep the later, more
      specific rule: e.g. expert tensors under the zero strategy keep
      expert_mlp->model and reduce embed (data, model) to (data,))."""
    entries = list(tuple(spec)) + [None] * (len(shape) - len(spec))
    # dedup from the right: later (more specific) dims keep their axes
    used: set = set()
    for i in range(len(entries) - 1, -1, -1):
        e = entries[i]
        if e is None:
            continue
        axes = list(e) if isinstance(e, (tuple, list)) else [e]
        kept = [a for a in axes if a not in used]
        used.update(kept)
        entries[i] = tuple(kept) if len(kept) > 1 else \
            (kept[0] if kept else None)
    out = []
    for dim, entry in zip(shape, entries):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def tree_shardings(mesh: Mesh, axes_tree, rules, shapes_tree=None) -> Any:
    def one(axes, shape=None):
        spec = spec_from_axes(axes, rules)
        if shape is not None:
            spec = constrain_spec(mesh, spec, tuple(shape.shape))
        return NamedSharding(mesh, spec)

    is_axes = lambda t: isinstance(t, tuple) and \
        all(isinstance(x, (str, type(None))) for x in t)
    if shapes_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=is_axes)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)


def batch_axes(mesh: Mesh) -> Tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def activation_rules(cfg: ModelConfig, mesh: Mesh,
                     strategy: str = "tp") -> Dict[str, Any]:
    """Logical-axis rules for with_sharding_constraint annotations
    (installed via repro.sharding_ctx.activation_sharding)."""
    expert_ep = (cfg.moe is not None and cfg.moe.sharding == "expert")
    if strategy == "serve":
        return activation_rules(cfg, mesh, "tp")
    if strategy == "zero":
        flat = tuple(mesh.axis_names)
        return {
            "batch": flat, "seq": None, "embed": None, "embed_act": None,
            "heads": None, "kv_heads": None, "head_dim": None, "mlp": None,
            "expert_mlp": None if expert_ep else "model",
            "experts": "model" if expert_ep else None, "vocab": None,
            "kv_seq": "model",
            # Muon local-reshard targets (iteration 3 of §Perf); the
            # §14 sketch dim l of lowrank bases is never sharded (the
            # subspace NS chain runs its Gram products on it)
            "opt_layers": "model", "opt_rows": "data", "opt_basis": None,
        }
    return {
        "batch": batch_axes(mesh),
        "seq": None,
        "embed": None,          # activations 1D-TP: embed stays local
        "embed_act": None,
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "mlp": "model",
        "expert_mlp": None if expert_ep else "model",
        "experts": "model" if expert_ep else None,
        "vocab": "model",
        "kv_seq": "model",
        "opt_layers": "model", "opt_rows": "data", "opt_basis": None,
    }


def pipeline_rules(rules: Dict[str, Any]) -> Dict[str, Any]:
    """Adapt a param/activation rules dict for 1F1B pipelining over "pod"
    (launch/pipeline.py):

    * ``layers`` shards over pod — each pod holds exactly its stage's
      slice of every [L, ...] stacked leaf, so the engine's [S, L/S, ...]
      stage view is a layout-preserving reshape (no resharding);
    * ``batch`` drops the pod axis — every stage needs all microbatch
      tokens (stage 0 embeds them, the last stage reads targets), so DP
      runs over data only;
    * ``opt_batch`` pins the §8 preconditioner bucket partitioning to the
      remaining (data,) axis (optim/bucketing.py::mesh_batch_axes) — pod
      is a pipeline axis now, not a DP axis.
    """
    out = dict(rules)
    if "layers" in out:
        out["layers"] = "pod"
    if "batch" in out:
        b = out["batch"]
        b = tuple(a for a in (b if isinstance(b, (tuple, list)) else (b,))
                  if a not in (None, "pod"))
        out["batch"] = b if b else None
    out["opt_batch"] = ("data",)
    return out


def train_batch_shardings(mesh: Mesh, cfg: ModelConfig,
                          pipeline: bool = False):
    b = ("data",) if pipeline else batch_axes(mesh)
    out = {"tokens": NamedSharding(mesh, P(b, None, None))
           if cfg.family == "audio" else NamedSharding(mesh, P(b, None))}
    if cfg.family == "vlm":
        out["patches"] = NamedSharding(mesh, P(b, None, None))
    return out


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache, batch_size: int):
    """Shardings for the decode cache pytree, by leaf ndim/role."""
    b = batch_axes(mesh)
    kv_seq = ("data", "model") if batch_size == 1 else "model"
    bax = None if batch_size == 1 else b

    def one(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        if "kpos" in names:
            spec = P(bax, kv_seq)
        elif "k" in names or "v" in names:
            # [* lead, batch, kv_seq, kv_heads, head_dim]
            lead = leaf.ndim - 4
            spec = P(*([None] * lead), bax, kv_seq, None, None)
        elif "ssm" in names and leaf.ndim == 4:  # [L, B, d_inner, state]
            spec = P(None, bax, "model", None)
        elif "conv" in names:  # [L?, B, dc-1, d_inner]
            lead = leaf.ndim - 3
            spec = P(*([None] * lead), bax, None, "model")
        elif "h" in names:  # rglru state [P?, B, width]
            lead = leaf.ndim - 2
            spec = P(*([None] * lead), bax, "model")
        else:
            spec = P(*([None] * leaf.ndim))
        return NamedSharding(mesh, constrain_spec(mesh, spec,
                                                  tuple(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, cache)


def decode_input_shardings(mesh: Mesh, cfg: ModelConfig, batch_size: int):
    b = None if batch_size == 1 else batch_axes(mesh)
    tok = NamedSharding(mesh, P(b, None, None)) if cfg.family == "audio" \
        else NamedSharding(mesh, P(b, None))
    return {"tokens": tok, "pos": NamedSharding(mesh, P(b, None))}


#: optimizer-state keys that hold cached preconditioner buffers with a
#: non-param layout — the active caches AND their §12 pending twins.
#: Twins shard identically to their active halves (same shape, same
#: spec), so the double-buffer swap lowers to a local per-shard select:
#: no resharding collective on the swap step.
PRECOND_CACHE_STATE_KEYS = frozenset({
    "ortho", "Linv", "Rinv", "ortho_p", "Linv_p", "Rinv_p",
})


def precond_cache_sharding(mesh: Mesh, shape: Tuple[int, ...]):
    """Sharding for cached preconditioner buffers in the optimizer state
    (Muon "ortho" matrix views [..lead.., m, n], Shampoo "Linv"/"Rinv"
    inverse roots [..lead.., n, n], and their pending "*_p" twins under
    the §12 async refresh plane) whose layout differs from the param
    (transposed/flattened views, factor squares).

    Layout mirrors the muon_local_reshard rule (DESIGN.md §4): the leading
    scanned-layer dim over model, the row dim over data — so a staleness
    cache adds O(bytes / mesh) per device instead of O(bytes), and a
    refresh step's all-gathered bucket scatters straight into the shards.
    constrain_spec drops axes from dims they don't divide, so any shape
    stays legal on any mesh.

    The spec is dtype-independent: bf16 cache storage
    (OptimizerConfig.precond_cache_dtype, DESIGN.md §9) halves the bytes
    under the SAME partitioning — the two savings compose.
    """
    entries: list = [None] * len(shape)
    if len(shape) >= 3 and "model" in mesh.axis_names:
        entries[0] = "model"
    if len(shape) >= 2 and "data" in mesh.axis_names:
        entries[-2] = "data"
    return NamedSharding(mesh, constrain_spec(mesh, P(*entries), shape))


def lowrank_basis_sharding(mesh: Mesh, shape: Tuple[int, ...]):
    """Sharding for §14 rangefinder bases Q [..lead.., m, l] (and the
    subspace factors B/P [..lead.., l, n] by symmetry of the rule).

    batch spec: the scanned-layer lead dim goes over model — same layout
    as precond_cache_sharding, so the lift Q @ polar(B) and the cache
    scatter of its result stay collective-free along the lead dim.

    basis spec: the long side m goes over data (each shard holds its
    row-slice of the basis; the NS orthonormalization's [l, l] Gram
    psums over data, l**2 words — negligible next to the O(m l) basis);
    the sketch dim l is NEVER sharded — every Gram product, alpha fit
    and residual certificate of the subspace chain contracts over it.

    constrain_spec keeps any shape legal on any mesh (drops non-dividing
    axes), mirroring the precond cache rule.
    """
    entries: list = [None] * len(shape)
    if len(shape) >= 3 and "model" in mesh.axis_names:
        entries[0] = "model"
    if len(shape) >= 2 and "data" in mesh.axis_names:
        entries[-2] = "data"
    return NamedSharding(mesh, constrain_spec(mesh, P(*entries), shape))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def shardings_like(mesh: Mesh, shapes_tree, ref_shardings_tree):
    """Broadcast a reference sharding tree (params) onto a same-structure
    state tree; scalars/rank-0 leaves are replicated."""

    def one(shape, sh):
        if not shape.shape:
            return replicated(mesh)
        return sh

    return jax.tree.map(one, shapes_tree, ref_shardings_tree)
