"""GPipe-style pipeline parallelism over a mesh axis (opt-in).

``pipeline_apply`` runs a stack of identical stages (each owning an equal
slice of the layer stack) over a mesh axis — on the production mesh the
"pod" axis, so each pod holds half the layers and activations stream
between pods via collective_permute, replacing cross-pod parameter
replication with a fill-drain microbatch schedule.

Schedule: classic GPipe forward, T = n_micro + n_stages - 1 ticks; stage s
processes microbatch (t - s) at tick t.  The wrapper runs inside
``jax.shard_map`` over the pipeline axis; everything else (data/tensor
sharding inside a stage) composes via the remaining mesh axes left in
"auto" mode.

This is the forward path (inference / activation-streaming); training
integration (1F1B with backward interleave) is left as the documented
extension point.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe_forward(stage_fn: Callable, axis: str, n_stages: int,
                  n_micro: int):
    """Build the per-device pipelined forward.

    stage_fn(stage_params, x) -> y : one stage's computation; x/y share
    shape [micro_batch, ...].

    Returns fn(stage_params_local, x_micro [n_micro, mb, ...]) -> y
    (valid on the LAST stage; other stages return zeros) to be used
    inside shard_map with the stage dim of params mapped over ``axis``.
    """

    def run(params_local, x_micro):
        # shard_map keeps the sharded stage dim with local size 1: drop it
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        T = n_micro + n_stages - 1
        mb_shape = x_micro.shape[1:]

        def tick(carry, t):
            buf, outs = carry
            mb = t - stage
            active = jnp.logical_and(mb >= 0, mb < n_micro)
            # stage 0 reads its own microbatch; later stages read the
            # activation handed over by the previous stage
            x0 = jax.lax.dynamic_index_in_dim(
                x_micro, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            x_in = jnp.where(stage == 0, x0, buf)
            y = stage_fn(params_local, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # hand over to the next stage
            buf_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage records its finished microbatch
            is_last = stage == n_stages - 1
            outs = jax.lax.cond(
                jnp.logical_and(active, is_last),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb, 0, n_micro - 1), 0),
                lambda o: o, outs)
            return (buf_next, outs), None

        buf0 = jnp.zeros(mb_shape, x_micro.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x_micro.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        return outs

    return run


def pipeline_apply(mesh, stage_fn: Callable, stage_params, x,
                   n_micro: int, axis: str = "pod"):
    """Run x [B, ...] through n_stages pipelined stages over ``axis``.

    stage_params: pytree with a leading stage dimension == mesh.shape[axis]
    on every leaf.  Returns y [B, ...] (gathered from the last stage).
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])
    run = gpipe_forward(stage_fn, axis, n_stages, n_micro)
    from repro.sharding_ctx import compat_shard_map

    mapped = compat_shard_map(
        run, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis),  # per-stage outputs stacked; last stage valid
        axis_names={axis})  # other mesh axes stay "auto"
    outs = mapped(stage_params, x_micro)
    # outs [n_stages * n_micro, mb, ...]: only the last stage's block is
    # the real output (earlier stages contributed zeros)
    outs = outs.reshape((n_stages, n_micro, mb) + x.shape[1:])
    return outs[-1].reshape((B,) + x.shape[1:])
