"""Pipeline parallelism over a mesh axis: GPipe forward + 1F1B training.

``pipeline_apply`` runs a stack of identical stages (each owning an equal
slice of the layer stack) over a mesh axis — on the production mesh the
"pod" axis, so each pod holds a contiguous layer slice and activations
stream between pods via collective_permute, replacing cross-pod parameter
replication with a fill-drain microbatch schedule.

Forward schedule (GPipe): T = n_micro + n_stages - 1 ticks; stage s
processes microbatch (t - s) at tick t.

Training schedule (1F1B, ``one_f_one_b``): backward interleaves into the
same tick scan.  For microbatch m on stage s (S stages, M microbatches):

    forward  tick:  m + s
    backward tick:  m + 2*(S-1) - s
    total ticks  :  T = M + 2*(S-1)            (``n_ticks_1f1b``)

so the last stage's backward of microbatch m lands one tick pattern that
interleaves 1 forward with 1 backward in steady state; the fill+drain
bubble is the 2*(S-1) tick overhead (``bubble_fraction`` = 2*(S-1)/T,
strictly decreasing in M).  Activations hand over s→s+1 and gradients
s→s-1 via ``lax.ppermute`` at the end of every tick.  Each stage stashes
only its *inputs* (one [M, mb, ...] buffer, written in place so XLA
aliases it across the scan — the donated microbatch buffer); the
backward re-runs the stage forward under ``jax.vjp`` (rematerialization),
so fill/drain never holds more than the input stash.

Both wrappers run inside ``shard_map`` manual over the pipeline axis;
everything else (data/tensor sharding inside a stage) composes via the
remaining mesh axes left in "auto" mode.  Stage bodies contain no
collectives, so gating them under ``lax.cond`` with a device-varying
(fill/drain) predicate is legal and skips the wasted compute.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding_ctx import compat_shard_map, suspend_activation_sharding


def n_ticks_1f1b(n_stages: int, n_micro: int) -> int:
    """Ticks in one 1F1B step: n_micro + fill + drain."""
    return n_micro + 2 * (n_stages - 1)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Fraction of ticks a stage idles (fill+drain) under 1F1B."""
    return 2.0 * (n_stages - 1) / n_ticks_1f1b(n_stages, n_micro)


def gpipe_forward(stage_fn: Callable, axis: str, n_stages: int,
                  n_micro: int):
    """Build the per-device pipelined forward.

    stage_fn(stage_params, x) -> y : one stage's computation; x/y share
    shape [micro_batch, ...].

    Returns fn(stage_params_local, x_micro [n_micro, mb, ...]) -> y
    (valid on the LAST stage; other stages return zeros) to be used
    inside shard_map with the stage dim of params mapped over ``axis``.
    """

    def run(params_local, x_micro):
        # shard_map keeps the sharded stage dim with local size 1: drop it
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        T = n_micro + n_stages - 1
        mb_shape = x_micro.shape[1:]

        def tick(carry, t):
            buf, outs = carry
            mb = t - stage
            active = jnp.logical_and(mb >= 0, mb < n_micro)
            # stage 0 reads its own microbatch; later stages read the
            # activation handed over by the previous stage
            x0 = jax.lax.dynamic_index_in_dim(
                x_micro, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            x_in = jnp.where(stage == 0, x0, buf)
            # fill/drain ticks skip the stage compute entirely (legal:
            # stage bodies are collective-free, so a device-varying
            # predicate cannot deadlock the mesh)
            y = jax.lax.cond(
                active,
                lambda x: stage_fn(params_local, x),
                lambda x: jnp.zeros_like(x), x_in)
            # hand over to the next stage
            buf_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage records its finished microbatch
            is_last = stage == n_stages - 1
            outs = jax.lax.cond(
                jnp.logical_and(active, is_last),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb, 0, n_micro - 1), 0),
                lambda o: o, outs)
            return (buf_next, outs), None

        buf0 = jnp.zeros(mb_shape, x_micro.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x_micro.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        return outs

    return run


def pipeline_apply(mesh, stage_fn: Callable, stage_params, x,
                   n_micro: int, axis: str = "pod"):
    """Run x [B, ...] through n_stages pipelined stages over ``axis``.

    stage_params: pytree with a leading stage dimension == mesh.shape[axis]
    on every leaf.  Returns y [B, ...] (gathered from the last stage).
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])
    run = gpipe_forward(stage_fn, axis, n_stages, n_micro)

    mapped = compat_shard_map(
        run, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis),  # per-stage outputs stacked; last stage valid
        axis_names={axis})  # other mesh axes stay "auto"
    outs = mapped(stage_params, x_micro)
    # outs [n_stages * n_micro, mb, ...]: only the last stage's block is
    # the real output (earlier stages contributed zeros)
    outs = outs.reshape((n_stages, n_micro, mb) + x.shape[1:])
    return outs[-1].reshape((B,) + x.shape[1:])


def _handover(y, axis: str, n_stages: int, stage, direction: int,
              use_ppermute: bool):
    """Ship y one stage over (+1 forward, -1 backward); edges get zeros.

    The natural collective is ``ppermute``, which lowers cleanly under
    the fully-manual shard_map ``pipeline_grads`` builds and is the
    default.  ``use_ppermute=False`` keeps a one-hot scatter + psum over
    the stage axis as an escape hatch (same values, S× the wire bytes):
    partial-manual lowering — where XLA's SPMD partitioner hard-crashes
    on collective-permute (hlo_sharding_util CHECK: IsManualSubgroup) —
    is exactly the kind of regression a future mesh layout could
    reintroduce, and the fallback is parity-tested against it."""
    if use_ppermute:
        pairs = ([(i, i + 1) for i in range(n_stages - 1)]
                 if direction > 0
                 else [(i, i - 1) for i in range(1, n_stages)])
        return jax.lax.ppermute(y, axis, pairs)
    tgt = stage + direction
    valid = jnp.logical_and(tgt >= 0, tgt < n_stages)
    buf = jnp.zeros((n_stages,) + y.shape, y.dtype)
    buf = jax.lax.dynamic_update_index_in_dim(
        buf, jnp.where(valid, y, jnp.zeros_like(y)),
        jnp.clip(tgt, 0, n_stages - 1), 0)
    buf = jax.lax.psum(buf, axis)
    return jax.lax.dynamic_index_in_dim(buf, stage, 0, keepdims=False)


def one_f_one_b(stage_fn: Callable, axis: str, n_stages: int,
                n_micro: int, act, use_ppermute: bool = True,
                dp_axes=(), dp_size: int = 1):
    """Build the per-device 1F1B loss+grad engine.

    stage_fn(shared, lay_local, inp, x, is_first, is_last) -> (y, loss)
      one stage's forward: ``shared`` are the replicated parameters
      (embedding / final norm / head), ``lay_local`` this stage's layer
      slice, ``inp`` the microbatch input (e.g. tokens [mb, seq]), ``x``
      the incoming activation (ignored when ``is_first``), and
      ``is_first`` / ``is_last`` traced stage predicates.  ``y`` must
      have the shape/dtype of ``act`` (a ShapeDtypeStruct [mb, ...]);
      ``loss`` is a fixed-shape fp32 array of per-microbatch loss parts
      (zero where the stage doesn't own that term).

    Returns run(shared, lay_stacked, inp_micro [M, ...]) ->
      (loss_parts, g_shared, g_lay_stacked) where loss_parts and
      g_shared are psum'd over ``axis`` (hence replicated — this is what
      makes tied embeddings and per-stage MoE aux losses "just work"),
      losses and gradients are microbatch *means*, and g_lay_stacked
      keeps the local stage dim of size 1 for a P(axis) out_spec.
    """
    S, M = n_stages, n_micro
    T = n_ticks_1f1b(S, M)
    scale = 1.0 / M

    def run(shared, lay_stacked, inp_micro, stage_arr):
        lay = jax.tree.map(lambda p: p[0], lay_stacked)
        # stage index arrives as a pod-sharded iota ([1] per device)
        # rather than lax.axis_index: under partial-manual shard_map
        # (data/model auto) axis_index lowers to a PartitionId op that
        # GSPMD refuses to partition
        stage = stage_arr[0]
        is_first = stage == 0
        is_last = stage == S - 1

        def full_stage(sh, la, inp, x):
            with suspend_activation_sharding():
                return stage_fn(sh, la, inp, x, is_first, is_last)

        inp0 = jax.tree.map(lambda a: a[0], inp_micro)
        x_zero = jnp.zeros(act.shape, act.dtype)
        _, loss_shape = jax.eval_shape(full_stage, shared, lay, inp0,
                                       x_zero)
        loss_zero = jnp.zeros(loss_shape.shape, loss_shape.dtype)
        cotangent = jnp.full(loss_shape.shape, scale, loss_shape.dtype)

        def tick(carry, t):
            fwd_buf, bwd_buf, stash, g_sh, g_lay, loss_acc = carry

            # ---- forward half: microbatch (t - stage) ----
            f_mb = t - stage
            f_valid = jnp.logical_and(f_mb >= 0, f_mb < M)
            fc = jnp.clip(f_mb, 0, M - 1)
            inp_f = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, fc, 0, keepdims=False), inp_micro)
            x_in = fwd_buf
            # stash the stage INPUT (the only activation kept per
            # microbatch; the backward rematerializes the rest).  The
            # in-place dynamic update lets XLA alias one [M, mb, ...]
            # buffer across the whole scan.
            stash = jax.lax.cond(
                f_valid,
                lambda s: jax.lax.dynamic_update_index_in_dim(
                    s, x_in, fc, 0),
                lambda s: s, stash)
            # the last stage's forward is fused into its backward tick
            # (the vjp recomputes it), so only feeder stages run the
            # forward-for-handover here
            y = jax.lax.cond(
                jnp.logical_and(f_valid, jnp.logical_not(is_last)),
                lambda: full_stage(shared, lay, inp_f, x_in)[0],
                lambda: x_zero)
            fwd_next = _handover(y, axis, S, stage, +1, use_ppermute)

            # ---- backward half: microbatch (t - 2(S-1) + stage) ----
            b_mb = t - 2 * (S - 1) + stage
            b_valid = jnp.logical_and(b_mb >= 0, b_mb < M)
            bc = jnp.clip(b_mb, 0, M - 1)
            inp_b = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, bc, 0, keepdims=False), inp_micro)
            x_b = jax.lax.dynamic_index_in_dim(stash, bc, 0,
                                               keepdims=False)

            def do_bwd():
                (y2, loss), vjp = jax.vjp(
                    lambda sh, la, x: full_stage(sh, la, inp_b, x),
                    shared, lay, x_b)
                # downstream cotangent: the grad handed back by stage
                # s+1; the last stage seeds only through the loss
                g_y = jnp.where(is_last, jnp.zeros_like(y2), bwd_buf)
                d_sh, d_la, dx = vjp((g_y, cotangent))
                return d_sh, d_la, dx, loss

            def no_bwd():
                return (jax.tree.map(jnp.zeros_like, shared),
                        jax.tree.map(jnp.zeros_like, lay),
                        jnp.zeros(act.shape, act.dtype), loss_zero)

            d_sh, d_la, dx, loss_b = jax.lax.cond(b_valid, do_bwd,
                                                  no_bwd)
            g_sh = jax.tree.map(jnp.add, g_sh, d_sh)
            g_lay = jax.tree.map(jnp.add, g_lay, d_la)
            loss_acc = loss_acc + loss_b
            bwd_next = _handover(dx, axis, S, stage, -1, use_ppermute)
            return (fwd_next, bwd_next, stash, g_sh, g_lay,
                    loss_acc), None

        carry0 = (x_zero, jnp.zeros(act.shape, act.dtype),
                  jnp.zeros((M,) + act.shape, act.dtype),
                  jax.tree.map(jnp.zeros_like, shared),
                  jax.tree.map(jnp.zeros_like, lay), loss_zero)
        (_, _, _, g_sh, g_lay, loss_acc), _ = jax.lax.scan(
            tick, carry0, jnp.arange(T))

        # shared params see every microbatch on every stage slot that
        # uses them; layer grads live on their stage.  psum makes losses
        # and shared grads replicated (P() out_specs).
        # the 1/M mean is baked into the backward cotangent seed, so the
        # accumulated grads are already microbatch means; only the raw
        # loss sum still needs the scale.  With the microbatch dim
        # sharded over dp_axes, each DP shard holds the mean over its
        # slice — psum over (stage, dp) and divide by the DP degree.
        red = (axis,) + tuple(dp_axes)
        inv = 1.0 / dp_size
        loss_tot = jax.lax.psum(loss_acc * (scale * inv), red)
        g_sh = jax.tree.map(lambda a: jax.lax.psum(a, red) * inv, g_sh)
        if dp_axes:
            g_lay = jax.tree.map(
                lambda a: jax.lax.psum(a, tuple(dp_axes)) * inv, g_lay)
        g_lay = jax.tree.map(lambda a: a[None], g_lay)
        return loss_tot, g_sh, g_lay

    return run


def pipeline_grads(mesh, stage_fn: Callable, shared, lay_stacked,
                   inp_micro, act, n_micro: int, axis: str = "pod"):
    """shard_map wrapper around ``one_f_one_b`` over ``axis``.

    shared: replicated parameter pytree; lay_stacked: pytree with a
    leading stage dim == mesh.shape[axis] on every leaf; inp_micro:
    per-microbatch inputs [n_micro, mb, ...] (every stage needs the
    tokens that seed its loss terms; the mb dim is sharded over "data"
    when the mesh has one and mb divides); act: ShapeDtypeStruct of one
    GLOBAL microbatch activation [mb, ...] (divided by the data degree
    internally).  Returns (loss_parts, g_shared, g_lay_stacked) — the
    first two replicated, the last stage-sharded.
    """
    n_stages = mesh.shape[axis]
    # The shard_map is FULLY manual over every mesh axis: XLA's
    # partial-manual (manual-subgroup) lowering hard-crashes on the
    # transformer backbone in this jax/XLA generation (CHECK failure in
    # hlo_sharding_util IsManualSubgroup), so nothing may be left in
    # auto mode.  Data parallelism is therefore explicit: the
    # per-microbatch dim is sharded over "data" and the engine psums /
    # averages grads over it (dp_axes); any tensor-model axes replicate
    # the stage compute (params enter replicated via P()).  Full-manual
    # also means ppermute lowers cleanly, so handover uses the real
    # collective.
    dp_axes: tuple = ()
    leaves = jax.tree.leaves(inp_micro)
    if "data" in mesh.axis_names and axis != "data" and \
            all(a.ndim >= 2 and a.shape[1] % mesh.shape["data"] == 0
                for a in leaves):
        dp_axes = ("data",)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    inp_spec = P(None, *dp_axes) if dp_axes else P()
    if dp_size > 1:  # per-device activation: mb shrinks by the DP degree
        act = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (s.shape[0] // dp_size,) + tuple(s.shape[1:]), s.dtype),
            act)
    run = one_f_one_b(stage_fn, axis, n_stages, n_micro, act,
                      use_ppermute=True, dp_axes=dp_axes, dp_size=dp_size)
    mapped = compat_shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(axis), inp_spec, P(axis)),
        out_specs=(P(), P(), P(axis)),
        axis_names=None)
    return mapped(shared, lay_stacked, inp_micro,
                  jnp.arange(n_stages, dtype=jnp.int32))
