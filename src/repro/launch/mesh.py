"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module does not touch jax device state — required because
the dry-run process must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: (data=16, model=16) per pod; the multi-pod
    variant adds a leading pure-DP "pod" axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2, *,
                    multi_pod: bool = False, pods: int = 2):
    """Small mesh for CPU multi-device tests (device count forced by the
    caller via XLA_FLAGS before jax init)."""
    shape = (pods, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
