"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module does not touch jax device state — required because
the dry-run process must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: axis_types/AxisType only exist
    on newer releases (this container ships 0.4.37, where every mesh axis
    is implicitly Auto — the semantics the newer call spells out)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def compat_abstract_mesh(shape, axes):
    """jax.sharding.AbstractMesh across jax versions: 0.4.x takes a
    ((name, size), ...) tuple, newer releases take (shape, names[,
    axis_types]).  Metadata-only — for sharding-rule tests that need the
    production mesh shape without 256 devices."""
    from jax.sharding import AbstractMesh

    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return AbstractMesh(shape, axes,
                            axis_types=(axis_type.Auto,) * len(axes))
    return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: (data=16, model=16) per pod; the multi-pod
    variant adds a leading pure-DP "pod" axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, *,
                    multi_pod: bool = False, pods: int = 2):
    """Small mesh for CPU multi-device tests (device count forced by the
    caller via XLA_FLAGS before jax init)."""
    shape = (pods, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)
