"""Central configuration dataclasses for the repro framework.

Everything is a plain frozen dataclass so configs hash/compare cleanly and
can be used as jit static arguments.  Architecture files under
``repro/configs`` construct ``ModelConfig`` instances; the launcher layers
``MeshConfig``/``TrainConfig`` on top.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# PRISM


@dataclass(frozen=True)
class MatfnPrecision:
    """Precision policy of the matrix-function engine (DESIGN.md §9).

    Three roles, threaded end-to-end through core/, kernels/ and optim/:

      compute:    dtype of GEMM operands and iterates (X, R, V, the
                  sketch S).  "bfloat16" halves HBM traffic and
                  optimizer-state bytes on TPU, where the MXU's native
                  operand currency is bf16.
      accumulate: dtype of MXU/dot accumulation.  PINNED float32 — every
                  Pallas kernel uses an fp32 VMEM scratch accumulator
                  (``preferred_element_type=jnp.float32``) and every
                  pure-jnp oracle/iteration path mirrors that exactly.
      fit:        dtype of the PRISM alpha machinery — sketched traces,
                  the trace-weight map W, the closed-form minimization,
                  Frobenius norms, and the §7 pad-trace correction.
                  PINNED float32 (DESIGN.md §2/§9): the fit is O(n^2 p)
                  scalars, so pinning costs nothing, while a bf16 fit
                  would make alpha itself noisy instead of letting the
                  fit *absorb* bf16 residual noise adaptively.
    """

    compute: str = "float32"
    accumulate: str = "float32"
    fit: str = "float32"

    def __post_init__(self):
        if self.accumulate != "float32":
            raise ValueError("MatfnPrecision.accumulate is pinned float32 "
                             f"(got {self.accumulate!r}); see DESIGN.md §9")
        if self.fit != "float32":
            raise ValueError("MatfnPrecision.fit is pinned float32 "
                             f"(got {self.fit!r}); see DESIGN.md §9")

    @property
    def compute_dtype(self):
        import jax.numpy as jnp

        return jnp.dtype(self.compute)

    @property
    def accumulate_dtype(self):
        import jax.numpy as jnp

        return jnp.dtype(self.accumulate)

    @property
    def fit_dtype(self):
        import jax.numpy as jnp

        return jnp.dtype(self.fit)


@dataclass(frozen=True)
class PrismConfig:
    """Configuration of the PRISM matrix-function engine.

    Attributes:
      degree: d in g_d(xi; alpha) = f_{d-1}(xi) + alpha xi^d.  degree=1 is
        the 3rd-order Newton-Schulz family, degree=2 the 5th-order family.
      sketch_dim: rows p of the Gaussian OSE sketch S in R^{p x n}.  The
        paper observes p as small as 5 suffices; we default to 8 (padded to
        a TPU lane multiple inside the kernel).
      iterations: fixed iteration count when run inside jit (Muon/Shampoo).
      warm_alpha_iters: use alpha = u (the upper constraint) for this many
        initial iterations instead of fitting (paper Sec. C efficiency
        trick; preserves the quadratic-convergence guarantee by Lemma B.1).
      alpha_bounds: override [l, u]; None selects the paper's defaults
        ([1/2, 1] for d=1, [3/8, 29/20] for d=2).
      use_kernels: route GEMM hot spots through the Pallas kernels (TPU);
        False uses pure-jnp reference paths (CPU tests, oracles).
      dtype: COMPUTE dtype of the iteration (operands, iterates, sketch);
        accumulation and the alpha fit stay fp32 regardless — see
        ``precision`` / MatfnPrecision (DESIGN.md §9).
      fuse: the single-launch fused-iteration kernel tier (DESIGN.md §10).
        "auto" engages it per call when the iteration's whole working set
        fits the VMEM budget (kernels/ops.py::fused_fits — a trace-time,
        batch-size-independent shape test); "on"/"off" force it.  Only
        meaningful with ``use_kernels``.
      vmem_budget: VMEM budget in bytes for the fused tier (and the
        sketch-chain size guard).  0 defers to ``REPRO_VMEM_BUDGET`` or
        the built-in default (kernels/ops.py).
      tol: convergence certificate for ADAPTIVE early stopping
        (DESIGN.md §11).  When set, every FITTED iteration reads the
        sketched residual estimate est_r ~ ||R_k||_F off the trace chain
        it already computes (t_2 = tr(S R^2 S^T), fp32, §7 pad-corrected)
        and freezes any [B, n, n] slice whose est_r <= tol — the fit
        phase becomes a lax.while_loop that exits when the SLOWEST slice
        certifies, so ``iterations`` turns from a fixed cost into a
        budget (upper bound).  ``None`` (default) keeps the fixed-iters
        chains: fully unrolled, reverse-differentiable, bit-identical to
        previous releases.  The certificate is an UNBIASED sketch
        estimate, not a bound: with sketch_dim = p its relative std is
        ~sqrt(2/p), so a slice can certify while its true ||R||_F sits
        slightly above tol (sketch_dim=0 makes est_r exact).  Warm
        iterations and classical (fit-free) chains never consult tol —
        they have no trace chain to read — and run their static schedule.
      divergence_factor: the §15 divergence detector riding the same
        certificate.  Inside the adaptive loop every slice tracks its
        best (smallest) est_r so far; the step est_r goes non-finite or
        exceeds ``divergence_factor ×`` that best, the slice is
        QUARANTINED — rolled back to its best-so-far iterate
        (bitwise, like the freeze masks) and withdrawn from further
        updates, with an int8 status code surfacing the event.  Only
        consulted when ``tol`` is set (the detector reads the same free
        trace-chain certificate); must be > 1.  Larger values tolerate
        more transient certificate noise before declaring divergence —
        with sketch_dim = p the certificate's relative std is
        ~sqrt(2/p), so factors below ~2 would quarantine healthy chains
        on sketch variance alone.
    """

    degree: int = 2
    sketch_dim: int = 8
    iterations: int = 5
    warm_alpha_iters: int = 0
    alpha_bounds: Optional[Tuple[float, float]] = None
    use_kernels: bool = False
    dtype: str = "float32"
    fuse: str = "auto"
    vmem_budget: int = 0
    tol: Optional[float] = None
    divergence_factor: float = 10.0

    def __post_init__(self):
        if self.fuse not in ("auto", "on", "off"):
            raise ValueError(f"PrismConfig.fuse must be auto|on|off, "
                             f"got {self.fuse!r}")
        if self.tol is not None and not self.tol > 0.0:
            raise ValueError(f"PrismConfig.tol must be positive or None, "
                             f"got {self.tol!r}")
        if not self.divergence_factor > 1.0:
            raise ValueError(f"PrismConfig.divergence_factor must be > 1 "
                             f"(the §15 detector compares est_r against "
                             f"factor x best-so-far), got "
                             f"{self.divergence_factor!r}")

    @property
    def bounds(self) -> Tuple[float, float]:
        if self.alpha_bounds is not None:
            return self.alpha_bounds
        return {1: (0.5, 1.0), 2: (3.0 / 8.0, 29.0 / 20.0)}[self.degree]

    @property
    def precision(self) -> "MatfnPrecision":
        """The full precision policy implied by ``dtype`` (accumulate and
        fit pinned fp32 by construction)."""
        return MatfnPrecision(compute=self.dtype)


# ---------------------------------------------------------------------------
# Model


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    num_experts_per_tok: int = 2
    # "expert": shard expert dim over the model axis (EP);
    # "tensor": shard each expert's hidden dim over the model axis (TP).
    sharding: str = "expert"
    router_aux_loss_coef: float = 0.01
    # per-expert slot budget C = ceil(k*T/E * capacity_factor); tokens over
    # budget are dropped (standard Switch/GShard semantics).  Set to
    # num_experts for drop-free routing (exact but unbalanced memory).
    capacity_factor: float = 1.25
    # "global": one dispatch over all B*S tokens (baseline; the gather
    # crosses data shards -> all-gathers of the token stream).
    # "per_sample": dispatch within each sequence -> gathers stay local to
    # the batch shard (§Perf MoE iteration); capacity is per sample.
    dispatch: str = "global"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent-block config (RG-LRU + local attention)."""

    lru_width: int = 0          # 0 => d_model
    conv_dim: int = 4
    attention_window: int = 2048
    # block pattern period: `pattern` entries cycle over layers
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    head_dim: int = 0  # 0 => d_model // num_heads
    d_ff: int = 3072
    vocab_size: int = 50257
    max_seq_len: int = 8192
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    mlp_act: str = "silu"  # silu (SwiGLU) | gelu
    sliding_window: int = 0  # 0 => full causal attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # audio (decoder over EnCodec tokens)
    num_codebooks: int = 0  # 0 => ordinary single-vocab LM
    # vlm (stub frontend): number of precomputed patch embeddings prepended
    num_patches: int = 0
    vision_dim: int = 1152  # dim of the (stubbed) precomputed patch embeds
    logits_softcap: float = 0.0
    scale_embeddings: bool = False  # gemma-style sqrt(d_model) embed scale
    emb_dtype: str = "bfloat16"
    dtype: str = "bfloat16"
    remat: str = "block"  # none | block (checkpoint each scanned block)
    scan_layers: bool = True
    # seq-chunk size for the chunked CE loss; larger chunks amortize the
    # LM-head all-gather across more tokens (ZeRO-3; §Perf iteration 4)
    loss_chunk: int = 512

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if serving memory does not grow with full seq_len attention."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Optimizer


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "muon"  # muon | shampoo | adamw
    learning_rate: float = 6e-3
    weight_decay: float = 0.01
    momentum: float = 0.95
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    # muon
    matfn_method: str = "prism"  # prism | polar_express | newton_schulz | eigh
    prism: PrismConfig = field(default_factory=lambda: PrismConfig(
        degree=2, iterations=3, warm_alpha_iters=3))
    # mixed-precision matrix-function engine (DESIGN.md §9): COMPUTE dtype
    # of the whole matfn stack — bucket gathers, NS/inverse-root chains,
    # sketch chains.  "bfloat16" halves chain HBM reads; accumulation and
    # the PRISM fit stay fp32 regardless (MatfnPrecision pins them).
    # "float32" (default) defers to prism.dtype untouched.
    matfn_dtype: str = "float32"
    # VMEM budget (bytes) for the fused single-launch iteration tier and
    # the sketch-chain size guard (DESIGN.md §10).  0 defers to the
    # REPRO_VMEM_BUDGET env var / built-in default; threads into
    # resolved_prism so bucketing and the iteration families share one
    # number.  The tier itself stays per-bucket automatic (prism.fuse).
    vmem_budget: int = 0
    # adaptive early stopping (DESIGN.md §11): convergence certificate for
    # the fitted matfn iterations — a bucket slice freezes once its
    # sketched residual estimate drops to tol, so prism.iterations becomes
    # a budget instead of a fixed cost.  None keeps fixed-iters chains.
    # Threads into resolved_prism; per-leaf iters_used telemetry lands in
    # the Muon/Shampoo state whenever a tol is set (matfn_telemetry).
    matfn_tol: Optional[float] = None
    # dtype of the staleness caches carried in the optimizer state (Muon
    # "ortho", Shampoo "Linv"/"Rinv").  "auto" follows matfn_dtype —
    # bf16 halves cached optimizer state; sharding rules are unchanged
    # (launch/sharding.py::precond_cache_sharding is dtype-independent).
    precond_cache_dtype: str = "auto"  # auto | float32 | bfloat16
    adamw_lr_scale: float = 0.05   # lr scale for non-matrix params under muon
    # shampoo
    precondition_every: int = 1
    max_precond_dim: int = 2048
    shampoo_eps: float = 1e-6
    grad_clip_norm: float = 1.0
    # shape-bucketed batched matrix-function engine (optim/bucketing.py):
    # stack same-shape matrix leaves into one [B, m, n] call per bucket
    # instead of a Python loop of per-leaf polar/sqrtm calls.  bucket_pad
    # additionally merges near-miss shapes into a shared padded bucket
    # (Muon/polar only; exact — see DESIGN.md §7) when the padded area
    # overhead stays below bucket_pad_slack.
    bucketed: bool = True
    bucket_pad: bool = False
    bucket_pad_slack: float = 0.25
    # mesh-sharded preconditioner engine (DESIGN.md §8): partition each
    # bucket's [B, m, n] batch dim over the (pod, data) mesh axes via
    # shard_map — each device runs the fitted PRISM/NS chain only on its
    # slice, then all-gathers the bucket.  "auto" activates whenever an
    # activation-sharding context with a >1-sized batch axis is installed
    # (launcher / multi-device tests); "off" keeps the replicated dispatch.
    precond_shard: str = "auto"  # auto | off
    # staleness-scheduled refresh: recompute matrix preconditioners (Muon
    # polar factors, Shampoo inverse roots) every K steps and serve the
    # K-1 steps in between from caches carried in the optimizer state.
    # Exact at step 0 (count % K == 0 refreshes, so the first step always
    # computes).  1 => refresh every step; Muon then carries no cache.
    # Shampoo's effective period is max(precond_every, precondition_every)
    # (the latter is the legacy Shampoo-only knob); use
    # optim.base.resolve_refresh_period for the resolved K.
    precond_every: int = 1
    # async preconditioner service (DESIGN.md §12): double-buffered
    # refresh plane.  Matrix-function chains NEVER run inside the train
    # step — each Muon/Shampoo state carries an ACTIVE preconditioner
    # buffer (consumed every step) and a PENDING one, recomputed by a
    # separately jitted ``Optimizer.refresh`` dispatched between steps
    # without blocking and swapped in ``precond_swap_delay`` steps later
    # under a lax.cond.  Steady-state steps then compile with zero matfn
    # launches.  Requires precond_every > 1 (the fixed refresh clock stays
    # as the staleness ceiling).
    precond_async: bool = False
    # steps between the async refresh DISPATCH and the pending->active
    # buffer swap: the window the refresh chains have to complete behind
    # forward/backward before any step consumes them.
    precond_swap_delay: int = 1
    # drift-triggered refresh (DESIGN.md §12): with matfn_tol set, the
    # optimizer state tracks a first-order proxy for the cached
    # preconditioner's residual drift (accumulated relative movement of
    # the matrix the cache was computed from) and a refresh is dispatched
    # as soon as the estimated cached residual tol + drift crosses
    # matfn_tol * precond_drift_slack — instead of waiting for the fixed
    # precond_every clock, which remains the ceiling.  0 disables the
    # trigger (pure clock schedule).
    precond_drift_slack: float = 0.0
    # distributed tricks
    gradient_compression: str = "none"  # none | int8
    # "bfloat16": differentiate wrt the bf16 compute params so the data-
    # parallel gradient reduction moves bf16 on the wire (fp32 master
    # update unchanged); "float32": reduce in fp32 (baseline).
    grads_dtype: str = "float32"
    # reshard stacked momentum matrices to (layers->model, rows->data)
    # before the polar iteration: Newton-Schulz runs with one small R-psum
    # instead of full cross-mesh GEMM collectives (§Perf iteration 3).
    muon_local_reshard: bool = False
    # low-rank sketched orthogonalization tier (DESIGN.md §14): views too
    # large or too rectangular for the cubic polar path (embedding,
    # LM-head, MoE-expert tables) orthogonalize in a sketched top-k
    # subspace at O(mnl) — a randomized rangefinder builds Q in R^{m x l}
    # (l = lowrank_rank + lowrank_oversample), the existing fitted
    # PRISM-NS polar runs on the projected [l, n] view, and the result
    # lifts back through Q.  lowrank_rank=0 (default) disables the tier;
    # with rank > 0 Muon additionally CLAIMS vocab/codebook leaves that
    # otherwise fall through to the AdamW path (base.is_matrix_param).
    lowrank_rank: int = 0
    # planner thresholds (optim/bucketing.py::resolve_lowrank_tier): a
    # bucket routes through the lowrank tier when its max view dim
    # exceeds lowrank_max_dim OR its aspect ratio max/min reaches
    # lowrank_aspect — and the modeled projected-chain FLOPs actually
    # beat the cubic path (kernels/ops.py::lowrank_polar_flops).
    lowrank_max_dim: int = 4096
    lowrank_aspect: float = 4.0
    lowrank_oversample: int = 8
    # numerics guardian (DESIGN.md §15): skip-step protection.  When on,
    # the optimizer update still computes unconditionally, but ONE fused
    # finiteness check over grads + proposed state gates the state write
    # under a single lax.cond — a non-finite step leaves params/momentum
    # bitwise untouched and bumps the ``bad_steps`` counter carried in
    # the optimizer state.  Adds zero matfn launches (the check is a
    # scalar reduction fused into the step program); off by default so
    # existing state trees stay bit-identical.
    skip_nonfinite: bool = False
    # async refresh validation (DESIGN.md §15): consecutive validation
    # failures a pending-buffer slot may accumulate — each failure
    # discards the poisoned pending twin (never swapped) and re-dispatches
    # with capped exponential backoff — before the service stops retrying
    # and DEGRADES the slot to its last good active buffer until the next
    # clock-period refresh.
    precond_max_retries: int = 3

    def __post_init__(self):
        if self.precond_async and self.precond_every <= 1:
            raise ValueError(
                "precond_async requires precond_every > 1: the fixed "
                "refresh clock is the staleness ceiling of the async "
                "service (DESIGN.md §12)")
        if self.precond_swap_delay < 0:
            raise ValueError("precond_swap_delay must be >= 0, got "
                             f"{self.precond_swap_delay!r}")
        if self.precond_drift_slack < 0:
            raise ValueError("precond_drift_slack must be >= 0, got "
                             f"{self.precond_drift_slack!r}")
        if self.precond_drift_slack > 0 and self.matfn_tol is None:
            raise ValueError(
                "precond_drift_slack needs matfn_tol: the drift trigger "
                "threshold is matfn_tol * precond_drift_slack — the "
                "certificate units of DESIGN.md §11/§12")
        if self.lowrank_rank < 0:
            raise ValueError(f"lowrank_rank must be >= 0 (0 disables the "
                             f"§14 tier), got {self.lowrank_rank!r}")
        if self.lowrank_oversample < 0:
            raise ValueError(f"lowrank_oversample must be >= 0, got "
                             f"{self.lowrank_oversample!r}")
        if self.lowrank_max_dim < 1:
            raise ValueError(f"lowrank_max_dim must be >= 1, got "
                             f"{self.lowrank_max_dim!r}")
        if self.lowrank_aspect < 1.0:
            raise ValueError(f"lowrank_aspect must be >= 1.0, got "
                             f"{self.lowrank_aspect!r}")
        if self.precond_max_retries < 0:
            raise ValueError(f"precond_max_retries must be >= 0, got "
                             f"{self.precond_max_retries!r}")
        if self.lowrank_rank and self.matfn_method not in (
                "prism", "newton_schulz"):
            raise ValueError(
                "lowrank_rank needs an NS-family matfn_method (prism | "
                "newton_schulz): the §14 tier runs the fitted chains in "
                f"the projected subspace, got {self.matfn_method!r}")

    @property
    def drift_threshold(self) -> Optional[float]:
        """Drift value at which the async service dispatches a refresh
        (DESIGN.md §12), or None when the trigger is disabled: the
        estimated residual of the CACHED preconditioner — its refresh
        certificate (<= matfn_tol, §11) plus the accumulated relative
        drift of the underlying matrix — crosses
        ``matfn_tol * precond_drift_slack``, i.e. the drift proxy alone
        crosses ``matfn_tol * (precond_drift_slack - 1)``."""
        if not (self.precond_async and self.precond_drift_slack > 0
                and self.matfn_tol is not None):
            return None
        return self.matfn_tol * max(self.precond_drift_slack - 1.0, 0.0)

    @property
    def resolved_prism(self) -> PrismConfig:
        """PrismConfig with ``matfn_dtype`` (and ``vmem_budget``) threaded
        in.  The default matfn_dtype="float32" leaves an explicitly
        configured prism.dtype alone."""
        out = self.prism
        if self.matfn_dtype != "float32" and \
                self.matfn_dtype != out.dtype:
            out = dataclasses.replace(out, dtype=self.matfn_dtype)
        if self.vmem_budget and self.vmem_budget != out.vmem_budget:
            out = dataclasses.replace(out, vmem_budget=self.vmem_budget)
        if self.matfn_tol is not None and self.matfn_tol != out.tol:
            out = dataclasses.replace(out, tol=self.matfn_tol)
        return out

    @property
    def matfn_telemetry(self) -> bool:
        """True when the optimizer should carry per-leaf ``iters_used``
        telemetry in its state (DESIGN.md §11): an adaptive tol is set
        and the method actually runs fitted (certifiable) iterations."""
        return (self.resolved_prism.tol is not None
                and self.matfn_method == "prism")

    @property
    def matfn_precision(self) -> MatfnPrecision:
        return self.resolved_prism.precision

    @property
    def cache_dtype(self) -> str:
        """Storage dtype of the precond_every staleness caches."""
        if self.precond_cache_dtype == "auto":
            return self.resolved_prism.dtype
        return self.precond_cache_dtype


# ---------------------------------------------------------------------------
# Mesh / shapes / training


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    data_axis: int = 16
    model_axis: int = 16
    num_pods: int = 2

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.multi_pod:
            return (self.num_pods, self.data_axis, self.model_axis)
        return (self.data_axis, self.model_axis)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        if self.multi_pod:
            return ("pod", "data", "model")
        return ("data", "model")


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    seed: int = 0
    straggler_slack: float = 3.0  # flag steps slower than slack x median
    keep_checkpoints: int = 3
    # 1F1B pipeline parallelism over the "pod" mesh axis (launch/pipeline.py):
    # >1 slices the layer stack into that many stages; n_micro microbatches
    # fill the schedule (bubble fraction 2(S-1)/(n_micro+2(S-1))).
    pipeline_stages: int = 1
    n_micro: int = 4

    def __post_init__(self):
        if self.pipeline_stages < 1:
            raise ValueError("pipeline_stages must be >= 1")
        if self.pipeline_stages > 1 and self.n_micro < 1:
            raise ValueError("n_micro must be >= 1 when pipelining")
