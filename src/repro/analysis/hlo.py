"""Trip-count-aware post-SPMD HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers graph under-reports FLOPs/bytes/collectives by ~num_layers
(verified empirically — see tests/test_hlo_analysis.py).  This module
re-derives module costs from the optimized HLO text with loop multiplicity:

  * computations are parsed into op lists; ``while`` ops carry
    ``backend_config={"known_trip_count":{"n":...}}`` -> body multiplicity
    = parent_mult * n (condition: n+1); fusion/reduce subcomputations are
    folded into their call sites.
  * FLOPs: 2 * prod(result dims) * prod(contracting dims) per dot
    (+ the same for any convolution), times multiplicity.  This is the
    standard MFU accounting (elementwise flops excluded, matching how MXU
    rooflines are quoted).
  * HBM bytes: post-optimization HLO is a DAG of fusion/dot/collective/
    copy nodes whose operands+results are exactly their HBM traffic
    (fusion internals stay on-chip); we sum operand+result bytes per node,
    times multiplicity.
  * collective wire bytes per chip, ring-algorithm estimates:
      all-gather (N-1)/N*result | all-reduce 2(N-1)/N*result
      reduce-scatter (N-1)*result | all-to-all (N-1)/N*result
      collective-permute result
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPNAME = re.compile(r"^\s*(?:\(.*?\)|\S+?)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_CALLS = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "add-dependency", "iota",
             "partition-id", "replica-id"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}
_ASYNC_DONE = {"all-gather-done", "all-reduce-done",
               "collective-permute-done", "async-done", "async-start",
               "async-update", "copy-start", "copy-done"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_array_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


class Op:
    __slots__ = ("name", "kind", "line", "result_type")

    def __init__(self, name, kind, line, result_type):
        self.name, self.kind, self.line = name, kind, line
        self.result_type = result_type


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[Op]], str,
                                           Dict[str, str]]:
    comps: Dict[str, List[Op]] = {}
    result_types: Dict[str, str] = {}
    entry = None
    current = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        h = _COMP_HEAD.match(line.strip())
        if h and ("->" in line):
            current = h.group(1)
            comps[current] = []
            if line.strip().startswith("ENTRY"):
                entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        km = _OPNAME.match(" " + rest)
        kind = km.group(1) if km else "unknown"
        # result type = text before the op kind keyword
        idx = rest.find(" " + kind + "(")
        rtype = rest[:idx] if idx > 0 else rest.split(" ")[0]
        comps[current].append(Op(name, kind, line, rtype))
        result_types[name] = rtype
    return comps, entry, result_types


def _multiplicities(comps: Dict[str, List[Op]], entry: str) -> Dict[str, float]:
    """Computation multiplicity via while trip counts; fusion/reduce
    subcomputations get multiplicity 0 (their cost is the call site)."""
    fused: set = set()
    for ops in comps.values():
        for op in ops:
            for c in _CALLS.findall(op.line):
                fused.add(c)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        c = order.pop(0)
        for op in comps.get(c, []):
            if op.kind == "while":
                cb = _COND_BODY.search(op.line)
                if not cb:
                    continue
                cond, body = cb.group(1), cb.group(2)
                t = _TRIP.search(op.line)
                n = float(t.group(1)) if t else 1.0
                mult[body] += mult[c] * n
                mult[cond] += mult[c] * (n + 1)
                for x in (cond, body):
                    if x not in seen:
                        seen.add(x)
                        order.append(x)
            elif op.kind == "conditional":
                bm = _BRANCHES.search(op.line)
                names = []
                if bm:
                    names = [b.strip().lstrip("%") for b in
                             bm.group(1).split(",")]
                else:
                    names = _CALLS.findall(op.line)
                for b in names:
                    if b in fused:
                        continue
                    mult[b] += mult[c]  # upper bound: every branch charged
                    if b not in seen:
                        seen.add(b)
                        order.append(b)
            elif op.kind in ("call", "async-start"):
                for b in _CALLS.findall(op.line):
                    if b in fused:
                        continue
                    mult[b] += mult[c]
                    if b not in seen:
                        seen.add(b)
                        order.append(b)
    return mult


def _dot_flops(op: Op, result_types: Dict[str, str]) -> float:
    dims = _first_array_dims(op.result_type)
    if dims is None:
        return 0.0
    _, rdims = dims
    out = 1.0
    for d in rdims:
        out *= d
    # contraction size from the lhs operand shape.  The operand may be
    # printed as a bare "%name" (look its type up) or with an inline type
    # "f32[a,b]{1,0} %name" (parse the literal directly).
    operands = _OPERANDS.search(op.line[op.line.find(op.kind + "("):])
    csize = 1.0
    cm = _CONTRACT.search(op.line)
    if cm and operands:
        # split on top-level commas only (shape literals contain commas)
        lhs = _split_operands(operands.group(1))[0].strip()
        ad = _first_array_dims(lhs)  # inline type?
        if ad is None:
            name = lhs.lstrip("%")
            ad = _first_array_dims(result_types.get(name, ""))
        if ad:
            _, ldims = ad
            for ci in cm.group(1).split(","):
                if ci != "" and int(ci) < len(ldims):
                    csize *= ldims[int(ci)]
    return 2.0 * out * csize


def _split_operands(s: str):
    """Split an operand list on commas outside brackets/braces."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return out


def _operand_bytes(op: Op, result_types: Dict[str, str]) -> float:
    seg = op.line[op.line.find(op.kind + "("):]
    m = _OPERANDS.search(seg)
    if not m:
        return 0.0
    total = 0.0
    for tok in _split_operands(m.group(1)):
        tok = tok.strip()
        if not tok:
            continue
        if "[" in tok:  # inline-typed operand
            if not tok.startswith("("):
                total += _type_bytes(tok)
            continue
        name = tok.lstrip("%")
        t = result_types.get(name, "")
        if not t or t.startswith("("):
            continue  # tuple-typed operand (loop state): not HBM traffic
        total += _type_bytes(t)
    return total


def _collective_wire(op: Op) -> Tuple[str, float]:
    kind = op.kind.replace("-start", "")
    rb = _type_bytes(op.result_type)
    m = _GROUPS_IOTA.search(op.line)
    if m:
        n = int(m.group(2))
    else:
        m2 = _GROUPS_LIST.search(op.line)
        n = len([x for x in m2.group(1).split(",") if x.strip()]) if m2 else 1
    n = max(n, 1)
    if kind == "all-gather":
        wire = (n - 1) / n * rb
    elif kind == "all-reduce":
        wire = 2 * (n - 1) / n * rb
    elif kind == "reduce-scatter":
        wire = (n - 1) * rb
    elif kind == "all-to-all":
        wire = (n - 1) / n * rb
    else:
        wire = float(rb)
    return kind, wire


def _op_hbm_bytes(op: Op, result_types: Dict[str, str]) -> float:
    """HBM traffic of one top-level op.

    Slicing/indexing ops touch only the slice, not the whole operand —
    charging full operand bytes would bill the entire stacked-layer
    parameter array once per scan iteration:
      dynamic-slice / gather: result read + result write (2x result)
      dynamic-update-slice / scatter: update read + slice write (2x update)
    """
    if op.kind in ("dynamic-slice", "gather"):
        return 2.0 * _type_bytes(op.result_type)
    if op.kind in ("dynamic-update-slice", "scatter"):
        seg = op.line[op.line.find(op.kind + "("):]
        m = _OPERANDS.search(seg)
        if m:
            names = [x.strip().lstrip("%") for x in m.group(1).split(",")]
            if len(names) >= 2 and names[1] in result_types:
                return 2.0 * _type_bytes(result_types[names[1]])
        return 2.0 * _type_bytes(op.result_type)
    return _type_bytes(op.result_type) + _operand_bytes(op, result_types)


def analyze_module(hlo: str, per_computation: bool = False) -> Dict:
    """Loop-aware flops / HBM bytes / collective bytes for one module."""
    comps, entry, result_types = _parse_computations(hlo)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = _multiplicities(comps, entry)
    fused = set()
    for ops in comps.values():
        for op in ops:
            for c in _CALLS.findall(op.line):
                fused.add(c)
    flops = 0.0
    bytes_hbm = 0.0        # dot/slice/collective-centric (TPU-fused view)
    bytes_hbm_upper = 0.0  # every top-level op (no-fusion upper bound)
    coll_bytes = defaultdict(float)
    coll_count = defaultdict(float)
    by_comp = defaultdict(lambda: {"flops": 0.0, "bytes": 0.0, "mult": 0.0})
    # dots can be fused into subcomputations (CPU backend output-fusion);
    # pre-compute each computation's local dot flops so fusion call sites
    # can be charged for them.
    local_dot_flops: Dict[str, float] = {}
    local_has_compute: Dict[str, bool] = {}
    for cname, ops in comps.items():
        local_dot_flops[cname] = sum(
            _dot_flops(op, result_types) for op in ops
            if op.kind in ("dot", "convolution"))
        # fusions holding dots/reduces are real kernels (matvecs, softmax,
        # norms): their operands/results are genuine HBM traffic, unlike
        # pure layout/convert wrapper fusions that a TPU would fuse away.
        local_has_compute[cname] = any(
            op.kind in ("dot", "convolution", "reduce") for op in ops)
    # ops whose results/operands genuinely hit HBM on a TPU; pure
    # elementwise/layout ops (convert/copy/transpose/broadcast/exp/...)
    # fuse into their producers/consumers and are excluded from the
    # central estimate (they dominate the CPU backend's unfused HLO).
    _HBM_OPS = {"dot", "convolution", "reduce", "sort", "custom-call",
                "rng", "reduce-window", "pad", "concatenate"}
    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0 or cname in fused:
            continue
        for op in ops:
            if op.kind in _SKIP_OPS or op.kind in _ASYNC_DONE:
                continue
            if op.kind in ("dot", "convolution"):
                f = _dot_flops(op, result_types)
                flops += m * f
                by_comp[cname]["flops"] += m * f
            elif op.kind == "fusion":
                # charge dot flops hidden inside the fused computation
                for called in _CALLS.findall(op.line):
                    f = local_dot_flops.get(called, 0.0)
                    if f:
                        flops += m * f
                        by_comp[cname]["flops"] += m * f
            if op.kind in _COLLECTIVES:
                kind, wire = _collective_wire(op)
                coll_bytes[kind] += m * wire
                coll_count[kind] += m
                b = _type_bytes(op.result_type) \
                    + _operand_bytes(op, result_types)
                bytes_hbm += m * b
                bytes_hbm_upper += m * b
                by_comp[cname]["bytes"] += m * b
                continue
            if op.kind == "while":
                continue  # body counted via multiplicity
            b = _op_hbm_bytes(op, result_types)
            bytes_hbm_upper += m * b
            is_compute_fusion = op.kind == "fusion" and any(
                local_has_compute.get(c, False)
                for c in _CALLS.findall(op.line))
            if op.kind in _HBM_OPS or is_compute_fusion or op.kind in (
                    "dynamic-slice", "gather", "dynamic-update-slice",
                    "scatter"):
                bytes_hbm += m * b
                by_comp[cname]["bytes"] += m * b
        by_comp[cname]["mult"] = m
    out = {
        "flops": flops,
        "hbm_bytes": bytes_hbm,
        "hbm_bytes_upper": bytes_hbm_upper,
        "wire_bytes_per_chip": float(sum(coll_bytes.values())),
        "bytes_by_kind": dict(coll_bytes),
        "count_by_kind": dict(coll_count),
    }
    if per_computation:
        out["by_computation"] = {k: v for k, v in sorted(
            by_comp.items(), key=lambda kv: -kv[1]["flops"])}
    return out


def collective_stats(hlo_text: str) -> Dict:
    """Back-compat wrapper: loop-aware collective inventory only."""
    out = analyze_module(hlo_text)
    return {
        "wire_bytes_per_chip": out["wire_bytes_per_chip"],
        "bytes_by_kind": out["bytes_by_kind"],
        "count_by_kind": out["count_by_kind"],
    }
