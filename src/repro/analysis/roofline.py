"""Roofline-term derivation from compiled dry-run artifacts.

TPU v5e hardware constants (per chip):
  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI bandwidth       ~50 GB/s per link (we charge the ring estimate
                      against one link's bandwidth — conservative)

Terms, all in seconds per step (chips = mesh size):
  compute    = HLO_FLOPs / (chips * peak)
  memory     = HLO_bytes / (chips * hbm_bw)
  collective = wire_bytes_per_chip / ici_bw

cost_analysis() on the CPU backend reports whole-module (per-device
partitioned program) flops/bytes — i.e. per-chip numbers — so `chips`
division is already baked in; we detect that by construction: flops from
the partitioned module are per-device, hence compute = flops / peak.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops_global: float = 0.0
    chips: int = 256

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste detector)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the perfect-overlap
        step time, counting only MODEL_FLOPS as useful."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (self.model_flops_global / (self.chips * PEAK_FLOPS)) / t

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "model_flops_global": self.model_flops_global,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_lower_bound_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(n_params: float, tokens: float, kind: str,
                n_active: Optional[float] = None) -> float:
    """6 N D for training; 2 N_active per generated token for decode;
    2 N D for prefill (forward only)."""
    n = n_active if n_active is not None else n_params
    if kind == "train":
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * tokens  # decode: tokens = batch (1 new token each)
