"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 arch.  [arXiv:2410.05355; unverified]"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
)

SMOKE = CONFIG.replace(
    name="falcon-mamba-7b-smoke", num_layers=2, d_model=64, vocab_size=256,
    ssm=SSMConfig(state_dim=4, conv_dim=4, expand=2))
