"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Expert dim (32) divides the model mesh axis (16) => EP sharding.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    qk_norm=False, qkv_bias=False, mlp_act="silu",
    moe=MoEConfig(num_experts=32, num_experts_per_tok=8, sharding="expert"),
)

SMOKE = CONFIG.replace(
    name="granite-moe-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256,
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2, sharding="expert"))
