"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attn, pattern (rec, rec, attn).
[arXiv:2402.19427; hf]

26 layers = 8 full periods + 2 recurrent tail layers; local window 2048
=> bounded decode state => long_500k runs.
"""
from repro.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256000,
    qk_norm=False, qkv_bias=False, mlp_act="gelu",
    scale_embeddings=True, logits_softcap=30.0, tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, conv_dim=4, attention_window=2048,
                      pattern=("recurrent", "recurrent", "attention")),
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-smoke", num_layers=5, d_model=64, num_heads=4,
    num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
    rglru=RGLRUConfig(lru_width=64, conv_dim=4, attention_window=16,
                      pattern=("recurrent", "recurrent", "attention")))
