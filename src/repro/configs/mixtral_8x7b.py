"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA.  [arXiv:2401.04088; hf]

8 experts < 16-way model axis => per-expert tensor sharding (TP).
Sliding window (4096) makes decode state bounded => long_500k runs.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    qk_norm=False, qkv_bias=False, mlp_act="silu",
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2, sharding="tensor"),
)

SMOKE = CONFIG.replace(
    name="mixtral-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256, sliding_window=32,
    moe=MoEConfig(num_experts=4, num_experts_per_tok=2, sharding="tensor"))
