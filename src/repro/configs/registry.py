"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Each assigned architecture lives in its own module exposing CONFIG (the
exact published dims) and SMOKE (a reduced same-family config for CPU
smoke tests).  Sources per the assignment brief are cited in each file.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.config import ModelConfig

_ARCHS = {
    "qwen3-14b": "qwen3_14b",
    "command-r-35b": "command_r_35b",
    "qwen2.5-32b": "qwen2_5_32b",
    "starcoder2-3b": "starcoder2_3b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-medium": "musicgen_medium",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mixtral-8x7b": "mixtral_8x7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    # the paper's own Muon experiment model (Sec. 6.2)
    "gpt2-paper": "gpt2_paper",
}


def arch_ids():
    return [a for a in _ARCHS if a != "gpt2-paper"]


def _module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE
