"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    head_dim=128, d_ff=12288, vocab_size=49152,
    qk_norm=False, qkv_bias=True, mlp_act="gelu",
    rope_theta=100_000.0,
)

SMOKE = CONFIG.replace(
    name="starcoder2-3b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
