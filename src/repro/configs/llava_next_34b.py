"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling (frontend stubbed: ``input_specs`` provides
precomputed patch embeddings).  [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=20480, vocab_size=64000,
    qk_norm=False, qkv_bias=False, mlp_act="silu",
    rope_theta=5_000_000.0,
    # anyres tiling: base 576 patches + 4 tiles x 576 = 2880 patch embeds
    num_patches=2880, vision_dim=1152,
)

SMOKE = CONFIG.replace(
    name="llava-next-34b-smoke", num_layers=2, d_model=64, num_heads=8,
    num_kv_heads=2, head_dim=8, d_ff=128, vocab_size=256,
    num_patches=16, vision_dim=32)
