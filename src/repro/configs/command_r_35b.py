"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01;
unverified]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=22528, vocab_size=256000,
    qk_norm=False, qkv_bias=False, mlp_act="silu",
    rope_theta=8_000_000.0,
)

SMOKE = CONFIG.replace(
    name="command-r-35b-smoke", num_layers=2, d_model=64, num_heads=8,
    num_kv_heads=2, head_dim=8, d_ff=160, vocab_size=512)
