"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens (4 codebooks; EnCodec
frontend stubbed: inputs are the token codes).  [arXiv:2306.05284; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    qk_norm=False, qkv_bias=False, mlp_act="gelu",
    num_codebooks=4,
)

SMOKE = CONFIG.replace(
    name="musicgen-medium-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=64, num_codebooks=4)
