from repro.configs.registry import arch_ids, get_config, get_smoke_config

__all__ = ["arch_ids", "get_config", "get_smoke_config"]
