"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=27648, vocab_size=152064,
    qk_norm=False, qkv_bias=True, mlp_act="silu",
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="qwen2.5-32b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
