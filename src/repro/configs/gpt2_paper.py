"""The paper's own Muon experiment model (Sec. 6.2 / App. C):
"GPT-2 Large ... with 10 layers, 16 attention heads, and an embedding
dimension of 1024", trained on FineWeb-like token streams.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-paper", family="dense",
    num_layers=10, d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=50257,
    qk_norm=False, qkv_bias=True, mlp_act="gelu",
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    name="gpt2-paper-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
