"""Batch construction + ShapeDtypeStruct input specs per (arch x shape).

``input_specs`` is the dry-run contract: weak-type-correct, shardable
stand-ins for every model input, with no device allocation.  ``make_batch``
builds the matching concrete synthetic batch for smoke tests / examples.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.family == "audio":
        specs["tokens"] = jax.ShapeDtypeStruct((B, cfg.num_codebooks, S),
                                               jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.vision_dim), jnp.bfloat16)
    return specs


def decode_token_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    if cfg.family == "audio":
        tok = jax.ShapeDtypeStruct((batch, cfg.num_codebooks, 1), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return {"tokens": tok,
            "pos": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}


def make_train_batch(key, cfg: ModelConfig, batch: int, seq: int):
    kt, kp = jax.random.split(key)
    out = {}
    if cfg.family == "audio":
        out["tokens"] = jax.random.randint(
            kt, (batch, cfg.num_codebooks, seq), 0, cfg.vocab_size, jnp.int32)
    else:
        out["tokens"] = jax.random.randint(
            kt, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            kp, (batch, cfg.num_patches, cfg.vision_dim),
            jnp.float32).astype(jnp.bfloat16)
    return out


def make_decode_inputs(key, cfg: ModelConfig, batch: int, pos: int):
    if cfg.family == "audio":
        tok = jax.random.randint(key, (batch, cfg.num_codebooks, 1), 0,
                                 cfg.vocab_size, jnp.int32)
    else:
        tok = jax.random.randint(key, (batch, 1), 0, cfg.vocab_size,
                                 jnp.int32)
    return {"tokens": tok,
            "pos": jnp.full((batch, 1), pos, jnp.int32)}
