"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Top-k routing -> flatten assignments -> stable-sort by expert -> fixed
capacity C = ceil(k*T/E * capacity_factor) slots per expert -> gather,
batched per-expert SwiGLU, weighted scatter-combine.  Fully jittable and
shardable:

  * EP  (granite, 32e % 16 == 0): expert dim of the stacked weights maps
    to the "model" mesh axis; the gather/scatter become all-to-alls.
  * TP  (mixtral, 8e < 16): each expert's hidden dim maps to "model".

Aux load-balancing loss (Switch-style) is returned alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.models import layers as L


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    d, f, e = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (d, e), -2, jnp.float32),
        "w_gate": L.dense_init(ks[1], (e, d, f), -2, dtype),
        "w_up": L.dense_init(ks[2], (e, d, f), -2, dtype),
        "w_down": L.dense_init(ks[3], (e, f, d), -2, dtype),
    }


def moe_axes(cfg: ModelConfig):
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }


def _dispatch_indices(topi, gates, E: int, C: int):
    """[T, K] assignments -> (tok_for_slot [E*C], gate_for_slot [E*C]).

    Stable sort by expert id + fixed per-expert capacity C; overflow
    assignments land in a scratch slot and are dropped.
    """
    T, K = topi.shape
    flat_e = topi.reshape(-1)                         # [T*K] expert ids
    flat_t = jnp.arange(T * K, dtype=jnp.int32) // K  # token of assignment
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert = index - first index of this expert id
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * K, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)  # overflow -> scratch slot
    tok_for_slot = jnp.zeros(E * C + 1, jnp.int32).at[slot].set(st_)
    gate_for_slot = jnp.zeros(E * C + 1, jnp.float32).at[slot].set(
        jnp.where(keep, sg, 0.0))
    return tok_for_slot[:-1], gate_for_slot[:-1]


def moe_ffn(params, x, cfg: ModelConfig):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    from repro.sharding_ctx import shard_activation

    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.num_experts_per_tok
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs_full = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(logits, K)  # [B, S, K]
    gates = jax.nn.softmax(topv, axis=-1)  # renormalize over selected

    # ---- aux load-balance loss (Switch): E * sum_e f_e * P_e
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)  # [B, S, K, E]
    f_e = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    P_e = jnp.mean(probs_full, axis=(0, 1))
    aux = E * jnp.sum(f_e * P_e) * m.router_aux_loss_coef

    if m.dispatch == "per_sample":
        # batch-local dispatch: gathers/scatters never cross the batch
        # shard, so a data-sharded token stream routes with zero token
        # all-gathers (§Perf MoE iteration).  Capacity is per sample.
        C = max(int(-(-K * S // E) * m.capacity_factor), 4)
        tok_slot, gate_slot = jax.vmap(
            lambda ti, g: _dispatch_indices(ti, g, E, C))(topi, gates)
        xg = jax.vmap(lambda xb, tb: xb[tb])(x, tok_slot)  # [B, E*C, D]
        xg = xg.reshape(B, E, C, D)
        xg = shard_activation(xg, ("batch", "experts", None, "embed_act"))
        gate_h = jnp.einsum("becd,edf->becf", xg, params["w_gate"])
        up_h = jnp.einsum("becd,edf->becf", xg, params["w_up"])
        h = (jax.nn.silu(gate_h.astype(jnp.float32))
             * up_h.astype(jnp.float32)).astype(x.dtype)
        h = shard_activation(h, ("batch", "experts", None, "expert_mlp"))
        y_e = jnp.einsum("becf,efd->becd", h,
                         params["w_down"]).reshape(B, E * C, D)
        y = jax.vmap(
            lambda ts, ye, gs: jnp.zeros((S, D), jnp.float32)
            .at[ts].add(ye.astype(jnp.float32) * gs[:, None]))(
                tok_slot, y_e, gate_slot)
        return y.astype(x.dtype), aux

    # ---- global dispatch over all B*S tokens (baseline)
    T = B * S
    xf = x.reshape(T, D)
    C = max(int(-(-K * T // E) * m.capacity_factor), 4)
    tok_for_slot, gate_for_slot = _dispatch_indices(
        topi.reshape(T, K), gates.reshape(T, K), E, C)
    xg = xf[tok_for_slot].reshape(E, C, D)  # [E, C, D]
    xg = shard_activation(xg, ("experts", None, "embed_act"))
    # per-expert SwiGLU
    gate_h = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"])
    up_h = jnp.einsum("ecd,edf->ecf", xg, params["w_up"])
    h = (jax.nn.silu(gate_h.astype(jnp.float32))
         * up_h.astype(jnp.float32)).astype(x.dtype)
    h = shard_activation(h, ("experts", None, "expert_mlp"))
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * C, D)

    y = jnp.zeros((T, D), jnp.float32).at[tok_for_slot].add(
        y_e.astype(jnp.float32) * gate_for_slot[:, None])
    return y.reshape(B, S, D).astype(x.dtype), aux
