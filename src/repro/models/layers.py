"""Shared model layers: norms, rotary embeddings, MLPs, embeddings.

Parameters are plain nested dicts of jnp arrays.  Every ``init_*`` has a
matching ``*_axes`` returning the same pytree structure with *logical axis
names* per dimension; launch/sharding.py maps those to mesh axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            / np.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    # std 1/sqrt(d_model): unit-scale lookups after gemma-style sqrt(d)
    # input scaling, O(1) logits under tied embeddings
    return (jax.random.normal(key, shape, jnp.float32)
            / np.sqrt(shape[-1])).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def init_rms_norm(d: int, dtype=jnp.float32):
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings


def rope(x, positions, theta: float):
    """Apply RoPE to x [..., S, H, Hd] with integer positions [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x32 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x32[0] * cos - x32[1] * sin,
                           x32[1] * cos + x32[0] * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, dtype=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, (d, f), -2, dtype),
         "w_down": dense_init(k2, (f, d), -2, dtype)}
    if cfg.mlp_act == "silu":  # SwiGLU has a gate projection
        p["w_gate"] = dense_init(k3, (d, f), -2, dtype)
    return p


def mlp_axes(cfg: ModelConfig):
    p = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if cfg.mlp_act == "silu":
        p["w_gate"] = ("embed", "mlp")
    return p


def mlp(params, x, cfg: ModelConfig):
    from repro.sharding_ctx import shard_activation

    up = x @ params["w_up"]
    if cfg.mlp_act == "silu":
        gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
        h = (gate * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    h = shard_activation(h, ("batch", "seq", "mlp"))
    out = h @ params["w_down"]
    return shard_activation(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# misc


def softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


NEG_INF = -1e30  # finite mask: -inf breaks online-softmax (exp(-inf+inf)=nan)


def causal_mask_bias(q_pos, k_pos, window: int = 0):
    """Additive bias [.., Sq, Sk]: 0 where attendable, ~-inf otherwise."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window and window > 0:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
