"""Model zoo: composable decoder framework for all assigned families."""
from repro.models.transformer import Model, build

__all__ = ["Model", "build"]
