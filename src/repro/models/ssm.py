"""Mamba-1 selective state-space block (falcon-mamba-7b).

Train path: chunked selective scan — an outer lax.scan over sequence
chunks carries only the [B, d_inner, d_state] boundary state (each chunk
body is rematerialized in the backward pass), so activation memory never
holds per-timestep states for the whole sequence.  Decode path: one
recurrence step against carried (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L

SCAN_CHUNK = 16


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d, di, ds = cfg.d_model, _d_inner(cfg), cfg.ssm.state_dim
    dtr, dc = _dt_rank(cfg), cfg.ssm.conv_dim
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": L.dense_init(ks[0], (d, 2 * di), -2, dtype),
        "conv_w": L.dense_init(ks[1], (dc, di), -2, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.dense_init(ks[2], (di, dtr + 2 * ds), -2, dtype),
        "dt_proj": L.dense_init(ks[3], (dtr, di), -2, dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[4], (di, d), -2, dtype),
    }


def mamba_axes(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "mlp"), "conv_w": (None, "mlp"),
        "conv_b": ("mlp",), "x_proj": ("mlp", None),
        "dt_proj": (None, "mlp"), "dt_bias": ("mlp",),
        "A_log": ("mlp", None), "D": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }


def _ssm_inputs(params, xc, cfg: ModelConfig):
    """xc [B, S, di] (post-conv) -> (dA [B,S,di,ds], dBx, C, D-term)."""
    ds = cfg.ssm.state_dim
    dtr = _dt_rank(cfg)
    proj = xc @ params["x_proj"]  # [B, S, dtr + 2 ds]
    dt_in, Bmat, Cmat = jnp.split(proj.astype(jnp.float32),
                                  [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])  # [B, S, di]
    A = -jnp.exp(params["A_log"])  # [di, ds]
    dA = jnp.exp(dt[..., None] * A)  # [B, S, di, ds]
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bmat[..., None, :]
    return dA, dBx, Cmat


def _scan_chunk(h0, dA, dBx, Cmat):
    """Sequential scan within one chunk; h0 [B, di, ds]."""

    def step(h, inp):
        dAt, dBxt, Ct = inp  # [B, di, ds], [B, di, ds], [B, ds]
        h = dAt * h + dBxt
        y = jnp.einsum("bds,bs->bd", h, Ct)
        return h, y

    xs = (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
          Cmat.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return h, ys.transpose(1, 0, 2)  # [B, S, di]


def mamba_block(params, x, cfg: ModelConfig, chunk: int = SCAN_CHUNK):
    """x [B, S, D] -> y [B, S, D] (training / prefill)."""
    B, S, D = x.shape
    di, dc = _d_inner(cfg), cfg.ssm.conv_dim
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # [B, S, di]
    # causal depthwise conv along seq
    xpad = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S, :] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu((xc + params["conv_b"]).astype(jnp.float32)).astype(x.dtype)

    dA, dBx, Cmat = _ssm_inputs(params, xc, cfg)
    ds = cfg.ssm.state_dim
    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    def outer(h, blk):
        dAc, dBc, Cc = blk
        h, y = _scan_chunk(h, dAc, dBc, Cc)
        return h, y

    split = lambda a: a.reshape((B, nchunk, chunk) + a.shape[2:]) \
        .transpose(1, 0, 2, *range(3, a.ndim + 1))
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(outer), h0,
                         (split(dA), split(dBx), split(Cmat)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, nchunk * chunk, di)[:, :S]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return (y.astype(x.dtype)) @ params["out_proj"]


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, ds, dc = _d_inner(cfg), cfg.ssm.state_dim, cfg.ssm.conv_dim
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, ds), jnp.float32),
    }


def mamba_decode_step(params, x, cache, cfg: ModelConfig):
    """x [B, 1, D] + cache -> (y [B, 1, D], new cache)."""
    B = x.shape[0]
    dc = cfg.ssm.conv_dim
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # [B, 1, di]
    win = jnp.concatenate([cache["conv"], xin.astype(cache["conv"].dtype)],
                          axis=1)  # [B, dc, di]
    xc = jnp.einsum("bcd,cd->bd", win, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    dA, dBx, Cmat = _ssm_inputs(params, xc, cfg)
    h = dA[:, 0] * cache["ssm"] + dBx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cmat[:, 0])[:, None, :]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ params["out_proj"]
    return out, {"conv": win[:, 1:], "ssm": h}
