"""Grouped-query attention with flash-style chunked softmax.

Supports: GQA (num_kv_heads < num_heads), qk-norm (Qwen3), QKV bias
(Qwen2.5), RoPE, sliding-window (Mixtral) / local (RecurrentGemma)
attention, and single-token decode against a KV cache.

The train/prefill path never materializes the [S, S] score matrix: it
scans over KV blocks with an online-softmax running (max, denom, acc)
carry, so activation memory is O(S * block) — required for prefill_32k.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.sharding_ctx import shard_activation

KV_BLOCK = 1024
HEAD_PAD = 16  # pad head counts to the model-axis width for clean TP


def padded_heads(cfg: ModelConfig):
    """(H_padded, KV_padded).  Heads pad up to a multiple of HEAD_PAD with
    exactly-zero parameters: zero heads produce zero outputs AND zero
    gradients, and Newton-Schulz polar (Muon) preserves zero columns, so
    padding is mathematically inert while making 40/24/10/56-head archs
    16-way tensor-shardable (Megatron pads the same way)."""
    H, KV = cfg.num_heads, cfg.num_kv_heads
    Hp = -(-H // HEAD_PAD) * HEAD_PAD
    kvp = Hp if KV == H else KV  # MHA pads kv with q; GQA keeps kv
    assert Hp % kvp == 0, (Hp, kvp)
    return Hp, kvp


def init_attention(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nh_t, nkv_t = cfg.num_heads, cfg.num_kv_heads
    nh, nkv = padded_heads(cfg)
    ks = jax.random.split(key, 4)

    def zero_pad(w, axis, true_n):
        if w.shape[axis] == true_n:
            return w
        idx = [slice(None)] * w.ndim
        idx[axis] = slice(true_n, None)
        return w.at[tuple(idx)].set(0)

    p = {
        "wq": zero_pad(L.dense_init(ks[0], (d, nh, hd), -3, dtype), 1, nh_t),
        "wk": zero_pad(L.dense_init(ks[1], (d, nkv, hd), -3, dtype), 1,
                       nkv_t),
        "wv": zero_pad(L.dense_init(ks[2], (d, nkv, hd), -3, dtype), 1,
                       nkv_t),
        "wo": zero_pad(L.dense_init(ks[3], (nh, hd, d), -3, dtype), 0, nh_t),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = L.init_rms_norm(hd)
        p["k_norm"] = L.init_rms_norm(hd)
    return p


def attention_axes(cfg: ModelConfig):
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p.update(bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"),
                 bv=("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p.update(q_norm=("head_dim",), k_norm=("head_dim",))
    return p


def _project_qkv(params, x, positions, cfg: ModelConfig):
    """x [B, S, D] -> q [B, S, H, Hd], k/v [B, S, KV, Hd] (RoPE applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_activation(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_activation(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _expand_kv(k, groups: int):
    """[B, S, KV, Hd] -> [B, S, H, Hd] by repeating each kv head.

    Sharding note: reshaping a model-sharded H dim into (KV, groups)
    de-shards attention under GSPMD (verified on the dry-run — attention
    compute replicated across the model axis).  Repeating KV up to H keeps
    the head dim intact and model-sharded; per chip the repeat gathers
    only the kv heads its q-heads need.
    """
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _flash_attend(q, k, v, q_pos, k_pos, window: int, kv_block: int):
    """Online-softmax attention; q [B,S,H,Hd], k/v [B,Sk,KV,Hd]."""
    B, S, H, Hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    k = shard_activation(_expand_kv(k, H // KV),
                         ("batch", "seq", "heads", "head_dim"))
    v = shard_activation(_expand_kv(v, H // KV),
                         ("batch", "seq", "heads", "head_dim"))
    scale = 1.0 / jnp.sqrt(Hd).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale
    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                   constant_values=jnp.iinfo(jnp.int32).max)
    kb = kp.reshape(B, nb, kv_block, H, Hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nb, kv_block, H, Hd).transpose(1, 0, 2, 3, 4)
    pb = kpos.reshape(B, nb, kv_block).transpose(1, 0, 2)

    def body(carry, blk):
        m, den, acc = carry
        kc, vc, pc = blk  # [B, kb, H, Hd], [B, kb]
        # bf16 operands, fp32 MXU accumulation (halves attention HBM reads)
        s = jnp.einsum("bshk,bthk->bsht", qf.astype(q.dtype), kc,
                       preferred_element_type=jnp.float32)
        bias = L.causal_mask_bias(q_pos, pc, window)  # [B, S, kb]
        s = s + bias[:, :, None, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        den = den * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bsht,bthk->bshk", p.astype(q.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, den, acc), None

    # constrain scan carries: without this GSPMD has no preference for the
    # zero-init carries and unifies the loop on a replicated-head layout
    m0 = shard_activation(jnp.full((B, S, H), L.NEG_INF, jnp.float32),
                          ("batch", "seq", "heads"))
    d0 = shard_activation(jnp.zeros((B, S, H), jnp.float32),
                          ("batch", "seq", "heads"))
    a0 = shard_activation(jnp.zeros((B, S, H, Hd), jnp.float32),
                          ("batch", "seq", "heads", "head_dim"))
    (m, den, acc), _ = jax.lax.scan(body, (m0, d0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(den, 1e-30)[..., None]
    return out.astype(q.dtype)


def attend(params, x, positions, cfg: ModelConfig, window: int | None = None,
           kv_block: int = KV_BLOCK):
    """Self-attention over x [B, S, D] (train / prefill)."""
    q, k, v = _project_qkv(params, x, positions, cfg)
    w = cfg.sliding_window if window is None else window
    out = _flash_attend(q, k, v, positions, positions, w, kv_block)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


def decode_attend(params, x, position, cache_k, cache_v, cache_pos, slot,
                  cfg: ModelConfig, window: int | None = None,
                  active: Optional[jax.Array] = None):
    """One-token decode: x [B, 1, D], cache_k/v [B, Sc, KV, Hd].

    cache_pos [B, Sc] holds the absolute position of each cache slot
    (already updated for the CURRENT token at ``slot``; rolling buffers
    for SWA archs reuse slots; empty slots hold a sentinel > pos and are
    masked by causality).  The new k/v are scattered into the cache at
    ``slot`` BEFORE attending — concatenating one token onto a
    kv_seq-sharded cache forces GSPMD to fully rematerialize (all-gather)
    the cache slice per layer (measured 40 GB/token on qwen3 decode_32k);
    the in-place write touches one shard and attention runs flash-decode
    style with the softmax reducing over the sharded seq axis.

    ``active`` [B] bool (serving slot mask, DESIGN.md §16): lanes with
    active=False keep their cache rows bitwise-frozen — a retired slot
    in a continuous-batching step never scribbles its KV state, so its
    cache stays exactly what its request left behind until the slot is
    re-admitted.  None means every lane is live (the training-era path,
    bit-identical to pre-§16 behavior).

    Returns (out [B, 1, D], new cache_k, new cache_v).
    """
    q, k, v = _project_qkv(params, x, position, cfg)
    w = cfg.sliding_window if window is None else window
    B, _, H, Hd = q.shape
    KV = k.shape[2]
    groups = H // KV
    old_k, old_v = cache_k, cache_v
    cache_k = jax.vmap(lambda c, s, kn: c.at[s].set(kn[0]))(cache_k, slot, k)
    cache_v = jax.vmap(lambda c, s, vn: c.at[s].set(vn[0]))(cache_v, slot, v)
    if active is not None:
        gate = active.reshape(B, 1, 1, 1)
        cache_k = jnp.where(gate, cache_k, old_k)
        cache_v = jnp.where(gate, cache_v, old_v)
    keys = shard_activation(cache_k, ("batch", "kv_seq", None, None))
    vals = shard_activation(cache_v, ("batch", "kv_seq", None, None))
    scale = jnp.asarray(1.0 / np.sqrt(Hd), q.dtype)
    qg = (q * scale).reshape(B, 1, KV, groups, Hd)  # local reshape (tiny)
    s = jnp.einsum("bsghk,btgk->bsght", qg, keys,
                   preferred_element_type=jnp.float32)
    s = shard_activation(s, ("batch", None, None, None, "kv_seq"))
    bias = L.causal_mask_bias(position, cache_pos, w)  # [B, 1, Sc]
    s = s + bias[:, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bsght,btgk->bsghk", p.astype(q.dtype), vals,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H, Hd).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), keys, vals
