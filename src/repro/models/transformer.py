"""Composable decoder model covering every assigned architecture family.

One ``Model`` object (bound to a ModelConfig) provides:

  init(key)            parameters (nested dict, layers stacked for scan)
  logical_axes()       same pytree of logical-axis-name tuples
  param_dtypes()       same pytree of dtypes (mixed-precision cast targets)
  loss(params, batch)  -> (scalar loss, metrics)  [train shapes]
  forward(...)         -> logits                   [prefill shapes]
  init_cache(batch)    decode state (KV / conv+ssm / lru, rolling for SWA)
  cache_logical_axes()
  decode_step(params, cache, tokens, pos) -> (logits, cache)  [decode shapes]

Families:
  dense  — [attn, mlp] x L, one lax.scan over stacked layer params
  moe    — [attn, moe_ffn] x L
  ssm    — [mamba] x L (attention-free)
  hybrid — scan over (recurrent, recurrent, attention) periods + tail
  vlm    — dense backbone; stub frontend projects precomputed patch embeds
  audio  — dense backbone over summed EnCodec codebook embeddings,
           one LM head per codebook
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.sharding_ctx import shard_activation

INT_SENTINEL = np.iinfo(np.int32).max
VOCAB_PAD_MULTIPLE = 16  # pad odd vocab tables so TP sharding divides


# ---------------------------------------------------------------------------
# per-layer blocks


def _init_block(key, cfg: ModelConfig):
    """One decoder layer (dense / moe families)."""
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_rms_norm(cfg.d_model),
        "ln2": L.init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
    }
    if cfg.family in ("moe",):
        p["moe"] = moe_lib.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def _block_axes(cfg: ModelConfig):
    p = {"ln1": ("embed",), "ln2": ("embed",),
         "attn": attn.attention_axes(cfg)}
    if cfg.family in ("moe",):
        p["moe"] = moe_lib.moe_axes(cfg)
    else:
        p["mlp"] = L.mlp_axes(cfg)
    return p


def _apply_block(p, x, positions, cfg: ModelConfig):
    """One decoder layer -> (x, aux, (k, v)).  The per-layer (k, v) are
    what ``attend`` already projects; serving prefill (§16) stacks them
    into the decode cache, the train/loss path discards them."""
    h, kv = attn.attend(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                        positions, cfg)
    x = x + h
    xin = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family in ("moe",):
        h, aux = moe_lib.moe_ffn(p["moe"], xin, cfg)
    else:
        h, aux = L.mlp(p["mlp"], xin, cfg), 0.0
    return x + h, aux, kv


def _init_mamba_layer(key, cfg: ModelConfig):
    return {"ln": L.init_rms_norm(cfg.d_model),
            "mamba": ssm_lib.init_mamba(key, cfg)}


def _init_rec_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms_norm(cfg.d_model),
            "rglru": rglru_lib.init_rglru(k1, cfg),
            "ln2": L.init_rms_norm(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg)}


def _rec_layer_axes(cfg):
    return {"ln1": ("embed",), "rglru": rglru_lib.rglru_axes(cfg),
            "ln2": ("embed",), "mlp": L.mlp_axes(cfg)}


def _apply_rec_layer(p, x, cfg: ModelConfig):
    x = x + rglru_lib.rglru_block(p["rglru"],
                                  L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
    x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x


def _init_attn_layer(key, cfg: ModelConfig):
    """Hybrid attention layer (local window) with its own MLP."""
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms_norm(cfg.d_model),
            "attn": attn.init_attention(k1, cfg),
            "ln2": L.init_rms_norm(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg)}


def _attn_layer_axes(cfg):
    return {"ln1": ("embed",), "attn": attn.attention_axes(cfg),
            "ln2": ("embed",), "mlp": L.mlp_axes(cfg)}


def _apply_attn_layer(p, x, positions, cfg: ModelConfig, window: int):
    h, _ = attn.attend(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                       positions, cfg, window=window)
    x = x + h
    x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x


# ---------------------------------------------------------------------------
# stacking helpers


def _stacked_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _add_layer_axis(axes_tree):
    return jax.tree.map(lambda t: ("layers",) + tuple(t), axes_tree,
                        is_leaf=lambda t: isinstance(t, tuple))


def _gate_cache(new, old, active, batch_axis: int):
    """Per-lane cache freeze (§16): where ``active`` [B] is False the
    OLD leaf value survives bitwise.  Works on a pytree whose every leaf
    carries the batch dim at ``batch_axis``; active=None is a no-op."""
    if active is None:
        return new

    def gate(n, o):
        shape = [1] * n.ndim
        shape[batch_axis] = -1
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree.map(gate, new, old)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # Megatron-style "make vocab divisible": granite's 49155 would
        # otherwise force a replicated LM head / embedding (DESIGN.md §4)
        self.padded_vocab = -(-cfg.vocab_size // VOCAB_PAD_MULTIPLE) \
            * VOCAB_PAD_MULTIPLE
        if cfg.family == "hybrid":
            period = len(cfg.rglru.pattern)
            self.n_periods = cfg.num_layers // period
            self.n_tail = cfg.num_layers - self.n_periods * period
            # decode path assumes any partial tail period is recurrent-only
            assert all(cfg.rglru.pattern[i] == "recurrent"
                       for i in range(self.n_tail)), cfg.rglru.pattern

    # ------------------------------------------------------------- init

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        kemb, klay, khead, ktail, kproj = jax.random.split(key, 5)
        dtype = jnp.dtype(cfg.emb_dtype)
        params: Dict[str, Any] = {"final_ln": L.init_rms_norm(cfg.d_model)}

        V = self.padded_vocab
        if cfg.family == "audio":
            params["embed"] = L.embed_init(
                kemb, (cfg.num_codebooks, V, cfg.d_model), dtype)
            params["heads"] = L.dense_init(
                khead, (cfg.num_codebooks, cfg.d_model, V), -2, dtype)
        else:
            params["embed"] = L.embed_init(kemb, (V, cfg.d_model), dtype)
            if not cfg.tie_embeddings:
                params["head"] = L.dense_init(
                    khead, (cfg.d_model, V), -2, dtype)
        if cfg.family == "vlm":
            params["vision_proj"] = L.dense_init(
                kproj, (cfg.vision_dim, cfg.d_model), -2, dtype)

        if cfg.family == "ssm":
            params["layers"] = _stacked_init(
                lambda k: _init_mamba_layer(k, cfg), klay, cfg.num_layers)
        elif cfg.family == "hybrid":
            def init_period(k):
                ks = jax.random.split(k, len(cfg.rglru.pattern))
                return {
                    f"p{i}": (_init_rec_layer(ks[i], cfg)
                              if kind == "recurrent"
                              else _init_attn_layer(ks[i], cfg))
                    for i, kind in enumerate(cfg.rglru.pattern)
                }
            params["layers"] = _stacked_init(init_period, klay,
                                             self.n_periods)
            if self.n_tail:
                tks = jax.random.split(ktail, self.n_tail)
                params["tail"] = [
                    (_init_rec_layer(tks[i], cfg)
                     if cfg.rglru.pattern[i] == "recurrent"
                     else _init_attn_layer(tks[i], cfg))
                    for i in range(self.n_tail)
                ]
        else:
            params["layers"] = _stacked_init(
                lambda k: _init_block(k, cfg), klay, cfg.num_layers)
        return params

    def logical_axes(self):
        cfg = self.cfg
        axes: Dict[str, Any] = {"final_ln": ("embed",)}
        if cfg.family == "audio":
            axes["embed"] = ("codebooks", "vocab", "embed")
            axes["heads"] = ("codebooks", "embed", "vocab")
        else:
            axes["embed"] = ("vocab", "embed")
            if not cfg.tie_embeddings:
                axes["head"] = ("embed", "vocab")
        if cfg.family == "vlm":
            axes["vision_proj"] = (None, "embed")
        if cfg.family == "ssm":
            lay = {"ln": ("embed",), "mamba": ssm_lib.mamba_axes(cfg)}
        elif cfg.family == "hybrid":
            lay = {
                f"p{i}": (_rec_layer_axes(cfg) if kind == "recurrent"
                          else _attn_layer_axes(cfg))
                for i, kind in enumerate(cfg.rglru.pattern)
            }
        else:
            lay = _block_axes(cfg)
        axes["layers"] = _add_layer_axis(lay)
        if cfg.family == "hybrid" and self.n_tail:
            axes["tail"] = [
                (_rec_layer_axes(cfg) if cfg.rglru.pattern[i] == "recurrent"
                 else _attn_layer_axes(cfg))
                for i in range(self.n_tail)
            ]
        return axes

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_dtypes(self):
        return jax.tree.map(lambda s: s.dtype, self.param_shapes())

    # ------------------------------------------------------------- embed

    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        if cfg.family == "audio":
            # tokens [B, K, S]; sum codebook embeddings
            x = jnp.sum(jax.vmap(
                lambda emb, tok: emb[tok], in_axes=(0, 1), out_axes=1)(
                    params["embed"], tokens), axis=1)
        else:
            x = params["embed"][tokens]
        if cfg.scale_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        x = x.astype(jnp.dtype(cfg.dtype))
        return shard_activation(x, ("batch", "seq", "embed"))

    def _lm_logits(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
        if cfg.family == "audio":
            logits = jnp.einsum("bsd,kdv->bksv", x, params["heads"])
        elif cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = x @ params["head"]
        logits = L.softcap(logits.astype(jnp.float32), cfg.logits_softcap)
        if self.padded_vocab != cfg.vocab_size:  # mask pad rows out of CE
            iota = jnp.arange(self.padded_vocab)
            logits = jnp.where(iota < cfg.vocab_size, logits, L.NEG_INF)
        return logits

    # ------------------------------------------------------------- forward

    def _backbone(self, params, x, positions):
        """x [B, S, D] -> (x, aux_loss)."""
        cfg = self.cfg
        remat = cfg.remat == "block"

        if cfg.family == "ssm":
            def body(carry, p):
                h = carry + ssm_lib.mamba_block(
                    p["mamba"],
                    L.rms_norm(carry, p["ln"], cfg.norm_eps), cfg)
                return h, 0.0
            body = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(body, x, params["layers"])
            return x, 0.0

        if cfg.family == "hybrid":
            def body(carry, p):
                h = carry
                for i, kind in enumerate(cfg.rglru.pattern):
                    if kind == "recurrent":
                        h = _apply_rec_layer(p[f"p{i}"], h, cfg)
                    else:
                        h = _apply_attn_layer(p[f"p{i}"], h, positions, cfg,
                                              cfg.rglru.attention_window)
                return h, 0.0
            body = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(body, x, params["layers"])
            for i in range(self.n_tail):
                p = params["tail"][i]
                if cfg.rglru.pattern[i] == "recurrent":
                    x = _apply_rec_layer(p, x, cfg)
                else:
                    x = _apply_attn_layer(p, x, positions, cfg,
                                          cfg.rglru.attention_window)
            return x, 0.0

        def body(carry, p):
            h, aux, _ = _apply_block(p, carry, positions, cfg)
            return h, aux
        body = jax.checkpoint(body) if remat else body
        x, auxs = jax.lax.scan(body, x, params["layers"])
        return x, jnp.sum(auxs)

    def _backbone_kv(self, params, x, positions):
        """Dense/moe/vlm/audio backbone that also stacks each layer's
        projected (k, v) — [L, B, S, KV, Hd] — for the §16 serving
        prefill.  No remat: prefill is forward-only."""
        cfg = self.cfg

        def body(carry, p):
            h, aux, (k, v) = _apply_block(p, carry, positions, cfg)
            return h, (aux, k, v)

        x, (auxs, ks, vs) = jax.lax.scan(body, x, params["layers"])
        return x, ks, vs

    def forward(self, params, batch) -> jax.Array:
        """Full-sequence logits (train / prefill)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        B, S = x.shape[0], x.shape[1]
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype)  # [B, P, vision_dim]
            px = patches @ params["vision_proj"]
            x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
            S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux = self._backbone(params, x, positions)
        if cfg.family == "vlm":
            x = x[:, patches.shape[1]:]
        return self._lm_logits(params, x), aux

    def prefill(self, params, batch) -> jax.Array:
        """Serving prefill: backbone over the full prompt, logits for the
        LAST position only (next-token sampling semantics) — the full
        [B, S, V] logit tensor is never needed when serving."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        B = x.shape[0]
        if cfg.family == "vlm":
            px = batch["patches"].astype(x.dtype) @ params["vision_proj"]
            x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, _ = self._backbone(params, x, positions)
        return self._lm_logits(params, x[:, -1:])

    def loss(self, params, batch,
             chunk: Optional[int] = None
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token CE with seq-chunked logits.

        The LM head is applied per sequence chunk inside a scan so the
        [B, S, V] logits (0.5-4 TB fp32 for the 256k-vocab train cells)
        are never materialized — peak extra memory is [B, chunk, V].
        """
        chunk = chunk or self.cfg.loss_chunk
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        B = x.shape[0]
        if cfg.family == "vlm":
            px = batch["patches"].astype(x.dtype) @ params["vision_proj"]
            x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux = self._backbone(params, x, positions)
        if cfg.family == "vlm":
            x = x[:, batch["patches"].shape[1]:]
        ce = self._ce_from_hidden(params, x, tokens, chunk)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux,
                      "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}

    def _ce_from_hidden(self, params, x, tokens,
                        chunk: Optional[int] = None) -> jax.Array:
        """Chunked next-token CE given backbone output x [B, S, D].

        Factored out of ``loss`` so the last pipeline stage
        (train/state.py) can seed CE from its local activations without
        re-running the embedding path."""
        chunk = chunk or self.cfg.loss_chunk
        cfg = self.cfg
        B = x.shape[0]
        if cfg.family == "audio":  # targets [B, K, S]
            tg = tokens[:, :, 1:]
            xs = x[:, :-1]
        else:
            tg = tokens[:, 1:]
            xs = x[:, :-1]
        Sm1 = xs.shape[1]
        nb = -(-Sm1 // chunk)
        pad = nb * chunk - Sm1
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        tgp = jnp.pad(tg, ((0, 0),) * (tg.ndim - 1) + ((0, pad),))
        mask = jnp.pad(jnp.ones((B, Sm1), jnp.float32),
                       ((0, 0), (0, pad)))

        def ce_chunk(carry, idx):
            sl = jax.lax.dynamic_slice_in_dim(xs, idx * chunk, chunk, 1)
            logits = self._lm_logits(params, sl)  # fp32, [B,(K),chunk,V]
            msl = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, 1)
            if cfg.family == "audio":
                tsl = jax.lax.dynamic_slice_in_dim(tgp, idx * chunk, chunk,
                                                   2)
                logp = jax.nn.log_softmax(logits, axis=-1)
                nll = -jnp.take_along_axis(logp, tsl[..., None],
                                           axis=-1)[..., 0]
                nll = jnp.mean(nll, axis=1)  # average codebooks
            else:
                tsl = jax.lax.dynamic_slice_in_dim(tgp, idx * chunk, chunk,
                                                   1)
                logp = jax.nn.log_softmax(logits, axis=-1)
                nll = -jnp.take_along_axis(logp, tsl[..., None],
                                           axis=-1)[..., 0]
            return carry + jnp.sum(nll * msl), None

        total, _ = jax.lax.scan(jax.checkpoint(ce_chunk), jnp.float32(0.0),
                                jnp.arange(nb))
        return total / (B * Sm1)

    # ------------------------------------------------------------- decode

    def cache_len(self, seq_len: int) -> int:
        """Decode-state length actually required (rolling for SWA/local)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0
        if cfg.sliding_window:
            return min(seq_len, cfg.sliding_window)
        if cfg.family == "hybrid":
            return min(seq_len, cfg.rglru.attention_window)
        return seq_len

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        hd = cfg.resolved_head_dim
        kv = attn.padded_heads(cfg)[1] if cfg.family != "ssm" \
            else cfg.num_kv_heads
        clen = self.cache_len(seq_len)
        if cfg.family == "ssm":
            return {
                "ssm": jax.vmap(lambda _: ssm_lib.init_mamba_cache(
                    cfg, batch, dtype))(jnp.arange(cfg.num_layers)),
            }
        if cfg.family == "hybrid":
            n_attn_per_period = sum(
                1 for k in cfg.rglru.pattern if k == "attention")
            n_rec_per_period = len(cfg.rglru.pattern) - n_attn_per_period
            cache = {
                "rec": jax.vmap(lambda _: {
                    f"r{i}": rglru_lib.init_rglru_cache(cfg, batch, dtype)
                    for i in range(n_rec_per_period)})(
                        jnp.arange(self.n_periods)),
                "k": jnp.zeros((self.n_periods, n_attn_per_period, batch,
                                clen, kv, hd), dtype),
                "v": jnp.zeros((self.n_periods, n_attn_per_period, batch,
                                clen, kv, hd), dtype),
                "kpos": jnp.full((batch, clen), INT_SENTINEL, jnp.int32),
            }
            if self.n_tail:
                cache["tail"] = [
                    rglru_lib.init_rglru_cache(cfg, batch, dtype)
                    for i in range(self.n_tail)
                    if cfg.rglru.pattern[i] == "recurrent"
                ]
            return cache
        return {
            "k": jnp.zeros((cfg.num_layers, batch, clen, kv, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, clen, kv, hd), dtype),
            "kpos": jnp.full((batch, clen), INT_SENTINEL, jnp.int32),
        }

    def prefill_cache(self, params, batch, cache_len: int, lengths=None):
        """ONE-launch serving prefill (DESIGN.md §16): run the whole
        (right-padded) prompt through the backbone once, returning
        ``(last-real-token logits, populated decode cache)`` — the cache
        has the structure of ``init_cache(B, cache_len)`` and is ready
        for ``decode_step`` at ``pos = length``.

        ``lengths`` [B] int32 gives each lane's true prompt length
        (None = every token of the padded batch is real).  Ragged lanes
        are exact: pad positions never enter the cache (their ``kpos``
        stays the sentinel, so causal masking excludes them — and with a
        rolling window each lane keeps its OWN last ``cache_len`` real
        positions, not the padded batch's), and the returned logits are
        gathered at ``lengths - 1`` per lane.

        dense/moe/vlm/audio run the parallel flash-prefill backbone with
        the per-layer (k, v) stacked straight into the cache — one
        compiled program for the whole prompt instead of ``prompt_len``
        decode launches.  ssm/hybrid (recurrences, not KV tables) fall
        back to one compiled ``lax.scan`` of ``decode_step`` over the
        prompt: still a single launch, bitwise-identical to the streamed
        decode loop it replaces.

        Parity contract: matches a streamed decode loop iff the rolling
        cache never discards a position still inside the attention
        window — i.e. ``cache_len >= prompt_len`` for full attention
        (SWA archs clamp to their window via ``cache_len()``, which is
        lossless).  A smaller cache is a *different* (truncated-context)
        model in both paths and they diverge; the serving engine always
        sizes ``cache_len = prompt_pad + max_gen``.
        """
        cfg = self.cfg
        if cfg.family in ("ssm", "hybrid"):
            return self._prefill_scan(params, batch, cache_len, lengths)
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        B = x.shape[0]
        if cfg.family == "vlm":
            assert lengths is None, \
                "ragged prompts are not supported for vlm prefill"
            px = batch["patches"].astype(x.dtype) @ params["vision_proj"]
            x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, ks, vs = self._backbone_kv(params, x, positions)

        # slot-centric scatter: slot s of lane b holds that lane's newest
        # real position p ≡ s (mod clen), exactly what a streamed decode
        # loop would have left behind (rolling writes at pos % clen)
        clen = self.cache_len(cache_len)
        L_real = jnp.full((B,), S, jnp.int32) if lengths is None \
            else lengths.astype(jnp.int32)
        s_idx = jnp.arange(clen, dtype=jnp.int32)
        q = (L_real[:, None] - 1 - s_idx[None, :]) // clen  # [B, clen]
        win = s_idx[None, :] + clen * q
        has = win >= 0
        src = jnp.clip(win, 0, S - 1)
        gather_idx = src[None, :, :, None, None]
        mask5 = has[None, :, :, None, None]
        new_k = jnp.where(mask5, jnp.take_along_axis(ks, gather_idx, axis=2),
                          0).astype(jnp.dtype(cfg.dtype))
        new_v = jnp.where(mask5, jnp.take_along_axis(vs, gather_idx, axis=2),
                          0).astype(jnp.dtype(cfg.dtype))
        kpos = jnp.where(has, win, INT_SENTINEL).astype(jnp.int32)

        if lengths is None:
            x_last = x[:, -1:]
        else:
            idx = (L_real - 1).reshape(B, 1, 1)
            x_last = jnp.take_along_axis(x, idx, axis=1)
        return self._lm_logits(params, x_last), \
            {"k": new_k, "v": new_v, "kpos": kpos}

    def _prefill_scan(self, params, batch, cache_len: int, lengths=None):
        """Prefill fallback for recurrent-state families: one compiled
        scan of decode_step over the prompt, active-masked past each
        lane's true length so pad steps freeze the state bitwise."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape[0], tokens.shape[-1]
        cache = self.init_cache(B, cache_len)
        V = self.padded_vocab
        shape = (B, cfg.num_codebooks, 1, V) if cfg.family == "audio" \
            else (B, 1, V)
        last0 = jnp.zeros(shape, jnp.float32)
        L_real = None if lengths is None else lengths.astype(jnp.int32)

        def body(carry, t):
            cache, last = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=-1)
            pos = jnp.full((B, 1), t, jnp.int32)
            act = None if L_real is None else (t < L_real)
            logits, cache = self.decode_step(params, cache, tok, pos,
                                             active=act)
            if L_real is None:
                last = jnp.where(t == S - 1, logits, last)
            else:
                cond = (t == L_real - 1).reshape(
                    (B,) + (1,) * (logits.ndim - 1))
                last = jnp.where(cond, logits, last)
            return (cache, last), None

        (cache, last), _ = jax.lax.scan(body, (cache, last0),
                                        jnp.arange(S, dtype=jnp.int32))
        return last, cache

    def decode_step(self, params, cache, tokens, pos, active=None):
        """tokens [B, 1] ([B, K, 1] audio); pos [B, 1] absolute position.

        Returns (logits for the new token, updated cache).  Rolling caches
        write at slot pos % window.

        ``active`` [B] bool is the serving slot mask (DESIGN.md §16):
        inactive lanes run as dead compute in the fixed-capacity batch
        but leave EVERY cache leaf bitwise-frozen — KV rows, kpos, SSM /
        RG-LRU state — so a retired or not-yet-admitted slot can never
        scribble state that a later request would observe.  Their logits
        are garbage by construction; callers (serving/engine.py) mask
        them at the sampling layer.  active=None is the pre-§16
        every-lane-live path, bit-identical to before.
        """
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)  # audio sums codebooks
        if cfg.family == "ssm":
            def body(carry, inp):
                h = carry
                p, c = inp
                y, c2 = ssm_lib.mamba_decode_step(
                    p["mamba"], L.rms_norm(h, p["ln"], cfg.norm_eps), c, cfg)
                return h + y, c2
            x, new_ssm = jax.lax.scan(body, x,
                                      (params["layers"], cache["ssm"]))
            new_cache = {"ssm": _gate_cache(new_ssm, cache["ssm"], active,
                                            batch_axis=1)}
            return self._lm_logits(params, x), new_cache

        if cfg.family == "hybrid":
            return self._decode_hybrid(params, cache, x, pos, active)

        clen = cache["k"].shape[2]
        slot = (pos[:, 0] % clen).astype(jnp.int32)  # [B]
        new_kpos = jax.vmap(
            lambda kp, s, p: kp.at[s].set(p))(cache["kpos"], slot, pos[:, 0])
        new_kpos = _gate_cache(new_kpos, cache["kpos"], active, batch_axis=0)

        def body(carry, inp):
            h = carry
            p, ck, cv = inp
            y, ck, cv = attn.decode_attend(
                p["attn"], L.rms_norm(h, p["ln1"], cfg.norm_eps), pos,
                ck, cv, new_kpos, slot, cfg, active=active)
            h = h + y
            hin = L.rms_norm(h, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                ff, _ = moe_lib.moe_ffn(p["moe"], hin, cfg)
            else:
                ff = L.mlp(p["mlp"], hin, cfg)
            h = h + ff
            return h, (ck, cv)

        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        logits = self._lm_logits(params, x)
        return logits, {"k": new_k, "v": new_v, "kpos": new_kpos}

    def _decode_hybrid(self, params, cache, x, pos, active=None):
        cfg = self.cfg
        clen = cache["k"].shape[3]
        slot = (pos[:, 0] % clen).astype(jnp.int32)
        new_kpos = jax.vmap(
            lambda kp, s, p: kp.at[s].set(p))(cache["kpos"], slot, pos[:, 0])
        new_kpos = _gate_cache(new_kpos, cache["kpos"], active, batch_axis=0)

        def body(carry, inp):
            h = carry
            p, crec, ck, cv = inp
            new_rec = {}
            ai = 0
            ri = 0
            for i, kind in enumerate(cfg.rglru.pattern):
                pi = p[f"p{i}"]
                if kind == "recurrent":
                    y, c2 = rglru_lib.rglru_decode_step(
                        pi["rglru"],
                        L.rms_norm(h, pi["ln1"], cfg.norm_eps),
                        crec[f"r{ri}"], cfg)
                    new_rec[f"r{ri}"] = c2
                    ri += 1
                else:
                    y, ck_new, cv_new = attn.decode_attend(
                        pi["attn"], L.rms_norm(h, pi["ln1"], cfg.norm_eps),
                        pos, ck[ai], cv[ai], new_kpos, slot, cfg,
                        window=cfg.rglru.attention_window, active=active)
                    ck = ck.at[ai].set(ck_new)
                    cv = cv.at[ai].set(cv_new)
                    ai += 1
                h = h + y
                h = h + L.mlp(pi["mlp"],
                              L.rms_norm(h, pi["ln2"], cfg.norm_eps), cfg)
            return h, (new_rec, ck, cv)

        x, (new_rec, new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], cache["rec"], cache["k"],
                      cache["v"]))
        new_rec = _gate_cache(new_rec, cache["rec"], active, batch_axis=1)
        new_cache = {"rec": new_rec, "k": new_k, "v": new_v,
                     "kpos": new_kpos}
        ti = 0
        new_tail = []
        for i in range(self.n_tail):
            p = params["tail"][i]
            if cfg.rglru.pattern[i] == "recurrent":
                y, c2 = rglru_lib.rglru_decode_step(
                    p["rglru"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                    cache["tail"][ti], cfg)
                new_tail.append(_gate_cache(c2, cache["tail"][ti], active,
                                            batch_axis=0))
                ti += 1
                x = x + y
                x = x + L.mlp(p["mlp"],
                              L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        if self.n_tail:
            new_cache["tail"] = new_tail
        return self._lm_logits(params, x), new_cache


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
