"""RG-LRU recurrent block (RecurrentGemma), diagonal gated linear recurrence.

  r_t = sigmoid(x_t W_a + b_a)            (recurrence gate)
  i_t = sigmoid(x_t W_x + b_x)            (input gate)
  a_t = exp(c * softplus(Lambda) * (-r_t))   with c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is diagonal, so the whole sequence runs as one
``lax.associative_scan`` over (a, b) pairs — O(log S) depth, activation
memory O(B * S * W) like any other layer.  The full residual block is
conv1d -> RG-LRU -> gated output (the "Hawk"/Griffin recurrent block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L

_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d, w, dc = cfg.d_model, _width(cfg), cfg.rglru.conv_dim
    ks = jax.random.split(key, 6)
    return {
        "in_x": L.dense_init(ks[0], (d, w), -2, dtype),
        "in_gate": L.dense_init(ks[1], (d, w), -2, dtype),
        "conv_w": L.dense_init(ks[2], (dc, w), -2, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": L.dense_init(ks[3], (w, w), -2, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": L.dense_init(ks[4], (w, w), -2, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 2.0, jnp.float32),  # softplus(2) ~ 2.1
        "out_proj": L.dense_init(ks[5], (w, d), -2, dtype),
    }


def rglru_axes(cfg: ModelConfig):
    return {
        "in_x": ("embed", "mlp"), "in_gate": ("embed", "mlp"),
        "conv_w": (None, "mlp"), "conv_b": ("mlp",),
        "w_a": ("mlp", None), "b_a": ("mlp",),
        "w_i": ("mlp", None), "b_i": ("mlp",),
        "lam": ("mlp",), "out_proj": ("mlp", "embed"),
    }


def _gates(params, xc):
    r = jax.nn.sigmoid((xc @ params["w_a"]).astype(jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid((xc @ params["w_i"]).astype(jnp.float32)
                       + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xc.astype(jnp.float32))
    return a, b


def rglru_block(params, x, cfg: ModelConfig):
    """x [B, S, D] -> y [B, S, D] (training / prefill)."""
    B, S, D = x.shape
    dc = cfg.rglru.conv_dim
    xin = x @ params["in_x"]
    gate = x @ params["in_gate"]
    xpad = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S, :] * params["conv_w"][i] for i in range(dc))
    xc = (xc + params["conv_b"]).astype(x.dtype)
    a, b = _gates(params, xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h * jax.nn.gelu(gate.astype(jnp.float32))
    return y.astype(x.dtype) @ params["out_proj"]


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w, dc = _width(cfg), cfg.rglru.conv_dim
    return {
        "conv": jnp.zeros((batch, dc - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode_step(params, x, cache, cfg: ModelConfig):
    """x [B, 1, D] + cache -> (y [B, 1, D], new cache)."""
    xin = x @ params["in_x"]  # [B, 1, w]
    gate = x @ params["in_gate"]
    win = jnp.concatenate([cache["conv"], xin.astype(cache["conv"].dtype)],
                          axis=1)
    xc = jnp.einsum("bcd,cd->bd", win, params["conv_w"]) + params["conv_b"]
    xc = xc.astype(x.dtype)[:, None, :]
    a, b = _gates(params, xc)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None, :] * jax.nn.gelu(gate.astype(jnp.float32))
    out = y.astype(x.dtype) @ params["out_proj"]
    return out, {"conv": win[:, 1:], "h": h}
