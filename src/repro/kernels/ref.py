"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition with no tiling — used by the
per-kernel allclose sweeps in tests/test_kernels.py and as the CPU
fallback path inside ops.py.

Accumulation semantics (DESIGN.md §9): the oracles reproduce the kernels'
mixed-precision contract exactly — dots accumulate fp32 regardless of the
operand dtype (``preferred_element_type``), epilogues (C-add, alpha*I,
trace reductions) run on the fp32 accumulator, and only the tensor that
leaves the kernel rounds back to the operand dtype.  For fp32 operands
this is the plain definition; for bf16 operands it is what the MXU does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_add(A, B, C=None, *, alpha=1.0, beta=0.0):
    """D = alpha * A @ B + beta * C."""
    out = alpha * jnp.matmul(A, B, preferred_element_type=jnp.float32)
    if C is not None and beta != 0.0:
        out = out + beta * C.astype(jnp.float32)
    return out.astype(A.dtype)


def gram(X, *, alpha=1.0, beta=-1.0):
    """R = alpha * I + beta * X^T X (symmetric)."""
    n = X.shape[-1]
    Xt = jnp.swapaxes(X, -1, -2)
    G = jnp.matmul(Xt, X, preferred_element_type=jnp.float32)
    out = alpha * jnp.eye(n, dtype=jnp.float32) + beta * G
    return out.astype(X.dtype)


def _residual(X, Y=None, *, family="polar"):
    """Family residual with the fused kernels' accumulation order: the
    I - <product> epilogue (and the sqrt re-symmetrization) runs on the
    fp32 MXU accumulator, rounding ONCE to the compute dtype."""
    if family == "polar":
        G = jnp.matmul(jnp.swapaxes(X, -1, -2), X,
                       preferred_element_type=jnp.float32)
    elif family == "sign":
        G = jnp.matmul(X, X, preferred_element_type=jnp.float32)
    else:
        G = jnp.matmul(Y, X, preferred_element_type=jnp.float32)
    r32 = jnp.eye(G.shape[-1], dtype=jnp.float32) - G
    if family == "sqrt":
        r32 = 0.5 * (r32 + jnp.swapaxes(r32, -1, -2))
    return r32.astype(X.dtype)


def residual_chain(X, S, max_power: int, *, family="polar", Y=None):
    """(R, t): fused residual + sketched power-trace chain oracle.

    Mirrors fused_iter.residual_chain op for op: the chain consumes the
    ROUNDED compute-dtype R while every trace reduces St (fp32-cast)
    against the fp32 accumulator of R @ V.  Returns R [..., n, n] and
    fp32 traces [..., max_power] for powers 1..max_power.
    """
    R = _residual(X, Y, family=family)
    St = S.T.astype(R.dtype)
    St32 = St.astype(jnp.float32)
    V = jnp.broadcast_to(St, R.shape[:-2] + St.shape)
    traces = []
    for _ in range(max_power):
        Vacc = jnp.matmul(R, V, preferred_element_type=jnp.float32)
        traces.append(jnp.sum(St32 * Vacc, axis=(-2, -1)))
        V = Vacc.astype(R.dtype)
    return R, jnp.stack(traces, axis=-1)


def _horner(X, R, alpha32, coeffs, side):
    x32 = X.astype(jnp.float32)
    acc = alpha32 * x32
    for j in range(len(coeffs) - 1, -1, -1):
        lo = acc.astype(X.dtype)
        prod = (jnp.matmul(lo, R, preferred_element_type=jnp.float32)
                if side == "right"
                else jnp.matmul(R, lo, preferred_element_type=jnp.float32))
        acc = prod + coeffs[j] * x32
    return acc.astype(X.dtype)


def apply_g(X, R, alpha, *, coeffs, Y=None):
    """Fused d-GEMM Horner oracle for X g_d(R; alpha) (+ g_d(R; alpha) Y).

    Mirrors fused_iter.apply_g: the accumulator stays fp32 across all d
    GEMMs (each dot's operand rounds to the compute dtype, the carried
    f_j * X epilogues never do) and the fp32 alpha multiplies the fp32
    accumulator directly — never pre-rounded to the compute dtype.
    """
    a = jnp.asarray(alpha, jnp.float32)
    if a.ndim:
        a = a[..., None, None]
    out = _horner(X, R, a, coeffs, "right")
    if Y is None:
        return out
    return out, _horner(Y, R, a, coeffs, "left")


def warm_tail(X, alphas, *, coeffs, family="polar", Y=None):
    """Fused constant-alpha multi-iteration oracle (one residual + one
    Horner application per alpha, fused accumulation order throughout)."""
    for a in alphas:
        R = _residual(X, Y, family=family)
        a32 = jnp.asarray(a, jnp.float32)
        if family == "sqrt":
            X, Y = (_horner(X, R, a32, coeffs, "right"),
                    _horner(Y, R, a32, coeffs, "left"))
        else:
            X = _horner(X, R, a32, coeffs, "right")
    return (X, Y) if family == "sqrt" else X


def sketch_traces(R, S, max_power: int):
    """t_i = tr(S R^i S^T), i = 0..max_power (fp32).

    Trace epilogues reduce St (fp32-cast) against the fp32 ACCUMULATOR of
    R @ V — not the rounded V' — matching sketch_traces.py, where the
    reduction happens while the fp32 tile is still in VMEM; V' then
    rounds to the compute dtype before feeding the next power.
    """
    St = S.T.astype(R.dtype)
    St32 = St.astype(jnp.float32)
    V = jnp.broadcast_to(St, R.shape[:-2] + St.shape)
    traces = [jnp.sum(St32 * St32) * jnp.ones(R.shape[:-2], jnp.float32)]
    for _ in range(max_power):
        Vacc = jnp.matmul(R, V, preferred_element_type=jnp.float32)
        traces.append(jnp.sum(St32 * Vacc, axis=(-2, -1)))
        V = Vacc.astype(R.dtype)
    return jnp.stack(traces, axis=-1)
