"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition with no tiling — used by the
per-kernel allclose sweeps in tests/test_kernels.py and as the CPU
fallback path inside ops.py.

Accumulation semantics (DESIGN.md §9): the oracles reproduce the kernels'
mixed-precision contract exactly — dots accumulate fp32 regardless of the
operand dtype (``preferred_element_type``), epilogues (C-add, alpha*I,
trace reductions) run on the fp32 accumulator, and only the tensor that
leaves the kernel rounds back to the operand dtype.  For fp32 operands
this is the plain definition; for bf16 operands it is what the MXU does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_add(A, B, C=None, *, alpha=1.0, beta=0.0):
    """D = alpha * A @ B + beta * C."""
    out = alpha * jnp.matmul(A, B, preferred_element_type=jnp.float32)
    if C is not None and beta != 0.0:
        out = out + beta * C.astype(jnp.float32)
    return out.astype(A.dtype)


def gram(X, *, alpha=1.0, beta=-1.0):
    """R = alpha * I + beta * X^T X (symmetric)."""
    n = X.shape[-1]
    Xt = jnp.swapaxes(X, -1, -2)
    G = jnp.matmul(Xt, X, preferred_element_type=jnp.float32)
    out = alpha * jnp.eye(n, dtype=jnp.float32) + beta * G
    return out.astype(X.dtype)


def sketch_traces(R, S, max_power: int):
    """t_i = tr(S R^i S^T), i = 0..max_power (fp32).

    Trace epilogues reduce St (fp32-cast) against the fp32 ACCUMULATOR of
    R @ V — not the rounded V' — matching sketch_traces.py, where the
    reduction happens while the fp32 tile is still in VMEM; V' then
    rounds to the compute dtype before feeding the next power.
    """
    St = S.T.astype(R.dtype)
    St32 = St.astype(jnp.float32)
    V = jnp.broadcast_to(St, R.shape[:-2] + St.shape)
    traces = [jnp.sum(St32 * St32) * jnp.ones(R.shape[:-2], jnp.float32)]
    for _ in range(max_power):
        Vacc = jnp.matmul(R, V, preferred_element_type=jnp.float32)
        traces.append(jnp.sum(St32 * Vacc, axis=(-2, -1)))
        V = Vacc.astype(R.dtype)
    return jnp.stack(traces, axis=-1)
