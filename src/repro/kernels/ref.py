"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition with no tiling — used by the
per-kernel allclose sweeps in tests/test_kernels.py and as the CPU
fallback path inside ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_add(A, B, C=None, *, alpha=1.0, beta=0.0):
    """D = alpha * A @ B + beta * C."""
    out = alpha * jnp.matmul(A, B, preferred_element_type=jnp.float32)
    if C is not None and beta != 0.0:
        out = out + beta * C.astype(jnp.float32)
    return out.astype(A.dtype)


def gram(X, *, alpha=1.0, beta=-1.0):
    """R = alpha * I + beta * X^T X (symmetric)."""
    n = X.shape[-1]
    Xt = jnp.swapaxes(X, -1, -2)
    G = jnp.matmul(Xt, X, preferred_element_type=jnp.float32)
    out = alpha * jnp.eye(n, dtype=jnp.float32) + beta * G
    return out.astype(X.dtype)


def sketch_traces(R, S, max_power: int):
    """t_i = tr(S R^i S^T), i = 0..max_power (fp32)."""
    St = S.T.astype(R.dtype)
    V = jnp.broadcast_to(St, R.shape[:-2] + St.shape)
    traces = [jnp.sum(St * St, dtype=jnp.float32)
              * jnp.ones(R.shape[:-2], jnp.float32)]
    for _ in range(max_power):
        V = jnp.matmul(R, V, preferred_element_type=jnp.float32).astype(R.dtype)
        traces.append(jnp.sum(St * V, axis=(-2, -1), dtype=jnp.float32))
    return jnp.stack(traces, axis=-1)
