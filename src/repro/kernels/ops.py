"""jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * on TPU backends the compiled Mosaic kernels run natively;
  * on CPU (this container) ``interpret=True`` executes the kernel bodies
    in Python for correctness validation, or — when ``REPRO_KERNEL_MODE=ref``
    or the shapes are large — the pure-jnp oracle in ref.py is used so CPU
    benchmarks aren't dominated by the interpreter.  "Large" is a per-call
    operand-size cutoff on the interpret path: any operand above
    ``REPRO_INTERPRET_MAX_ELEMS`` elements (default 2**21; 0 disables the
    cutoff) falls back to ref, so CPU kernel-validation runs don't crawl
    through the Python interpreter on production-sized buckets.

All wrappers accept leading batch dimensions, which are collapsed into the
single batch-grid dimension of the kernels (DESIGN.md §7): a whole
[B, m, n] parameter bucket is one launch, never a vmap of B 2-D launches.

Precision (DESIGN.md §9): every kernel takes operands in the caller's
compute dtype (fp32 or bf16) and accumulates fp32 on a VMEM scratch;
trace epilogues stay fp32 end-to-end.  The ref.py oracles reproduce the
same accumulation semantics, so dispatch mode never changes the contract.

Fused-iteration tier (DESIGN.md §10): ``residual_chain`` / ``apply_g`` /
``warm_tail`` collapse a fitted iteration to 2 launches and a whole
constant-alpha run to 1.  The tier is chosen at trace time per bucket by
``fused_fits`` — a pure shape test against the VMEM budget
(REPRO_VMEM_BUDGET / config ``vmem_budget``), independent of the batch
size because the batch dim is the streamed grid dimension.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import fused_iter as _fused
from repro.kernels import gram as _gram
from repro.kernels import matmul_add as _mma
from repro.kernels import ref as _ref
from repro.kernels import sketch_traces as _sk

_LANE = 128  # TPU lane width: sketch dim padded up to this
_DEFAULT_INTERPRET_MAX_ELEMS = 1 << 21
# Fused-tier VMEM budget (DESIGN.md §10): ~16 MiB/core minus headroom for
# the grid pipeline's double buffering.  Override via REPRO_VMEM_BUDGET
# or the PrismConfig/OptimizerConfig ``vmem_budget`` knob.
_DEFAULT_VMEM_BUDGET = 12 << 20


def vmem_budget(override: int = 0) -> int:
    """Effective VMEM budget in bytes: config override > env > default."""
    if override:
        return int(override)
    return int(os.environ.get("REPRO_VMEM_BUDGET", _DEFAULT_VMEM_BUDGET))


def fused_vmem_bytes(mshape, dtype, *, coupled: bool = False,
                     sketch_pad: int = _LANE) -> int:
    """Modeled per-grid-step VMEM working set of the fused-iteration
    kernels for ONE [m, n] slice (DESIGN.md §10).

    Batch-size independent by construction: the batch dim is the streamed
    grid dimension, so VMEM holds one slice's state at a time.  Counts
    the warm tail's footprint — the largest of the three kernels:
    double-buffered in/out X blocks + the two ping-pong buffers (doubled
    for the coupled family's Y), R plus its fp32 residual accumulator,
    the fp32 Horner accumulator pair, and the chain's St/V lanes.
    """
    import numpy as np

    m, n = int(mshape[-2]), int(mshape[-1])
    item = np.dtype(dtype).itemsize
    M = m + (-m) % _fused._SUBLANE if not coupled and m != n else \
        m + (-m) % _LANE
    N = n + (-n) % _LANE
    per_mat = M * N * item
    mats = 6 * per_mat * (2 if coupled else 1)   # 4 in/out (dbl-buf) + 2 pp
    resid = N * N * (item + 4)                   # R + fp32 accumulator
    horner = 2 * M * N * 4                       # x32 + fp32 Horner acc
    chain = N * sketch_pad * (3 * item + 4)      # St, V, V' + fp32 acc
    return mats + resid + horner + chain


def fused_fits(mshape, dtype, *, coupled: bool = False,
               budget: int = 0) -> bool:
    """Trace-time fused-tier choice for a bucket of [m, n] matrices."""
    return fused_vmem_bytes(mshape, dtype, coupled=coupled) <= \
        vmem_budget(budget)


def polar_flops(mshape, *, iters: int, degree: int = 2) -> int:
    """Modeled GEMM FLOPs of the cubic polar path on one [m, n] view.

    Per NS iteration (polar transposes to m >= n, Gram side n): the Gram
    residual X^T X (2 m n^2), the (degree-1) [n, n] Horner GEMMs
    (2 n^3 each) and the [m, n] x [n, n] apply (2 m n^2).  The sketch
    chain's O(n^2 p) is omitted — negligible by construction (§6).
    """
    m, n = int(mshape[-2]), int(mshape[-1])
    m, n = max(m, n), min(m, n)
    return iters * (4 * m * n * n + 2 * (degree - 1) * n ** 3)


def lowrank_polar_flops(mshape, l: int, *, iters: int, degree: int = 2,
                        power_iters: int = 1) -> int:
    """Modeled GEMM FLOPs of the §14 lowrank tier on one [m, n] view:
    sketch product + power iterations + project + lift (O(mnl) each),
    plus the two l-Gram-side NS chains (rangefinder polar on [m, l], the
    fitted subspace polar on [l, n])."""
    m, n = int(mshape[-2]), int(mshape[-1])
    m, n = max(m, n), min(m, n)
    l = int(l)
    products = (2 + 2 * power_iters) * 2 * m * n * l  # sketch+power+B+lift
    q_chain = polar_flops((m, l), iters=iters, degree=degree)
    sub_chain = polar_flops((n, l), iters=iters, degree=degree)
    return products + q_chain + sub_chain


def polar_hbm_bytes(mshape, dtype, *, iters: int) -> int:
    """Modeled HBM traffic of the cubic path: each iteration streams X
    twice (Gram + apply) and R twice (write + Horner read)."""
    import numpy as np

    m, n = int(mshape[-2]), int(mshape[-1])
    m, n = max(m, n), min(m, n)
    item = np.dtype(dtype).itemsize
    return iters * (2 * m * n + 2 * n * n) * item


def lowrank_polar_hbm_bytes(mshape, l: int, dtype, *, iters: int,
                            power_iters: int = 1) -> int:
    """Modeled HBM traffic of the §14 tier: M streams once per O(mnl)
    product; the chains stream their [m, l] / [l, n] iterates."""
    import numpy as np

    m, n = int(mshape[-2]), int(mshape[-1])
    m, n = max(m, n), min(m, n)
    item = np.dtype(dtype).itemsize
    products = (2 + 2 * power_iters) * (m * n + m * l + n * l) * item
    chains = iters * (2 * (m * l + l * l) + 2 * (n * l + l * l)) * item
    return products + chains


def _gd_coeffs(degree: int):
    """Ascending Taylor coefficients f_0..f_{d-1} of g_d (static floats)."""
    from repro.core import polynomials as poly

    return tuple(float(c) for c in poly.taylor_inv_sqrt(degree - 1))


def _interpret_cutoff() -> int:
    """Max per-operand element count the interpret path accepts; larger
    calls fall back to the ref oracle.  0 (or negative) disables the
    cutoff — benchmarks set that while launch-COUNTING under interpret
    mode, where kernels are only traced, never executed."""
    return int(os.environ.get("REPRO_INTERPRET_MAX_ELEMS",
                              _DEFAULT_INTERPRET_MAX_ELEMS))


def _mode(*operands) -> str:
    env = os.environ.get("REPRO_KERNEL_MODE", "auto")
    mode = env if env != "auto" else \
        ("native" if jax.default_backend() == "tpu" else "ref")
    if mode == "interpret":
        cutoff = _interpret_cutoff()
        if cutoff > 0 and any(a is not None and a.size > cutoff
                              for a in operands):
            return "ref"
    return mode  # "ref" | "interpret" | "native"


def _collapse(lead, *arrays):
    """Reshape shared leading batch dims of each array into one [B, ., .]."""
    size = 1
    for d in lead:
        size *= d
    out = []
    for a in arrays:
        if a is None:
            out.append(None)
        elif a.ndim > 2:
            out.append(a.reshape((size,) + a.shape[a.ndim - 2:]))
        else:  # unbatched operand broadcast against the batch
            out.append(jnp.broadcast_to(a, (size,) + a.shape))
    return out


def matmul_add(A, B, C=None, *, alpha: float = 1.0, beta: float = 0.0,
               bm: int = 256, bn: int = 256, bk: int = 256):
    """D = alpha * A @ B (+ beta * C), batched over leading dims."""
    mode = _mode(A, B, C)
    if mode == "ref":
        return _ref.matmul_add(A, B, C, alpha=alpha, beta=beta)
    interp = mode == "interpret"
    lead = A.shape[:-2]
    if not lead:
        return _mma.matmul_add(A, B, C, alpha=alpha, beta=beta,
                               bm=bm, bn=bn, bk=bk, interpret=interp)
    Ab, Bb, Cb = _collapse(lead, A, B, C)
    out = _mma.matmul_add(Ab, Bb, Cb, alpha=alpha, beta=beta,
                          bm=bm, bn=bn, bk=bk, interpret=interp)
    return out.reshape(lead + out.shape[1:])


def gram(X, *, alpha: float = 1.0, beta: float = -1.0,
         bn: int = 256, bk: int = 256):
    """R = alpha * I + beta * X^T X (symmetric syrk), batched."""
    mode = _mode(X)
    if mode == "ref":
        return _ref.gram(X, alpha=alpha, beta=beta)
    interp = mode == "interpret"
    bn_eff = min(bn, X.shape[-1])
    lead = X.shape[:-2]
    (Xb,) = _collapse(lead, X) if lead else (X,)
    U = _gram.gram_upper(Xb, alpha=alpha, beta=beta, bn=bn, bk=bk,
                         interpret=interp)
    # mirror: diagonal blocks carry alpha*I + full tile; strictly-upper
    # blocks transpose into the lower triangle.
    R = _gram.mirror_upper(U, bn_eff)
    return R.reshape(lead + R.shape[-2:]) if lead else R


def _chain_vmem_bytes(n: int, p: int, dtype, bn: int) -> int:
    """VMEM footprint of the whole-chain kernel: full St plus the two
    [N, p128] ping-pong buffers stay resident across powers, plus the
    double-buffered R tile and the fp32 trace accumulator."""
    bn = min(bn, n)
    N = n + (-n) % bn
    item = jnp.dtype(dtype).itemsize
    return 3 * N * p * item + 2 * bn * bn * item + bn * p * 4


def sketch_traces(R, S, max_power: int, *, bn: int = 256,
                  budget: int = 0):
    """t_i = tr(S R^i S^T), i = 0..max_power; one fused chain launch.

    ``bn`` tiles both the rows and the contraction dim of the chain (they
    must coincide: V's row partition is reused as the contraction
    partition of the next power inside the single launch).

    VMEM guard (DESIGN.md §10): the whole-chain kernel keeps St and two
    V ping-pong buffers — O(n * 128) bytes — resident for the entire
    launch with no size bound.  When that footprint exceeds the VMEM
    budget (``budget`` override, else REPRO_VMEM_BUDGET), the chain
    falls back to a loop of bounded-footprint per-step ``sketch_step``
    launches: max_power launches instead of one, but never an
    over-budget kernel.
    """
    mode = _mode(R)
    if mode == "ref":
        return _ref.sketch_traces(R, S, max_power)
    interp = mode == "interpret"
    p = S.shape[0]
    St = jnp.pad(S.T.astype(R.dtype), ((0, 0), (0, (-p) % _LANE)))
    lead = R.shape[:-2]
    (Rb,) = _collapse(lead, R) if lead else (R[None],)
    t0 = jnp.sum(St.astype(jnp.float32) * St.astype(jnp.float32))
    n = R.shape[-1]
    if _chain_vmem_bytes(n, St.shape[1], R.dtype, bn) <= \
            vmem_budget(budget):
        ts = _sk.sketch_chain(Rb, St, max_power, bn=bn, interpret=interp)
    else:
        V = jnp.broadcast_to(St, Rb.shape[:-2] + St.shape)
        steps = []
        for _ in range(max_power):
            V, t_i = _sk.sketch_step(Rb, V, St, bm=bn, bk=bn,
                                     interpret=interp)
            steps.append(t_i)
        ts = jnp.stack(steps, axis=-1)
    t = jnp.concatenate(
        [jnp.broadcast_to(t0, ts.shape[:-1] + (1,)), ts], axis=-1)
    return t.reshape(lead + (max_power + 1,))


# ---------------------------------------------------------------------------
# Fused-iteration tier (DESIGN.md §10): single-launch residual+chain,
# Horner application, and constant-alpha warm tails
# ---------------------------------------------------------------------------


def residual_chain(X, S, max_power: int, *, family: str = "polar", Y=None):
    """(R, t): the family residual AND the whole sketched power-trace
    chain in ONE launch — R never leaves VMEM before the traces are
    reduced (it reaches HBM once, as the output the Horner launch reads).

    X: [..., m, n]; S: [p, n] sketch; Y: the coupled sqrt family's second
    iterate.  Returns R [..., n, n] (X.dtype) and fp32 traces
    t [..., max_power + 1] for powers 0..max_power (t0 is sketch-only).
    """
    mode = _mode(X, Y)
    S32 = S.astype(jnp.float32)
    t0 = jnp.sum(S32 * S32)
    lead = X.shape[:-2]
    if mode == "ref":
        R, ts = _ref.residual_chain(X, S, max_power, family=family, Y=Y)
    else:
        interp = mode == "interpret"
        p = S.shape[0]
        St = jnp.pad(S.T.astype(X.dtype), ((0, 0), (0, (-p) % _LANE)))
        Xb, Yb = _collapse(lead, X, Y) if lead else \
            (X[None], None if Y is None else Y[None])
        Rb, ts = _fused.residual_chain(Xb, St, max_power, family=family,
                                       Y=Yb, interpret=interp)
        n = Rb.shape[-1]
        R = Rb.reshape(lead + (n, n))
        ts = ts.reshape(lead + (max_power,))
    t = jnp.concatenate(
        [jnp.broadcast_to(t0, ts.shape[:-1] + (1,)), ts], axis=-1)
    return R, t


def apply_g(X, R, alpha, *, degree: int, Y=None):
    """X g_d(R; alpha) (and g_d(R; alpha) Y when coupled) — the d Horner
    GEMMs in ONE launch with the accumulator resident in VMEM and the
    fitted fp32 alpha applied on the fp32 accumulator (never pre-rounded
    to the compute dtype; DESIGN.md §9/§10).

    alpha: scalar or [...] matching X's leading dims, fp32.
    """
    coeffs = _gd_coeffs(degree)
    mode = _mode(X, R, Y)
    if mode == "ref":
        return _ref.apply_g(X, R, alpha, coeffs=coeffs, Y=Y)
    interp = mode == "interpret"
    lead = X.shape[:-2]
    Xb, Rb, Yb = _collapse(lead, X, R, Y) if lead else \
        (X[None], R[None], None if Y is None else Y[None])
    a = jnp.broadcast_to(jnp.asarray(alpha, jnp.float32),
                         lead).reshape(Xb.shape[0])
    out = _fused.apply_g(Xb, Rb, a, coeffs=coeffs, Y=Yb, interpret=interp)
    if Y is None:
        return out.reshape(lead + out.shape[1:])
    return (out[0].reshape(lead + out[0].shape[1:]),
            out[1].reshape(lead + out[1].shape[1:]))


def warm_tail(X, alphas, *, degree: int, family: str = "polar", Y=None):
    """An entire run of constant-alpha iterations in ONE launch: X (and
    Y) ping-pong in VMEM, HBM sees one read + one write of each operand
    for the whole run (DESIGN.md §10).

    alphas: static sequence of per-iteration floats (the PRISM warm value
    u, or classical Taylor coefficients — any static schedule).
    """
    alphas = tuple(float(a) for a in alphas)
    coeffs = _gd_coeffs(degree)
    mode = _mode(X, Y)
    if mode == "ref":
        return _ref.warm_tail(X, alphas, coeffs=coeffs, family=family, Y=Y)
    interp = mode == "interpret"
    lead = X.shape[:-2]
    Xb, Yb = _collapse(lead, X, Y) if lead else \
        (X[None], None if Y is None else Y[None])
    arr = jnp.asarray(alphas, jnp.float32)
    out = _fused.warm_tail(Xb, arr, len(alphas), family=family,
                           coeffs=coeffs, Y=Yb, interpret=interp)
    if family == "sqrt":
        return (out[0].reshape(lead + out[0].shape[1:]),
                out[1].reshape(lead + out[1].shape[1:]))
    return out.reshape(lead + out.shape[1:])


def count_launches(fn, *args) -> int:
    """Pallas launches fn would issue at runtime, counted by tracing.

    Wraps the kernel wrapper functions (each contains exactly one
    pallas_call) and counts call sites during an abstract trace — the
    inner-jit compilation cache dedupes *traces*, not runtime launches,
    so counting wrappers is the accurate launch count.  Observability
    helper for tests and benchmarks (the launch-count contract of
    DESIGN.md §7).
    """
    targets = [(_gram, "gram_upper"), (_mma, "matmul_add"),
               (_sk, "sketch_chain"), (_sk, "sketch_step"),
               (_fused, "residual_chain"), (_fused, "apply_g"),
               (_fused, "warm_tail")]
    counter = {"n": 0}

    def wrap(f):
        def counting(*a, **k):
            counter["n"] += 1
            return f(*a, **k)
        return counting

    saved = [getattr(mod, name) for mod, name in targets]
    for mod, name in targets:
        setattr(mod, name, wrap(getattr(mod, name)))
    try:
        jax.make_jaxpr(fn)(*args)
    finally:
        for (mod, name), f in zip(targets, saved):
            setattr(mod, name, f)
    return counter["n"]
