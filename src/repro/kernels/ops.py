"""jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * on TPU backends the compiled Mosaic kernels run natively;
  * on CPU (this container) ``interpret=True`` executes the kernel bodies
    in Python for correctness validation, or — when ``REPRO_KERNEL_MODE=ref``
    or the shapes are large — the pure-jnp oracle in ref.py is used so CPU
    benchmarks aren't dominated by the interpreter.

All wrappers accept leading batch dimensions and map the 2-D kernels over
them (stacked scanned-layer parameter stacks use this path).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import gram as _gram
from repro.kernels import matmul_add as _mma
from repro.kernels import ref as _ref
from repro.kernels import sketch_traces as _sk

_LANE = 128  # TPU lane width: sketch dim padded up to this


def _mode() -> str:
    env = os.environ.get("REPRO_KERNEL_MODE", "auto")
    if env != "auto":
        return env  # "ref" | "interpret" | "native"
    return "native" if jax.default_backend() == "tpu" else "ref"


def _batched(fn, *arrays, n_batch_args=None):
    """vmap fn over any shared leading batch dims of the first arrays."""
    lead = arrays[0].shape[:-2]
    if not lead:
        return fn(*arrays)
    size = 1
    for d in lead:
        size *= d
    flat = [a.reshape((size,) + a.shape[len(lead):]) if a.ndim > 2 else a
            for a in arrays]
    mapped = jax.vmap(fn, in_axes=tuple(0 if a.ndim > 2 else None
                                        for a in arrays))
    out = mapped(*[f for f in flat])
    return jax.tree.map(lambda o: o.reshape(lead + o.shape[1:]), out)


def matmul_add(A, B, C=None, *, alpha: float = 1.0, beta: float = 0.0,
               bm: int = 256, bn: int = 256, bk: int = 256):
    """D = alpha * A @ B (+ beta * C), batched over leading dims."""
    mode = _mode()
    if mode == "ref":
        return _ref.matmul_add(A, B, C, alpha=alpha, beta=beta)
    interp = mode == "interpret"
    fn = functools.partial(_mma.matmul_add, alpha=alpha, beta=beta,
                           bm=bm, bn=bn, bk=bk, interpret=interp)
    args = (A, B) if C is None else (A, B, C)
    if C is None:
        return _batched(lambda a, b: fn(a, b), A, B)
    return _batched(lambda a, b, c: fn(a, b, C=c), A, B, C)


def gram(X, *, alpha: float = 1.0, beta: float = -1.0,
         bn: int = 256, bk: int = 256):
    """R = alpha * I + beta * X^T X (symmetric syrk), batched."""
    mode = _mode()
    if mode == "ref":
        return _ref.gram(X, alpha=alpha, beta=beta)
    interp = mode == "interpret"
    bn_eff = min(bn, X.shape[-1])

    def one(x):
        U = _gram.gram_upper(x, alpha=alpha, beta=beta, bn=bn, bk=bk,
                             interpret=interp)
        # mirror: diagonal blocks carry alpha*I + full tile; strictly-upper
        # blocks transpose into the lower triangle.
        return _gram.mirror_upper(U, bn_eff)

    return _batched(one, X)


def sketch_traces(R, S, max_power: int, *, bm: int = 256, bk: int = 256):
    """t_i = tr(S R^i S^T), i = 0..max_power; fused chain kernel."""
    mode = _mode()
    if mode == "ref":
        return _ref.sketch_traces(R, S, max_power)
    interp = mode == "interpret"
    p = S.shape[0]
    pad = (-p) % _LANE

    def one(r):
        St = jnp.pad(S.T.astype(r.dtype), ((0, 0), (0, pad)))
        V = St
        t0 = jnp.sum(St.astype(jnp.float32) * St.astype(jnp.float32))
        ts = [t0]
        for _ in range(max_power):
            V, t = _sk.sketch_step(r, V, St, bm=bm, bk=bk, interpret=interp)
            ts.append(t)
        return jnp.stack(ts).astype(jnp.float32)

    return _batched(one, R)
