"""jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * on TPU backends the compiled Mosaic kernels run natively;
  * on CPU (this container) ``interpret=True`` executes the kernel bodies
    in Python for correctness validation, or — when ``REPRO_KERNEL_MODE=ref``
    or the shapes are large — the pure-jnp oracle in ref.py is used so CPU
    benchmarks aren't dominated by the interpreter.  "Large" is a per-call
    operand-size cutoff on the interpret path: any operand above
    ``REPRO_INTERPRET_MAX_ELEMS`` elements (default 2**21; 0 disables the
    cutoff) falls back to ref, so CPU kernel-validation runs don't crawl
    through the Python interpreter on production-sized buckets.

All wrappers accept leading batch dimensions, which are collapsed into the
single batch-grid dimension of the kernels (DESIGN.md §7): a whole
[B, m, n] parameter bucket is one launch, never a vmap of B 2-D launches.

Precision (DESIGN.md §9): every kernel takes operands in the caller's
compute dtype (fp32 or bf16) and accumulates fp32 on a VMEM scratch;
trace epilogues stay fp32 end-to-end.  The ref.py oracles reproduce the
same accumulation semantics, so dispatch mode never changes the contract.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import gram as _gram
from repro.kernels import matmul_add as _mma
from repro.kernels import ref as _ref
from repro.kernels import sketch_traces as _sk

_LANE = 128  # TPU lane width: sketch dim padded up to this
_DEFAULT_INTERPRET_MAX_ELEMS = 1 << 21


def _interpret_cutoff() -> int:
    """Max per-operand element count the interpret path accepts; larger
    calls fall back to the ref oracle.  0 (or negative) disables the
    cutoff — benchmarks set that while launch-COUNTING under interpret
    mode, where kernels are only traced, never executed."""
    return int(os.environ.get("REPRO_INTERPRET_MAX_ELEMS",
                              _DEFAULT_INTERPRET_MAX_ELEMS))


def _mode(*operands) -> str:
    env = os.environ.get("REPRO_KERNEL_MODE", "auto")
    mode = env if env != "auto" else \
        ("native" if jax.default_backend() == "tpu" else "ref")
    if mode == "interpret":
        cutoff = _interpret_cutoff()
        if cutoff > 0 and any(a is not None and a.size > cutoff
                              for a in operands):
            return "ref"
    return mode  # "ref" | "interpret" | "native"


def _collapse(lead, *arrays):
    """Reshape shared leading batch dims of each array into one [B, ., .]."""
    size = 1
    for d in lead:
        size *= d
    out = []
    for a in arrays:
        if a is None:
            out.append(None)
        elif a.ndim > 2:
            out.append(a.reshape((size,) + a.shape[a.ndim - 2:]))
        else:  # unbatched operand broadcast against the batch
            out.append(jnp.broadcast_to(a, (size,) + a.shape))
    return out


def matmul_add(A, B, C=None, *, alpha: float = 1.0, beta: float = 0.0,
               bm: int = 256, bn: int = 256, bk: int = 256):
    """D = alpha * A @ B (+ beta * C), batched over leading dims."""
    mode = _mode(A, B, C)
    if mode == "ref":
        return _ref.matmul_add(A, B, C, alpha=alpha, beta=beta)
    interp = mode == "interpret"
    lead = A.shape[:-2]
    if not lead:
        return _mma.matmul_add(A, B, C, alpha=alpha, beta=beta,
                               bm=bm, bn=bn, bk=bk, interpret=interp)
    Ab, Bb, Cb = _collapse(lead, A, B, C)
    out = _mma.matmul_add(Ab, Bb, Cb, alpha=alpha, beta=beta,
                          bm=bm, bn=bn, bk=bk, interpret=interp)
    return out.reshape(lead + out.shape[1:])


def gram(X, *, alpha: float = 1.0, beta: float = -1.0,
         bn: int = 256, bk: int = 256):
    """R = alpha * I + beta * X^T X (symmetric syrk), batched."""
    mode = _mode(X)
    if mode == "ref":
        return _ref.gram(X, alpha=alpha, beta=beta)
    interp = mode == "interpret"
    bn_eff = min(bn, X.shape[-1])
    lead = X.shape[:-2]
    (Xb,) = _collapse(lead, X) if lead else (X,)
    U = _gram.gram_upper(Xb, alpha=alpha, beta=beta, bn=bn, bk=bk,
                         interpret=interp)
    # mirror: diagonal blocks carry alpha*I + full tile; strictly-upper
    # blocks transpose into the lower triangle.
    R = _gram.mirror_upper(U, bn_eff)
    return R.reshape(lead + R.shape[-2:]) if lead else R


def sketch_traces(R, S, max_power: int, *, bn: int = 256):
    """t_i = tr(S R^i S^T), i = 0..max_power; one fused chain launch.

    ``bn`` tiles both the rows and the contraction dim of the chain (they
    must coincide: V's row partition is reused as the contraction
    partition of the next power inside the single launch).
    """
    mode = _mode(R)
    if mode == "ref":
        return _ref.sketch_traces(R, S, max_power)
    interp = mode == "interpret"
    p = S.shape[0]
    St = jnp.pad(S.T.astype(R.dtype), ((0, 0), (0, (-p) % _LANE)))
    lead = R.shape[:-2]
    (Rb,) = _collapse(lead, R) if lead else (R[None],)
    t0 = jnp.sum(St.astype(jnp.float32) * St.astype(jnp.float32))
    ts = _sk.sketch_chain(Rb, St, max_power, bn=bn, interpret=interp)
    t = jnp.concatenate(
        [jnp.broadcast_to(t0, ts.shape[:-1] + (1,)), ts], axis=-1)
    return t.reshape(lead + (max_power + 1,))


def count_launches(fn, *args) -> int:
    """Pallas launches fn would issue at runtime, counted by tracing.

    Wraps the kernel wrapper functions (each contains exactly one
    pallas_call) and counts call sites during an abstract trace — the
    inner-jit compilation cache dedupes *traces*, not runtime launches,
    so counting wrappers is the accurate launch count.  Observability
    helper for tests and benchmarks (the launch-count contract of
    DESIGN.md §7).
    """
    targets = [(_gram, "gram_upper"), (_mma, "matmul_add"),
               (_sk, "sketch_chain"), (_sk, "sketch_step")]
    counter = {"n": 0}

    def wrap(f):
        def counting(*a, **k):
            counter["n"] += 1
            return f(*a, **k)
        return counting

    saved = [getattr(mod, name) for mod, name in targets]
    for mod, name in targets:
        setattr(mod, name, wrap(getattr(mod, name)))
    try:
        jax.make_jaxpr(fn)(*args)
    finally:
        for (mod, name), f in zip(targets, saved):
            setattr(mod, name, f)
    return counter["n"]
