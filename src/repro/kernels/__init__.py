"""Pallas TPU kernels for the PRISM GEMM hot spots.

  matmul_add    D = alpha A @ B + beta C   (fused Horner step)
  gram          R = alpha I + beta X^T X   (symmetric syrk, half MXU work)
  sketch_traces t_i = tr(S R^i S^T)        (whole chain in ONE launch,
                                            V resident in VMEM, fused
                                            trace epilogues)

All grids carry a leading batch dimension so a [B, m, n] parameter bucket
is one launch (DESIGN.md §7).  ops.py — jit wrappers w/ leading-dim
collapsing + CPU fallback; ref.py — jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
