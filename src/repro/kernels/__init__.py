"""Pallas TPU kernels for the PRISM GEMM hot spots.

  matmul_add    D = alpha A @ B + beta C   (fused Horner step)
  gram          R = alpha I + beta X^T X   (symmetric syrk, half MXU work)
  sketch_traces t_i = tr(S R^i S^T)        (fused chain + trace epilogue)

ops.py — jit wrappers w/ batching + CPU fallback; ref.py — jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
