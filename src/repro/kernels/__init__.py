"""Pallas TPU kernels for the PRISM GEMM hot spots.

  matmul_add     D = alpha A @ B + beta C   (fused Horner step)
  gram           R = alpha I + beta X^T X   (symmetric syrk, half MXU work)
  sketch_traces  t_i = tr(S R^i S^T)        (whole chain in ONE launch,
                                             V resident in VMEM, fused
                                             trace epilogues)
  fused_iter     single-launch fused-iteration tier (DESIGN.md §10):
                 residual + sketch chain in one launch, the d-GEMM Horner
                 application in one launch, and whole constant-alpha warm
                 tails in one launch with X ping-ponging in VMEM

All grids carry a leading batch dimension so a [B, m, n] parameter bucket
is one launch (DESIGN.md §7).  ops.py — jit wrappers w/ leading-dim
collapsing, CPU fallback, and the VMEM-budget tier choice; ref.py — jnp
oracles (including the fused accumulation order).
"""
from repro.kernels import fused_iter, ops, ref

__all__ = ["fused_iter", "ops", "ref"]
