"""Single-launch fused-iteration Pallas TPU kernels (DESIGN.md §10).

The batch-grid kernels of §7 made one fitted PRISM-NS iteration cost a
constant 2+d launches per bucket — but X and R still make a full HBM
round-trip between every launch, and a whole polar call costs
iters*(2+d) launches.  For buckets whose per-slice working set fits a
VMEM budget (the tier choice lives in ``ops.fused_fits``; it depends
only on the matrix shape, never on B), these kernels collapse the
iteration structure itself:

  * ``residual_chain`` — the residual R (I - X^T X for polar, I - X^2
    for sign, sym(I - Y X) for the coupled sqrt family) AND the whole
    sketched power-trace chain in ONE launch, grid (B,).  R is formed on
    the fp32 MXU accumulator, rounded once to the compute dtype, and the
    chain runs on it while it is still in VMEM — R reaches HBM exactly
    once (as the output the Horner launch reads back), instead of once
    per chain power.
  * ``apply_g`` — the d-GEMM Horner application X g_d(R; alpha) (and the
    coupled g_d(R; alpha) Y) in ONE launch, grid (B,).  The Horner
    accumulator stays fp32 in VMEM across all d GEMMs (each dot rounds
    its operand to the compute dtype — that is what the MXU consumes —
    but the carried f_j*X epilogues never round), and the FITTED fp32
    alpha multiplies the fp32 accumulator directly instead of
    pre-rounding to bf16 (DESIGN.md §9: the fit is pinned fp32; this
    keeps it fp32 all the way into the update).
  * ``warm_tail`` — an entire run of constant-alpha iterations (the
    warm-start phase of PRISM, or a whole classical-alpha chain) as ONE
    launch, grid (B, iters): X ping-pongs between two VMEM scratch
    buffers, each grid step computes the residual and the Horner update
    in-register, and X touches HBM exactly twice for the whole run —
    one read, one write — instead of (1+d) launches and 2(1+d) n^2
    round-trips per iteration.  The per-iteration alphas arrive as an
    SMEM vector, so mixed constant schedules fuse too.

Why the fit phase cannot fuse across iterations: alpha_{k+1} is the
argmin of a quartic whose coefficients are the sketched traces of
R_{k+1}, which only exists after update k — the closed-form minimizer
(cubic root selection, interval clamping) runs between launches in XLA.
The warm phase has no such data dependence, which is exactly why it
collapses to one launch.

Padding: wrappers zero-pad X (and the lane-padded sketch St) up to TPU
tile multiples.  Zero padding is exact end-to-end here: pad rows/cols of
X stay identically zero through every update, the residual's pad block
is exactly I with zero coupling, and the chain's trace contributions
from pad rows vanish because St's pad rows are zero (same §7 argument as
pad-to-bucket, applied at tile granularity).  For the coupled family Y's
pad block evolves as a self-contained scalar multiple of I and is sliced
away.

Precision: operands fp32 or bf16; every dot accumulates fp32
(``preferred_element_type``); trace epilogues reduce the fp32
accumulator of R @ V before V rounds (§9).  ref.py carries op-for-op
oracles for the fused accumulation order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SUBLANE = 16   # covers the bf16 (16, 128) min tile; fp32 needs only 8
_LANE = 128

FAMILIES = ("polar", "sign", "sqrt")


def _pad2(n: int, mult: int) -> int:
    return (-n) % mult


def _eye(n: int) -> jax.Array:
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return jnp.where(row == col, jnp.float32(1.0), jnp.float32(0.0))


def _residual32(x, y, family: str):
    """fp32 residual of the family: the I - <product> epilogue runs on the
    fp32 MXU accumulator; callers round once to the compute dtype."""
    if family == "polar":
        g = jax.lax.dot_general(x, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    elif family == "sign":
        g = jnp.dot(x, x, preferred_element_type=jnp.float32)
    else:  # coupled sqrt: R = I - Y X, re-symmetrized for stability
        g = jnp.dot(y, x, preferred_element_type=jnp.float32)
    r32 = _eye(g.shape[0]) - g
    if family == "sqrt":
        r32 = 0.5 * (r32 + r32.T)
    return r32


def _horner32(x, x32, r, alpha32, coeffs, side: str):
    """fp32 Horner accumulator for X g_d(R; a) (side="right") or
    g_d(R; a) Y (side="left"); alpha32 is an fp32 scalar and the carried
    f_j * X epilogues never round — only each dot's operand does."""
    acc = alpha32 * x32
    for j in range(len(coeffs) - 1, -1, -1):
        lo = acc.astype(x.dtype)
        prod = (jnp.dot(lo, r, preferred_element_type=jnp.float32)
                if side == "right"
                else jnp.dot(r, lo, preferred_element_type=jnp.float32))
        acc = prod + coeffs[j] * x32
    return acc


# ---------------------------------------------------------------------------
# (a) fused residual + sketched power-trace chain: one launch per bucket
# ---------------------------------------------------------------------------


def _res_chain_kernel(*refs, family, max_power, coupled):
    if coupled:
        x_ref, y_ref, st_ref, r_ref, t_ref = refs
        y = y_ref[0]
    else:
        x_ref, st_ref, r_ref, t_ref = refs
        y = None
    b = pl.program_id(0)
    x = x_ref[0]
    r = _residual32(x, y, family).astype(r_ref.dtype)
    r_ref[0] = r
    st = st_ref[...]
    st32 = st.astype(jnp.float32)
    v = st
    for i in range(max_power):
        vacc = jnp.dot(r, v, preferred_element_type=jnp.float32)
        t_ref[b, i] = jnp.sum(st32 * vacc)
        v = vacc.astype(st.dtype)


@functools.partial(jax.jit,
                   static_argnames=("max_power", "family", "interpret"))
def residual_chain(X: jax.Array, St: jax.Array, max_power: int,
                   *, family: str = "polar", Y: jax.Array | None = None,
                   interpret: bool = False):
    """(R, t): the family residual of X (and Y) plus t_i = tr(S R^i S^T),
    i = 1..max_power, in ONE launch over the [B, ., .] bucket.

    X: [B, m, n] (polar) or [B, n, n] (sign / sqrt); Y: [B, n, n] for the
    coupled sqrt family; St: [n, p128] (sketch transposed, lane-padded).
    Returns R [B, n, n] in X.dtype and fp32 traces [B, max_power] (the
    i = 0 sketch-only trace is the caller's, as in ops.sketch_traces).
    """
    assert family in FAMILIES, family
    coupled = family == "sqrt"
    nb, m, n = X.shape
    p = St.shape[1]
    np_ = _pad2(n, _LANE)
    # square families: the residual lives on the full matrix, so both dims
    # pad to the lane multiple (m == n there)
    mp = np_ if family != "polar" else _pad2(m, _SUBLANE)
    Xp = jnp.pad(X, ((0, 0), (0, mp), (0, np_)))
    Stp = jnp.pad(St, ((0, np_), (0, 0)))
    N = n + np_
    M = Xp.shape[1]
    operands = [Xp]
    in_specs = [pl.BlockSpec((1, M, N), lambda b: (b, 0, 0))]
    if coupled:
        operands.append(jnp.pad(Y, ((0, 0), (0, np_), (0, np_))))
        in_specs.append(pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)))
    operands.append(Stp)
    in_specs.append(pl.BlockSpec((N, p), lambda b: (0, 0)))
    R, t = pl.pallas_call(
        functools.partial(_res_chain_kernel, family=family,
                          max_power=max_power, coupled=coupled),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, N, N), X.dtype),
            jax.ShapeDtypeStruct((nb, max_power), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return R[:, :n, :n], t


# ---------------------------------------------------------------------------
# (b) fused d-GEMM Horner application: one launch per bucket
# ---------------------------------------------------------------------------


def _apply_kernel(*refs, coeffs, coupled):
    if coupled:
        x_ref, y_ref, r_ref, a_ref, xo_ref, yo_ref = refs
    else:
        x_ref, r_ref, a_ref, xo_ref = refs
    b = pl.program_id(0)
    x = x_ref[0]
    r = r_ref[0]
    a = a_ref[b]
    acc = _horner32(x, x.astype(jnp.float32), r, a, coeffs, "right")
    xo_ref[0] = acc.astype(xo_ref.dtype)
    if coupled:
        y = y_ref[0]
        yacc = _horner32(y, y.astype(jnp.float32), r, a, coeffs, "left")
        yo_ref[0] = yacc.astype(yo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("coeffs", "interpret"))
def apply_g(X: jax.Array, R: jax.Array, alpha: jax.Array,
            *, coeffs: tuple, Y: jax.Array | None = None,
            interpret: bool = False):
    """X g_d(R; alpha) — and, when Y is given (the coupled sqrt family),
    also g_d(R; alpha) Y — as ONE launch of d fused GEMMs per operand.

    X: [B, m, n]; R: [B, n, n]; alpha: [B] fp32 (stays fp32 in the
    epilogue); coeffs: ascending Taylor coefficients f_0..f_{d-1} of
    g_d (static).  Returns X' (or (X', Y')).
    """
    nb, m, n = X.shape
    coupled = Y is not None
    mp, np_ = _pad2(m, _SUBLANE), _pad2(n, _LANE)
    Xp = jnp.pad(X, ((0, 0), (0, mp), (0, np_)))
    Rp = jnp.pad(R, ((0, 0), (0, np_), (0, np_)))
    M, N = Xp.shape[1], n + np_
    alpha = alpha.astype(jnp.float32)
    operands = [Xp]
    in_specs = [pl.BlockSpec((1, M, N), lambda b: (b, 0, 0))]
    out_specs = [pl.BlockSpec((1, M, N), lambda b: (b, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((nb, M, N), X.dtype)]
    if coupled:
        operands.append(jnp.pad(Y, ((0, 0), (0, np_), (0, np_))))
        in_specs.append(pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)))
        out_specs.append(pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nb, N, N), X.dtype))
    operands += [Rp, alpha]
    in_specs += [pl.BlockSpec((1, N, N), lambda b: (b, 0, 0)),
                 pl.BlockSpec(memory_space=pltpu.SMEM)]
    outs = pl.pallas_call(
        functools.partial(_apply_kernel, coeffs=coeffs, coupled=coupled),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    if coupled:
        return outs[0][:, :m, :n], outs[1][:, :n, :n]
    return outs[0][:, :m, :n]


# ---------------------------------------------------------------------------
# (c) fused multi-iteration warm tail: one launch per bucket
# ---------------------------------------------------------------------------


def _warm_kernel(*refs, family, coeffs, n_iters, coupled):
    if coupled:
        x_ref, y_ref, a_ref, xo_ref, yo_ref, xa, xb, ya, yb = refs
    else:
        x_ref, a_ref, xo_ref, xa, xb = refs
    it = pl.program_id(1)
    odd = (it % 2) == 1
    # iteration `it` reads the buffer iteration it-1 wrote ((it-1) % 2);
    # at it == 0 it reads the HBM input instead.  All candidate loads are
    # VMEM-resident; the selects keep the kernel branch-free (unvisited
    # buffers may hold garbage — select discards it).
    x = jnp.where(it == 0, x_ref[0], jnp.where(odd, xa[...], xb[...]))
    y = None
    if coupled:
        y = jnp.where(it == 0, y_ref[0], jnp.where(odd, ya[...], yb[...]))
    r = _residual32(x, y, family).astype(x.dtype)
    a = a_ref[it]
    new_x = _horner32(x, x.astype(jnp.float32), r, a, coeffs,
                      "right").astype(x.dtype)
    if coupled:
        new_y = _horner32(y, y.astype(jnp.float32), r, a, coeffs,
                          "left").astype(y.dtype)

    @pl.when(jnp.logical_not(odd))
    def _write_even():
        xa[...] = new_x
        if coupled:
            ya[...] = new_y

    @pl.when(odd)
    def _write_odd():
        xb[...] = new_x
        if coupled:
            yb[...] = new_y

    @pl.when(it == n_iters - 1)
    def _emit():
        xo_ref[0] = new_x
        if coupled:
            yo_ref[0] = new_y


@functools.partial(jax.jit,
                   static_argnames=("n_iters", "family", "coeffs",
                                    "interpret"))
def warm_tail(X: jax.Array, alphas: jax.Array, n_iters: int,
              *, family: str = "polar", coeffs: tuple,
              Y: jax.Array | None = None, interpret: bool = False):
    """``n_iters`` constant-alpha iterations of the family in ONE launch.

    X: [B, m, n] (polar; [B, n, n] for sign / sqrt); alphas: [n_iters]
    fp32, one per iteration (SMEM-resident — any static schedule fuses).
    X (and Y) ping-pong between two VMEM scratch buffers, so HBM sees one
    read and one write of each operand for the entire run.
    """
    assert family in FAMILIES, family
    coupled = family == "sqrt"
    nb, m, n = X.shape
    mp, np_ = _pad2(m, _SUBLANE), _pad2(n, _LANE)
    if family != "polar":
        mp = np_
    Xp = jnp.pad(X, ((0, 0), (0, mp), (0, np_)))
    M, N = Xp.shape[1], n + np_
    alphas = alphas.astype(jnp.float32)
    operands = [Xp]
    in_specs = [pl.BlockSpec((1, M, N), lambda b, it: (b, 0, 0))]
    out_specs = [pl.BlockSpec((1, M, N), lambda b, it: (b, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((nb, M, N), X.dtype)]
    scratch = [pltpu.VMEM((M, N), X.dtype), pltpu.VMEM((M, N), X.dtype)]
    if coupled:
        operands.append(jnp.pad(Y, ((0, 0), (0, np_), (0, np_))))
        in_specs.append(pl.BlockSpec((1, N, N), lambda b, it: (b, 0, 0)))
        out_specs.append(pl.BlockSpec((1, N, N), lambda b, it: (b, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((nb, N, N), X.dtype))
        scratch += [pltpu.VMEM((N, N), X.dtype),
                    pltpu.VMEM((N, N), X.dtype)]
    operands.append(alphas)
    in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
    outs = pl.pallas_call(
        functools.partial(_warm_kernel, family=family, coeffs=coeffs,
                          n_iters=n_iters, coupled=coupled),
        grid=(nb, n_iters),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    if coupled:
        return outs[0][:, :n, :n], outs[1][:, :n, :n]
    return outs[0][:, :m, :n]
