"""Fused GEMM-with-epilogue Pallas TPU kernel:  D = alpha * A @ B + beta * C.

This is the workhorse of the PRISM Newton-Schulz chains: every polynomial
update X (f0 I + f1 R + ... + a R^d) is evaluated as d fused GEMMs
(Horner on R), so the `+ beta * C` epilogue removes one full HBM
read-modify-write of the [m, n] accumulator per Horner step compared to
separate dot + add ops.

Tiling: (bm x bk) @ (bk x bn) MXU tiles with an fp32 VMEM scratch
accumulator; K is the innermost grid dimension, the C-epilogue and the
output cast happen on the last K step.  Tile sizes are 128-aligned for the
128x128 MXU systolic array.

Precision (DESIGN.md §9): operands may be fp32 or bf16 — the dot always
accumulates fp32 (``preferred_element_type``), the alpha/beta epilogue
runs on the fp32 accumulator (C upcast per tile), and the output rounds
once to the operand dtype.  ref.matmul_add is the bit-level oracle for
both dtypes.

Batching: the grid carries a leading batch dimension (B, M/bm, N/bn, K/bk)
so a whole [B, m, n] parameter bucket runs in ONE kernel launch instead of
a vmap of B independent 2-D launches (DESIGN.md §7).  2-D operands are
promoted to B = 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, c_ref, d_ref, acc_ref, *, alpha, beta, n_k):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[0].astype(jnp.float32)
        d_ref[0] = out.astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "bm", "bn",
                                             "bk", "interpret"))
def matmul_add(A: jax.Array, B: jax.Array, C: jax.Array | None = None,
               *, alpha: float = 1.0, beta: float = 0.0,
               bm: int = 256, bn: int = 256, bk: int = 256,
               interpret: bool = False) -> jax.Array:
    """D = alpha * A @ B + beta * C for [m, k] or [B, m, k] operands.

    All operands must share the same (possibly absent) batch dimension;
    leading-dim collapsing and broadcasting live in ``ops.py``.
    """
    squeeze = A.ndim == 2
    if squeeze:
        A = A[None]
        B = B[None]
        C = None if C is None else C[None]
    nb, m, k = A.shape
    _, k2, n = B.shape
    assert k == k2, (A.shape, B.shape)
    if C is None:
        C = jnp.zeros((nb, m, n), dtype=A.dtype)
        beta = 0.0
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # zero-pad to tile multiples (mathematically exact for GEMM+epilogue)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    Ap = jnp.pad(A, ((0, 0), (0, mp), (0, kp)))
    Bp = jnp.pad(B, ((0, 0), (0, kp), (0, np_)))
    Cp = jnp.pad(C, ((0, 0), (0, mp), (0, np_)))
    M, N, K = Ap.shape[1], Bp.shape[2], Ap.shape[2]
    n_k = K // bk
    out = pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, n_k=n_k),
        grid=(nb, M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda b, i, j, kk: (b, kk, j)),
            pl.BlockSpec((1, bm, bn), lambda b, i, j, kk: (b, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, M, N), A.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Ap, Bp, Cp)
    out = out[:, :m, :n]
    return out[0] if squeeze else out
