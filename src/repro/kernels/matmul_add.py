"""Fused GEMM-with-epilogue Pallas TPU kernel:  D = alpha * A @ B + beta * C.

This is the workhorse of the PRISM Newton-Schulz chains: every polynomial
update X (f0 I + f1 R + ... + a R^d) is evaluated as d fused GEMMs
(Horner on R), so the `+ beta * C` epilogue removes one full HBM
read-modify-write of the [m, n] accumulator per Horner step compared to
separate dot + add ops.

Tiling: (bm x bk) @ (bk x bn) MXU tiles with an fp32 VMEM scratch
accumulator; K is the innermost grid dimension, the C-epilogue and the
output cast happen on the last K step.  Tile sizes are 128-aligned for the
128x128 MXU systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, c_ref, d_ref, acc_ref, *, alpha, beta, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        d_ref[...] = out.astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "bm", "bn",
                                             "bk", "interpret"))
def matmul_add(A: jax.Array, B: jax.Array, C: jax.Array | None = None,
               *, alpha: float = 1.0, beta: float = 0.0,
               bm: int = 256, bn: int = 256, bk: int = 256,
               interpret: bool = False) -> jax.Array:
    """D = alpha * A @ B + beta * C for 2-D operands (batching in ops.py)."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2, (A.shape, B.shape)
    if C is None:
        C = jnp.zeros((m, n), dtype=A.dtype)
        beta = 0.0
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # zero-pad to tile multiples (mathematically exact for GEMM+epilogue)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    Ap = jnp.pad(A, ((0, mp), (0, kp)))
    Bp = jnp.pad(B, ((0, kp), (0, np_)))
    Cp = jnp.pad(C, ((0, mp), (0, np_)))
    M, N, K = Ap.shape[0], Bp.shape[1], Ap.shape[1]
    n_k = K // bk
    out = pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), A.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Ap, Bp, Cp)
    return out[:m, :n]
