"""Fused sketched-trace Pallas TPU kernel for PRISM's alpha fit.

One PRISM fit needs t_i = tr(S R^i S^T), i = 1..4d+2, via the chain
V_i = R V_{i-1} (V_0 = S^T, S in R^{p x n}).  On GPU these are p-wide
GEMMs + separate trace reductions; on TPU a p~8 matmul wastes the 128x128
MXU, so ``ops.sketch_traces`` pads the sketch to 128 lanes and this kernel
fuses each chain step with its trace epilogue:

    (V', t') = (R @ V,  sum(St * (R @ V)))

saving one full HBM round-trip of V' per power (the trace is reduced from
the fp32 accumulator while the tile is still in VMEM).  Grid is
(row-tiles, k-tiles) with a VMEM fp32 accumulator and an SMEM scalar
accumulator for the running trace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, v_ref, st_ref, vout_ref, t_ref, acc_ref, *, n_k):
    i, k = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (k == 0))
    def _init_trace():
        t_ref[0] = jnp.float32(0.0)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(r_ref[...], v_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        vnew = acc_ref[...]
        vout_ref[...] = vnew.astype(vout_ref.dtype)
        # fused trace epilogue: tr contribution of this row tile
        t_ref[0] += jnp.sum(st_ref[...].astype(jnp.float32) * vnew)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def sketch_step(R: jax.Array, V: jax.Array, St: jax.Array,
                *, bm: int = 256, bk: int = 256,
                interpret: bool = False):
    """(V', t') = (R @ V, tr-contraction of St with R @ V).

    R: [n, n]; V, St: [n, p128] (sketch transposed, lane-padded).
    Returns V' [n, p128] and the scalar t' = sum(St * V').
    """
    n, p = V.shape
    bm, bk = min(bm, n), min(bk, n)
    mp = (-n) % bm   # row padding (output rows)
    kp = (-n) % bk   # contraction-dim padding
    Rp = jnp.pad(R, ((0, mp), (0, kp)))
    Vp = jnp.pad(V, ((0, kp), (0, 0)))
    Stp = jnp.pad(St, ((0, mp), (0, 0)))
    N, K = Rp.shape
    n_k = K // bk
    vout, t = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(N // bm, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, p), lambda i, k: (k, 0)),
            pl.BlockSpec((bm, p), lambda i, k: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, p), lambda i, k: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, p), R.dtype),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, p), jnp.float32)],
        interpret=interpret,
    )(Rp, Vp, Stp)
    return vout[:n], t[0]
