"""Fused sketched-trace Pallas TPU kernels for PRISM's alpha fit.

One PRISM fit needs t_i = tr(S R^i S^T), i = 1..4d+2, via the chain
V_i = R V_{i-1} (V_0 = S^T, S in R^{p x n}).  On GPU these are p-wide
GEMMs + separate trace reductions; on TPU a p~8 matmul wastes the 128x128
MXU, so ``ops.sketch_traces`` pads the sketch to 128 lanes and these
kernels fuse each chain step with its trace epilogue:

    (V', t') = (R @ V,  sum(St * (R @ V)))

saving one full HBM round-trip of V' per power (the trace is reduced from
the fp32 accumulator while the tile is still in VMEM).

Precision (DESIGN.md §9): with bf16 R/St the chain stays bf16 in VMEM
(the ping-pong V buffers take R.dtype) but every trace is reduced in
fp32 FROM THE fp32 ACCUMULATOR of R @ V — before V' rounds to bf16 —
so the PRISM fit always sees fp32 traces; ref.sketch_traces mirrors
this ordering exactly.

Two entry points:

  * ``sketch_step`` — one chain step, grid (row-tiles, k-tiles); the
    original per-power kernel, kept as the building block contract.
  * ``sketch_chain`` — the ENTIRE chain for a whole [B, n, n] residual
    bucket in ONE launch, grid (B, powers, row-tiles, k-tiles).  V never
    leaves VMEM between powers: two ping-pong scratch buffers hold
    V_{i-1} / V_i, so the only HBM traffic is streaming R's tiles once
    per power.  This collapses the ~(4d+2) * B launches per fitted
    iteration of the per-step kernel into one (DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, v_ref, st_ref, vout_ref, t_ref, acc_ref, *, n_k):
    b, i, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init_trace():
        t_ref[b] = jnp.float32(0.0)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(r_ref[0], v_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        vnew = acc_ref[...]
        vout_ref[0] = vnew.astype(vout_ref.dtype)
        # fused trace epilogue: tr contribution of this row tile
        t_ref[b] += jnp.sum(st_ref[...].astype(jnp.float32) * vnew)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def sketch_step(R: jax.Array, V: jax.Array, St: jax.Array,
                *, bm: int = 256, bk: int = 256,
                interpret: bool = False):
    """(V', t') = (R @ V, tr-contraction of St with R @ V).

    R: [n, n] or [B, n, n]; V matches R's batching with [.., n, p128]
    rows; St: [n, p128], shared across the batch (sketch transposed,
    lane-padded).  Returns V' and t' = sum(St * V') per batch element.
    Bounded-VMEM building block: ops.sketch_traces falls back to a loop
    of these when the whole-chain kernel's VMEM footprint (which grows
    with n) exceeds the budget (DESIGN.md §10).
    """
    squeeze = R.ndim == 2
    if squeeze:
        R, V = R[None], V[None]
    nb, n, _ = R.shape
    p = V.shape[-1]
    bm, bk = min(bm, n), min(bk, n)
    mp = (-n) % bm   # row padding (output rows)
    kp = (-n) % bk   # contraction-dim padding
    Rp = jnp.pad(R, ((0, 0), (0, mp), (0, kp)))
    Vp = jnp.pad(V, ((0, 0), (0, kp), (0, 0)))
    Stp = jnp.pad(St, ((0, mp), (0, 0)))
    N, K = Rp.shape[1], Rp.shape[2]
    n_k = K // bk
    vout, t = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(nb, N // bm, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, k: (b, i, k)),
            pl.BlockSpec((1, bk, p), lambda b, i, k: (b, k, 0)),
            pl.BlockSpec((bm, p), lambda b, i, k: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, p), lambda b, i, k: (b, i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, N, p), R.dtype),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, p), jnp.float32)],
        interpret=interpret,
    )(Rp, Vp, Stp)
    vout = vout[:, :n]
    return (vout[0], t[0]) if squeeze else (vout, t)


# ---------------------------------------------------------------------------
# Whole-chain kernel: one launch per (bucket, fit)
# ---------------------------------------------------------------------------


def _chain_kernel(r_ref, st_ref, t_ref, v0_ref, v1_ref, acc_ref,
                  *, n_k, bn):
    b = pl.program_id(0)
    pw = pl.program_id(1)   # chain step: computes V_{pw+1} = R V_pw
    i = pl.program_id(2)    # output row tile of V_{pw+1}
    k = pl.program_id(3)    # contraction tile over rows of V_pw

    @pl.when((i == 0) & (k == 0))
    def _init_trace():
        t_ref[b, pw] = jnp.float32(0.0)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # V_pw rows [k*bn, (k+1)*bn): St for pw == 0, else the ping-pong buffer
    # written by the previous power ((pw-1) % 2).  All three candidate loads
    # are tiny (bn x p128) next to the R tile; the selects keep the kernel
    # branch-free (unvisited buffers may hold garbage — select discards it).
    ks = pl.multiple_of(k * bn, bn)
    st_k = st_ref[pl.ds(ks, bn), :]
    v_prev = jnp.where((pw % 2) == 1, v0_ref[pl.ds(ks, bn), :],
                       v1_ref[pl.ds(ks, bn), :])
    v_in = jnp.where(pw == 0, st_k, v_prev)
    acc_ref[...] += jnp.dot(r_ref[0], v_in,
                            preferred_element_type=jnp.float32)

    last = k == n_k - 1
    is_ = pl.multiple_of(i * bn, bn)

    @pl.when(last)
    def _trace_epilogue():
        # fused trace: tr contribution of this row tile of V_{pw+1}
        t_ref[b, pw] += jnp.sum(
            st_ref[pl.ds(is_, bn), :].astype(jnp.float32) * acc_ref[...])

    @pl.when(last & ((pw % 2) == 0))
    def _write_v0():
        v0_ref[pl.ds(is_, bn), :] = acc_ref[...].astype(v0_ref.dtype)

    @pl.when(last & ((pw % 2) == 1))
    def _write_v1():
        v1_ref[pl.ds(is_, bn), :] = acc_ref[...].astype(v1_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_power", "bn", "interpret"))
def sketch_chain(R: jax.Array, St: jax.Array, max_power: int,
                 *, bn: int = 256, interpret: bool = False) -> jax.Array:
    """t_i = tr(S R^i S^T) for i = 1..max_power, one launch for the batch.

    R: [B, n, n] (or [n, n]); St: [n, p128] (sketch transposed, lane-padded,
    shared across the batch).  Returns [B, max_power] fp32 traces (the
    i = 0 trace is sketch-only and computed by the caller).
    """
    squeeze = R.ndim == 2
    if squeeze:
        R = R[None]
    nb, n, _ = R.shape
    p = St.shape[1]
    bn = min(bn, n)
    pad = (-n) % bn
    Rp = jnp.pad(R, ((0, 0), (0, pad), (0, pad)))
    Stp = jnp.pad(St, ((0, pad), (0, 0)))
    N = n + pad
    n_k = N // bn
    t = pl.pallas_call(
        functools.partial(_chain_kernel, n_k=n_k, bn=bn),
        grid=(nb, max_power, n_k, n_k),
        in_specs=[
            pl.BlockSpec((1, bn, bn), lambda b, pw, i, k: (b, i, k)),
            # full St resident in VMEM: needed at row-tile k (chain input)
            # and row-tile i (trace epilogue) in the same grid step
            pl.BlockSpec((N, p), lambda b, pw, i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((nb, max_power), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((N, p), R.dtype),   # V ping-pong buffer (even pw)
            pltpu.VMEM((N, p), R.dtype),   # V ping-pong buffer (odd pw)
            pltpu.VMEM((bn, p), jnp.float32),
        ],
        interpret=interpret,
    )(Rp, Stp)
    return t[0] if squeeze else t
