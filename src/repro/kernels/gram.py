"""Symmetric Gram (syrk) Pallas TPU kernel:  R = alpha * I + beta * X^T X.

Newton-Schulz for the polar factor forms R_k = I - X_k^T X_k every
iteration; the product is symmetric, but a generic GEMM computes all n^2
output tiles.  This kernel enumerates ONLY the upper-triangular block grid
(T = nb (nb+1) / 2 tiles instead of nb^2) — the linear tile index t is
unranked to (block-row i, block-col j) in closed form inside the BlockSpec
index maps — cutting MXU work and HBM write traffic nearly in half.  This
is a TPU-native beyond-paper optimization (DESIGN.md §3).

The kernel emits the upper-block-triangle U (lower blocks zero);
``ops.gram`` mirrors it with one elementwise pass:
    R = U + transpose(strictly-upper-block part of U).

Precision (DESIGN.md §9): bf16 X accumulates X^T X in fp32 on the VMEM
scratch; the alpha*I epilogue is fp32 and the tile rounds once to the
operand dtype — the residual a bf16 Newton-Schulz iteration consumes is
the correctly-rounded fp32 Gram, not a bf16-accumulated one.

Batching: the grid is (B, T, K/bk) so a whole [B, m, n] parameter bucket
forms its residuals in ONE launch (DESIGN.md §7); 2-D inputs run as B = 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unrank_upper(t, nb: int):
    """Row-major upper-triangle unranking: t -> (i, j), i <= j < nb.

    f(i) = i*nb - i(i-1)/2 elements precede block-row i; invert via a
    float sqrt estimate + integer correction (robust to rounding).
    """
    tf = t.astype(jnp.float32)
    b = 2 * nb + 1
    i_est = jnp.floor((b - jnp.sqrt(b * b - 8.0 * tf)) / 2).astype(jnp.int32)

    def f(i):
        return i * nb - (i * (i - 1)) // 2

    i = i_est
    i = jnp.where(f(i + 1) <= t, i + 1, i)
    i = jnp.where(f(i) > t, i - 1, i)
    i = jnp.clip(i, 0, nb - 1)
    j = t - f(i) + i
    return i, jnp.clip(j, 0, nb - 1)


def _kernel(x1_ref, x2_ref, out_ref, acc_ref, *, alpha, beta, n_k, bn, nb):
    k = pl.program_id(2)
    t = pl.program_id(1)  # hoisted: program_id inside pl.when bodies does
    # not interpret on CPU (substitution happens at kernel top level only)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x1_ref[0].T, x2_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        i, j = _unrank_upper(t, nb)
        out = beta * acc_ref[...]
        if alpha != 0.0:
            # add alpha * I only on diagonal blocks
            row = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
            col = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
            eye = jnp.where((row == col) & (i == j), alpha, 0.0)
            out = out + eye
        out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "bn", "bk",
                                             "interpret"))
def gram_upper(X: jax.Array, *, alpha: float = 1.0, beta: float = -1.0,
               bn: int = 256, bk: int = 256,
               interpret: bool = False) -> jax.Array:
    """Upper-block-triangle of alpha * I + beta * X^T X for X [m, n] or
    [B, m, n].

    Only tiles (i, j) with i <= j are computed; strictly-lower blocks of
    the result are zero.  Use ``ops.gram`` for the full symmetric matrix.
    """
    squeeze = X.ndim == 2
    if squeeze:
        X = X[None]
    nbatch, m, n = X.shape
    bn, bk = min(bn, n), min(bk, m)
    np_, kp = (-n) % bn, (-m) % bk
    Xp = jnp.pad(X, ((0, 0), (0, kp), (0, np_)))
    M, N = Xp.shape[1], Xp.shape[2]
    nb, n_k = N // bn, M // bk
    T = nb * (nb + 1) // 2

    def in_map_a(b, t, kk):
        i, _ = _unrank_upper(t, nb)
        return (b, kk, i)

    def in_map_b(b, t, kk):
        _, j = _unrank_upper(t, nb)
        return (b, kk, j)

    def out_map(b, t, kk):
        i, j = _unrank_upper(t, nb)
        return (b, i, j)

    out = pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, n_k=n_k, bn=bn,
                          nb=nb),
        grid=(nbatch, T, n_k),
        in_specs=[
            pl.BlockSpec((1, bk, bn), in_map_a),
            pl.BlockSpec((1, bk, bn), in_map_b),
        ],
        out_specs=pl.BlockSpec((1, bn, bn), out_map),
        out_shape=jax.ShapeDtypeStruct((nbatch, N, N), X.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(Xp, Xp)
    out = out[:, :n, :n]
    return out[0] if squeeze else out


def mirror_upper(U: jax.Array, bn: int) -> jax.Array:
    """R = upper-blocks(U) + transpose(strictly-upper-blocks(U)).

    Lower blocks of U were never visited by the kernel (undefined memory),
    so both terms mask at block granularity before combining.
    """
    n = U.shape[-1]
    blk = jnp.arange(n) // bn
    upper = blk[:, None] <= blk[None, :]
    strictly_upper = blk[:, None] < blk[None, :]
    zero = jnp.zeros((), U.dtype)
    # jnp.where (not multiply): unvisited blocks may be NaN-filled
    return jnp.where(upper, U, zero) + \
        jnp.swapaxes(jnp.where(strictly_upper, U, zero), -1, -2)
