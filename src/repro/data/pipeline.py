"""Deterministic synthetic data pipeline.

Design goals for multi-pod training:
  * stateless: batch_for_step(step) is a pure function of (seed, step), so
    checkpoint resume and elastic re-sharding need no data-iterator state;
  * host-sharded: each host generates only its slice (process_index-based);
  * learnable: tokens come from a fixed random bigram (Markov) source, so
    optimizer benchmarks (Fig. 5/6) show real learning-curve separation —
    uniform random tokens would make every optimizer look identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 50257
    seq_len: int = 1024
    global_batch: int = 32
    seed: int = 1234
    markov_rank: int = 64  # low-rank bigram structure (learnability knob)


def _bigram_logits_factors(cfg: DataConfig):
    """Low-rank factors of the bigram transition logits (fixed by seed)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(cfg.seed))
    U = jax.random.normal(k1, (cfg.vocab_size, cfg.markov_rank)) * 1.5
    V = jax.random.normal(k2, (cfg.markov_rank, cfg.vocab_size)) * 1.5
    return U, V


def sample_tokens(cfg: DataConfig, step: int | jax.Array, batch: int,
                  num_codebooks: int = 0) -> jax.Array:
    """[batch, seq] (or [batch, K, seq]) tokens for this step."""
    U, V = _bigram_logits_factors(cfg)
    rows = batch * max(num_codebooks, 1)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 1),
                             jnp.asarray(step, jnp.int32))
    k0, kseq = jax.random.split(key)
    x0 = jax.random.randint(k0, (rows,), 0, cfg.vocab_size, jnp.int32)

    def step_fn(carry, k):
        x = carry
        logits = U[x] @ V  # [rows, vocab]
        nxt = jax.random.categorical(k, logits / jnp.sqrt(cfg.markov_rank))
        return nxt.astype(jnp.int32), nxt.astype(jnp.int32)

    keys = jax.random.split(kseq, cfg.seq_len - 1)
    _, rest = jax.lax.scan(step_fn, x0, keys)
    toks = jnp.concatenate([x0[None], rest], axis=0).T  # [rows, seq]
    if num_codebooks:
        return toks.reshape(batch, num_codebooks, cfg.seq_len)
    return toks


def make_batch_fn(model_cfg: ModelConfig, data_cfg: DataConfig):
    """Returns batch_for_step(step) -> model input dict (jit-able)."""

    def batch_for_step(step):
        out: Dict[str, jax.Array] = {}
        if model_cfg.family == "audio":
            out["tokens"] = sample_tokens(data_cfg, step,
                                          data_cfg.global_batch,
                                          model_cfg.num_codebooks)
        else:
            out["tokens"] = sample_tokens(data_cfg, step,
                                          data_cfg.global_batch)
        if model_cfg.family == "vlm":
            kp = jax.random.fold_in(jax.random.PRNGKey(data_cfg.seed + 2),
                                    jnp.asarray(step, jnp.int32))
            out["patches"] = jax.random.normal(
                kp, (data_cfg.global_batch, model_cfg.num_patches,
                     model_cfg.vision_dim)).astype(jnp.bfloat16)
        return out

    return batch_for_step
