from repro.data.pipeline import DataConfig, make_batch_fn, sample_tokens

__all__ = ["DataConfig", "make_batch_fn", "sample_tokens"]
