"""Deterministic load generator for the serving engine (DESIGN.md §16).

Produces a fully materialized request trace up front — seeded Poisson
arrivals (exponential inter-arrival gaps at the offered QPS), mixed
prompt/generation lengths drawn from configurable palettes, and uniform
random prompt tokens — so every consumer (engine tests, the static- vs
continuous-batching bench, replay debugging) sees the byte-identical
workload for a given ``(seed, qps, n_requests)`` triple.  Nothing here
touches jax: traces are host-side numpy, cheap to build and to diff.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: ``prompt`` is the real (unpadded) token ids;
    ``arrival`` is seconds since trace start on the load clock."""

    rid: int
    arrival: float
    prompt: np.ndarray          # [prompt_len] int32
    max_new: int

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


def make_trace(seed: int, *, n_requests: int, qps: float, vocab_size: int,
               prompt_lens: Sequence[int] = (4, 8, 12, 24),
               gen_lens: Sequence[int] = (4, 8, 16),
               ) -> Tuple[Request, ...]:
    """Seeded Poisson trace: ``n_requests`` requests at offered rate
    ``qps``, prompt/gen lengths sampled uniformly from the palettes.

    The mixed-length palettes are the point (not a nicety): uniform
    lengths would let static batching pad-free-ride, while ragged traces
    are exactly where continuous batching wins — the BENCH_serving.json
    throughput invariant is only meaningful on a mixed trace.
    """
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    if n_requests <= 0:
        raise ValueError(f"n_requests must be > 0, got {n_requests}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / qps, size=n_requests)
    arrivals = np.cumsum(gaps)
    plens = rng.choice(np.asarray(prompt_lens, np.int64), size=n_requests)
    glens = rng.choice(np.asarray(gen_lens, np.int64), size=n_requests)
    out = []
    for i in range(n_requests):
        prompt = rng.integers(0, vocab_size, size=int(plens[i]),
                              dtype=np.int64).astype(np.int32)
        out.append(Request(rid=i, arrival=float(arrivals[i]), prompt=prompt,
                           max_new=int(glens[i])))
    return tuple(out)
