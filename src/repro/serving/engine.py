"""Continuous-batching decode engine (DESIGN.md §16).

A fixed-capacity slot table turns ragged request traffic into dense,
fixed-shape device steps:

  admit    arrived requests are right-padded to pow2 prompt lengths on
           the host, grouped into exact-shape buckets by the §6 planner
           (``plan_buckets``/``gather_bucket`` on the request dimension
           — each view is one prompt shaped [1, P]), and each bucket
           runs ONE ``model.prefill_cache`` launch whose per-request KV
           rows + first sampled token install into free slots;
  decode   every engine step runs ONE fixed-shape [slots, 1] decode
           launch over the whole table; the ``active`` mask keeps
           retired/free lanes' cache rows bitwise-frozen (dead lanes
           cost a lane of FLOPs, never correctness);
  evict    slots free on EOS or ``max_new`` in ascending-slot order, so
           eviction is deterministic under a seeded trace.

The jit-shape contract (enforced by tests + BENCH_serving.json): the
decode step compiles at most 2 distinct shapes across an entire run —
in practice exactly 1, because the slot table never changes shape.
Prefill compiles one executable per (pow2 admit count, pow2 prompt len)
bucket, a bounded O(log slots · log max_prompt) set.

Clocking is dual-mode: ``step_dt=None`` measures wall time (the bench),
a float ``step_dt`` runs a virtual clock where every device launch
costs one tick (tests + the hardware-independent throughput invariant:
continuous admission beats static admission on mixed-length traces
because static convoys — it re-admits only when the WHOLE table has
drained, idling slots on the longest straggler).

Per-slot sampling keys fold (request id, position) — see
serving/decode.py — so two requests decoding at the same position never
share a sample stream and any trace replays bitwise.

The engine serves the KV-cache families (dense, moe).  ssm/hybrid have
``prefill_cache``/``decode_step`` support for single-stream serving but
their recurrence caches are not slot-installable here (yet).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.optim.bucketing import plan_buckets, gather_bucket
from repro.serving.decode import _check_temperature, sample_logits
from repro.serving.loadgen import Request

_MAX_STEPS = 200_000


def pow2_pad(n: int, floor: int = 4) -> int:
    """Smallest power of two >= max(n, floor) — the admission length
    bucket, bounding prefill executables to one per (count, len) pow2."""
    p = floor
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 8              # slot-table capacity == decode batch
    cache_len: int = 64         # per-slot KV length (>= prompt + gen!)
    greedy: bool = True
    temperature: float = 1.0
    top_k: Optional[int] = None
    eos_id: Optional[int] = None
    admission: str = "continuous"   # "continuous" | "static"
    seed: int = 0                   # base PRNG key for sampling

    def __post_init__(self):
        if self.slots <= 0:
            raise ValueError(f"slots must be > 0, got {self.slots}")
        if self.admission not in ("continuous", "static"):
            raise ValueError(f"unknown admission {self.admission!r}")
        if not self.greedy:
            _check_temperature(self.temperature)


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    prompt_len: int
    arrival: float
    admitted: float
    finished: float
    tokens: Tuple[int, ...]     # generated tokens (incl. prefill's first)

    @property
    def latency(self) -> float:
        """Full-request latency including queue wait."""
        return self.finished - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.admitted - self.arrival


@dataclasses.dataclass(frozen=True)
class RunResult:
    completions: Tuple[Completion, ...]
    occupancy: Tuple[int, ...]  # active slots at each decode step
    n_decode_steps: int
    n_prefill_launches: int
    decode_step_shapes: int     # jit-cache size of the decode step
    elapsed: float

    @property
    def generated_tokens(self) -> int:
        return sum(len(c.tokens) for c in self.completions)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.elapsed, 1e-9)

    def latency_percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        lats = np.asarray([c.latency for c in self.completions])
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}


class Engine:
    """Slot-table continuous-batching engine over one compiled model."""

    def __init__(self, model: Model, params, cfg: EngineConfig):
        if model.cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"Engine serves KV-cache families (dense, moe); "
                f"{model.cfg.family!r} caches are not slot-installable")
        self.model = model
        self.params = params
        self.cfg = cfg
        self._key = jax.random.PRNGKey(cfg.seed)

        def decode(params, cache, tokens, pos, active, key, rids):
            logits, cache = model.decode_step(params, cache, tokens, pos,
                                              active=active)
            lg = logits[:, 0]  # [slots, V]
            if cfg.greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                p = jnp.reshape(pos, (pos.shape[0], -1))[:, 0]
                keys = jax.vmap(lambda r, pp: jax.random.fold_in(
                    jax.random.fold_in(key, r), pp))(rids, p)
                nxt = jax.vmap(lambda l, k: sample_logits(
                    l, k, temperature=cfg.temperature,
                    top_k=cfg.top_k))(lg, keys)
            return nxt, cache

        # ONE decode executable for the whole run: the slot table is the
        # batch, so tokens/pos/active/rids never change shape
        self._decode = jax.jit(decode, donate_argnums=(1,))

        def prefill(params, tokens, lengths, key, rids):
            logits, rows = model.prefill_cache(
                params, {"tokens": tokens}, cfg.cache_len, lengths)
            lg = logits[:, 0]
            if cfg.greedy:
                first = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                # same (rid, pos) fold as decode: prefill's token is the
                # sample "at" position lengths - 1
                keys = jax.vmap(lambda r, pp: jax.random.fold_in(
                    jax.random.fold_in(key, r), pp))(rids, lengths - 1)
                first = jax.vmap(lambda l, k: sample_logits(
                    l, k, temperature=cfg.temperature,
                    top_k=cfg.top_k))(lg, keys)
            return first, rows

        self._prefill = jax.jit(prefill)

        def install(cache, rows, idx):
            # filler lanes carry idx == slots: out-of-bounds scatter
            # indices drop under jit, so pad lanes never land
            return {
                "k": cache["k"].at[:, idx].set(rows["k"]),
                "v": cache["v"].at[:, idx].set(rows["v"]),
                "kpos": cache["kpos"].at[idx].set(rows["kpos"]),
            }

        self._install = jax.jit(install, donate_argnums=(0,))

        self.reset()

    # ------------------------------------------------------------ state

    def reset(self):
        cfg = self.cfg
        self.cache = self.model.init_cache(cfg.slots, cfg.cache_len)
        self._rid = np.full(cfg.slots, -1, np.int64)     # -1 == free
        self._pos = np.zeros(cfg.slots, np.int32)        # next decode pos
        self._plen = np.zeros(cfg.slots, np.int32)
        self._max_new = np.zeros(cfg.slots, np.int64)
        self._toks: List[List[int]] = [[] for _ in range(cfg.slots)]
        self._meta: Dict[int, Tuple[Request, float]] = {}  # rid -> admit t

    @property
    def active_mask(self) -> np.ndarray:
        return self._rid >= 0

    @property
    def n_active(self) -> int:
        return int(self.active_mask.sum())

    @property
    def decode_step_shapes(self) -> int:
        return int(self._decode._cache_size())

    # -------------------------------------------------------- admission

    def _plan_admission(self, reqs: Sequence[Request]):
        """§6 planner on the request dimension: each prompt is a [1, P]
        view at its pow2-padded length; exact-shape buckets become one
        prefill launch each."""
        padded = [pow2_pad(r.prompt_len) for r in reqs]
        for r, p in zip(reqs, padded):
            if p > self.cfg.cache_len:
                raise ValueError(
                    f"request {r.rid}: padded prompt {p} exceeds "
                    f"cache_len {self.cfg.cache_len}")
        return plan_buckets([(1, p) for p in padded])

    def admit(self, reqs: Sequence[Request], free: Sequence[int],
              now: float) -> int:
        """Admit up to ``len(free)`` requests (FIFO) into free slots;
        one prefill launch per prompt-length bucket.  Returns launches."""
        cfg = self.cfg
        reqs = list(reqs)[:len(free)]
        if not reqs:
            return 0
        launches = 0
        buckets = self._plan_admission(reqs)
        # views are globally indexed (Entry.index points into reqs);
        # each prompt right-pads to its own pow2 bucket length
        views = [np.pad(r.prompt,
                        (0, pow2_pad(r.prompt_len) - r.prompt_len)
                        ).reshape(1, -1).astype(np.int32) for r in reqs]
        slot_iter = iter(sorted(free)[:len(reqs)])
        for b in buckets:
            idxs = [e.index for e in b.entries]
            take = [reqs[i] for i in idxs]
            slots = [next(slot_iter) for _ in take]
            P = b.shape[1]
            A = pow2_pad(len(take), floor=1)
            tokens = np.asarray(gather_bucket(b, views)
                                ).reshape(len(take), P)       # [A_real, P]
            lengths = np.asarray([r.prompt_len for r in take], np.int32)
            rids = np.asarray([r.rid for r in take], np.int32)
            idx = np.asarray(slots, np.int64)
            if A > len(take):                 # pad lanes: OOB idx drops
                padn = A - len(take)
                tokens = np.concatenate(
                    [tokens, np.repeat(tokens[-1:], padn, 0)])
                lengths = np.concatenate(
                    [lengths, np.repeat(lengths[-1:], padn)])
                rids = np.concatenate([rids, np.repeat(rids[-1:], padn)])
                idx = np.concatenate(
                    [idx, np.full(padn, cfg.slots, np.int64)])
            first, rows = self._prefill(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                self._key, jnp.asarray(rids))
            self.cache = self._install(self.cache, rows, jnp.asarray(idx))
            first = np.asarray(first)
            launches += 1
            for j, (r, s) in enumerate(zip(take, slots)):
                self._rid[s] = r.rid
                self._pos[s] = r.prompt_len
                self._plen[s] = r.prompt_len
                self._max_new[s] = r.max_new
                self._toks[s] = [int(first[j])]
                self._meta[r.rid] = (r, now)
        return launches

    # ----------------------------------------------------------- decode

    def step(self) -> np.ndarray:
        """One fixed-shape decode launch over the slot table.  Returns
        the per-slot next tokens (garbage at inactive lanes)."""
        cfg = self.cfg
        active = self.active_mask
        tokens = np.asarray(
            [t[-1] if t else 0 for t in self._toks], np.int32)
        nxt, self.cache = self._decode(
            self.params, self.cache,
            jnp.asarray(tokens.reshape(cfg.slots, 1)),
            jnp.asarray(self._pos.reshape(cfg.slots, 1)),
            jnp.asarray(active),
            self._key,
            jnp.asarray(np.maximum(self._rid, 0).astype(np.int32)))
        nxt = np.asarray(nxt)
        for s in range(cfg.slots):
            if active[s]:
                self._toks[s].append(int(nxt[s]))
                self._pos[s] += 1
        return nxt

    def sweep(self, now: float, out: List[Completion]):
        """Evict finished slots (EOS or max_new), ascending slot order."""
        for s in range(self.cfg.slots):
            rid = self._rid[s]
            if rid < 0:
                continue
            toks = self._toks[s]
            done = len(toks) >= self._max_new[s] or (
                self.cfg.eos_id is not None and toks
                and toks[-1] == self.cfg.eos_id)
            if done:
                req, admitted = self._meta.pop(int(rid))
                out.append(Completion(
                    rid=int(rid), prompt_len=req.prompt_len,
                    arrival=req.arrival, admitted=admitted, finished=now,
                    tokens=tuple(toks)))
                self._rid[s] = -1
                self._toks[s] = []

    # -------------------------------------------------------------- run

    def run(self, trace: Sequence[Request],
            step_dt: Optional[float] = None,
            prefill_dt: Optional[float] = None) -> RunResult:
        """Drive a loadgen trace to completion.

        ``step_dt=None``: wall clock (sleeps through idle gaps — the
        bench's offered-load mode).  A float runs the virtual clock:
        every decode launch costs ``step_dt``, every prefill launch
        ``prefill_dt`` (default ``step_dt``) — fully deterministic.
        """
        cfg = self.cfg
        virtual = step_dt is not None
        if virtual and prefill_dt is None:
            prefill_dt = step_dt
        pending = sorted(trace, key=lambda r: (r.arrival, r.rid))
        done: List[Completion] = []
        occupancy: List[int] = []
        n_steps = 0
        n_prefill = 0
        vt = 0.0
        t0 = time.monotonic()

        def now():
            return vt if virtual else time.monotonic() - t0

        for _ in range(_MAX_STEPS):
            if not pending and not self.n_active:
                break
            t = now()
            arrived = [r for r in pending if r.arrival <= t]
            free = [s for s in range(cfg.slots) if self._rid[s] < 0]
            admit_ok = bool(arrived) and bool(free) and (
                cfg.admission == "continuous" or self.n_active == 0)
            if admit_ok:
                n = min(len(arrived), len(free))
                launches = self.admit(arrived[:n], free, t)
                n_prefill += launches
                pending = pending[n:]
                if virtual:
                    vt += launches * prefill_dt
                # prefill may already satisfy max_new == 1
                self.sweep(now(), done)
            if self.n_active:
                occupancy.append(self.n_active)
                self.step()
                n_steps += 1
                if virtual:
                    vt += step_dt
                self.sweep(now(), done)
            elif pending:
                nxt_t = pending[0].arrival
                if virtual:
                    vt = max(vt, nxt_t)
                else:
                    time.sleep(max(0.0, nxt_t - now()))
        else:
            raise RuntimeError(f"engine exceeded {_MAX_STEPS} steps "
                               f"({len(pending)} pending)")

        done.sort(key=lambda c: c.rid)
        return RunResult(
            completions=tuple(done), occupancy=tuple(occupancy),
            n_decode_steps=n_steps, n_prefill_launches=n_prefill,
            decode_step_shapes=self.decode_step_shapes, elapsed=now())
