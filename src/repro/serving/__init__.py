from repro.serving.decode import (make_prefill_step, make_serve_step,
                                  sample_logits)
from repro.serving.engine import (Completion, Engine, EngineConfig,
                                  RunResult, pow2_pad)
from repro.serving.loadgen import Request, make_trace

__all__ = [
    "Completion", "Engine", "EngineConfig", "Request", "RunResult",
    "make_prefill_step", "make_serve_step", "make_trace", "pow2_pad",
    "sample_logits",
]
