"""Serving steps: prefill (last-token logits) + single-token decode.

Two decode policies share one step shape:

  greedy=True   serve_step(params, cache, tokens, pos)
                -> (logits, argmax token, cache); fully deterministic,
                the launch/serve.py and examples/serve_lm.py loop.
  greedy=False  serve_step(params, cache, tokens, pos, key, rids=None)
                -> (logits, sampled token, cache); temperature / top-k
                sampling.  The caller threads ONE base PRNG key; each
                lane folds (request id, position) into it, so replays
                are reproducible and two requests decoding at the same
                position never share a sample stream (rids=None keys
                by position alone, backward compatible).

``top_k=1`` degenerates to greedy regardless of temperature, so the
sampled path can be regression-tested against the greedy one.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def _check_temperature(temperature: float) -> None:
    """Single source of truth for the temperature domain check (raised
    both at ``make_serve_step`` factory time — fail fast, before any
    compile — and inside ``sample_logits`` for direct callers)."""
    if temperature <= 0.0:
        raise ValueError(f"temperature must be > 0, got {temperature} "
                         "(use greedy=True for argmax decoding)")


def sample_logits(logits: jax.Array, key: jax.Array,
                  temperature: float = 1.0,
                  top_k: Optional[int] = None) -> jax.Array:
    """Temperature / top-k sample over the trailing vocab axis.

    Works for any leading batch layout (LM [B, V], audio [B, C, 1, V]):
    returns int32 token ids shaped ``logits.shape[:-1]``.  ``top_k``
    restricts the support to the k largest logits (None = full vocab);
    ``temperature`` scales AFTER the restriction so top_k=1 is exact
    argmax for any temperature.
    """
    _check_temperature(temperature)
    vocab = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if top_k is not None and top_k < vocab:
        vals, idx = jax.lax.top_k(logits, top_k)
        choice = jax.random.categorical(key, vals / temperature, axis=-1)
        nxt = jnp.take_along_axis(idx, choice[..., None], axis=-1)
        return nxt[..., 0].astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


def make_serve_step(model: Model, greedy: bool = True,
                    temperature: float = 1.0,
                    top_k: Optional[int] = None) -> Callable:
    if greedy:
        def serve_step(params, cache, tokens, pos):
            logits, cache = model.decode_step(params, cache, tokens, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return logits, nxt, cache

        return serve_step

    _check_temperature(temperature)

    def serve_step(params, cache, tokens, pos, key, rids=None):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        # per-lane key = fold_in(fold_in(key, request_id), position):
        # under continuous batching two requests routinely decode at the
        # SAME position in the same step — folding only the position
        # would hand them one sample stream.  The (rid, pos) pair keys
        # every sample uniquely while keeping replays deterministic:
        # re-running any step of a trace resamples identically.
        B = logits.shape[0]
        r = jnp.zeros((B,), jnp.int32) if rids is None \
            else jnp.asarray(rids, jnp.int32)
        p = jnp.reshape(pos, (B, -1))[:, 0]
        keys = jax.vmap(lambda rr, pp: jax.random.fold_in(
            jax.random.fold_in(key, rr), pp))(r, p)
        nxt = jax.vmap(lambda lg, k: sample_logits(
            lg, k, temperature=temperature, top_k=top_k))(logits, keys)
        return logits, nxt, cache

    return serve_step
