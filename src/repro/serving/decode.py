"""Serving steps: prefill (last-token logits) + single-token decode."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model: Model, greedy: bool = True) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = None
        return logits, nxt, cache

    return serve_step
