"""Muon optimizer with PRISM orthogonalization (paper Sec. 6.2).

Matrix-shaped hidden weights: nesterov momentum -> polar factor of the
momentum (method selectable: prism | newton_schulz | polar_express | svd)
-> aspect-ratio-scaled update.  Everything else (embeddings, norms,
biases, routers) falls back to AdamW with a scaled lr, as in standard Muon
practice.

Orthogonalization dispatch is shape-bucketed by default
(optim/bucketing.py): same-shape momentum matrices stack into one
[B, m, n] batched polar call per bucket, so the whole tree costs a
constant number of compiled NS chains (and Pallas launches) instead of
one per leaf.  ``cfg.bucketed=False`` restores the per-leaf loop.

Under pjit the polar iteration's GEMMs run on *sharded* momentum matrices,
so orthogonalization is distributed for free (DION-style), and the PRISM
sketch fit adds only O(n^2 p / shards) work per fitted iteration.  With an
activation-sharding context the bucketed engine additionally shard_maps
each bucket's batch dim over the (pod, data) axes (DESIGN.md §8).

``cfg.precond_every = K > 1`` amortizes the matrix-function work over K
steps: every matrix leaf carries a cached orthogonalized view ("ortho")
in the state, refreshed when count % K == 0 (exact at step 0) and reused
— against the *fresh* momentum-accumulating state — in between.  The
``refresh`` argument of ``update`` overrides the schedule statically: a
Python bool picks the branch at trace time, so a skip step compiles with
zero matrix-function work (the launch-count contract of DESIGN.md §8).

Precision (DESIGN.md §9): ``cfg.matfn_dtype`` sets the compute dtype of
the whole orthogonalization path (bucket gathers stack directly in bf16);
``cfg.cache_dtype`` sets the storage dtype of the "ortho" cache — every
step (refresh or stale) applies the cache-dtype polar, so the update
direction is schedule-invariant.  Momentum and the applied parameter
delta stay fp32.

Adaptive early stopping (DESIGN.md §11): ``cfg.matfn_tol`` threads a
convergence certificate into every bucketed polar chain — each bucket
iterates only until its slowest slice certifies, instead of the full
static budget — and the realized per-matrix iteration counts surface as
an ``iters`` entry in each matrix leaf's state (``cfg.matfn_telemetry``).

Async refresh plane (DESIGN.md §12): with ``cfg.precond_async`` the
polar chains NEVER run inside ``update``.  Each matrix leaf carries an
active "ortho" buffer (consumed every step) and a pending "ortho_p"
twin; the separately jitted ``refresh`` member recomputes the pending
polars from the stored momentum (bucketing.polar_refresh — the same
computation an in-step refresh would run) and the update swaps
pending -> active under ONE lax.cond once
``count >= pending_at + precond_swap_delay``.  The update also
accumulates the drift proxy ("dnorm"/"rnorm": movement of the momentum
relative to its norm at the last refresh dispatch) that feeds the
drift-triggered schedule.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.core import matfn
from repro.optim import base, bucketing


def _flatten_with_axes(params, axes_tree):
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(
        axes_tree, is_leaf=lambda t: isinstance(t, tuple) and
        all(isinstance(x, (str, type(None))) for x in t))
    treedef = jax.tree.structure(params)
    return flat_p, flat_a, treedef


def make_muon(cfg: OptimizerConfig, axes_tree) -> base.Optimizer:
    # §11 telemetry: with an adaptive tol the realized per-matrix
    # iteration counts ("iters", the view's lead shape, int32) ride in
    # the state next to the momentum — observability for schedules,
    # benchmarks and tests, refreshed whenever the polar chains run and
    # carried through stale (cached) steps untouched.  §15 adds the
    # per-matrix int8 guardian "status" (prism.STATUS_*) alongside, on
    # the same refresh/stale/swap lifecycle.
    telemetry = cfg.matfn_telemetry
    # §14: with the lowrank tier enabled Muon claims embedding/LM-head/
    # codebook leaves too (base.is_matrix_param), and every matrix leaf
    # carries a static "tier" telemetry scalar naming the kernel tier
    # the bucketing planner resolves for its view shape.
    allow_embed = cfg.lowrank_rank > 0

    def init(params):
        flat_p, flat_a, treedef = _flatten_with_axes(params, axes_tree)
        state = []
        for p, a in zip(flat_p, flat_a):
            mom = jnp.zeros(p.shape, jnp.float32)
            if base.is_matrix_param(a, p.shape, allow_embed):
                s = {"mom": mom}
                # only the view SHAPE is needed for the telemetry/cache
                # entries: eval_shape runs the view reshape abstractly,
                # so init of an embedding-bearing tree never
                # materializes a throwaway full-size zeros view
                vshape = jax.eval_shape(
                    lambda x, _a=a: base.to_matrix_view(x, _a)[0],
                    jax.ShapeDtypeStruct(p.shape, jnp.float32)).shape
                if telemetry:
                    s["iters"] = jnp.zeros(vshape[:-2], jnp.int32)
                    s["status"] = jnp.zeros(vshape[:-2], jnp.int8)
                if allow_embed:
                    s["tier"] = jnp.full(
                        (), bucketing.TIER_CODES[bucketing.resolve_tier(
                            cfg, vshape[-2:])], jnp.int32)
                if cfg.precond_every > 1:
                    # staleness cache: the orthogonalized momentum VIEW
                    # (possibly transposed/flattened vs the param layout);
                    # stored in cfg.cache_dtype — bf16 halves cached
                    # optimizer state, sharding rules unchanged (§9).
                    # Under §14 this is the LIFTED full-size view, so the
                    # §12 double buffer and the precond-cache sharding
                    # rules apply to the lowrank tier without special
                    # cases.
                    s["ortho"] = jnp.zeros(vshape,
                                           jnp.dtype(cfg.cache_dtype))
                if cfg.precond_async:
                    # §12 double buffer: pending twin (sharded like the
                    # active cache) + the drift-proxy scalars
                    s["ortho_p"] = jnp.zeros(vshape,
                                             jnp.dtype(cfg.cache_dtype))
                    s["dnorm"] = jnp.zeros((), jnp.float32)
                    s["rnorm"] = jnp.zeros((), jnp.float32)
                    if telemetry:
                        s["iters_p"] = jnp.zeros(vshape[:-2], jnp.int32)
                        s["status_p"] = jnp.zeros(vshape[:-2], jnp.int8)
                state.append(s)
            else:
                state.append({"mom": mom,
                              "nu": jnp.zeros(p.shape, jnp.float32)})
        out = {"leaves": jax.tree.unflatten(treedef, state),
               "count": jnp.zeros((), jnp.int32)}
        if cfg.precond_async:
            # step index the in-flight refresh was dispatched at;
            # NO_PENDING = nothing in flight (swap cond never taken)
            out["pending_at"] = jnp.full((), base.NO_PENDING, jnp.int32)
        return out

    def _polar_per_leaf(views, leaf_idx, key):
        """Legacy per-leaf dispatch: one polar chain per matrix leaf.
        Returns (outs, iters, statuses) with the latter two None unless
        telemetry."""
        outs, its, sts = [], [], []
        for M, i in zip(views, leaf_idx):
            if cfg.muon_local_reshard and M.ndim >= 3:
                # layers -> model, rows -> data: the NS iterations then
                # need only one [n, n] R-psum over 16 chips per step
                # instead of cross-mesh GEMM collectives
                from repro.sharding_ctx import shard_activation

                M = shard_activation(
                    M, ("opt_layers",) * (M.ndim - 2)
                    + ("opt_rows", None))
            kk = jax.random.fold_in(key, i) if key is not None else None
            if cfg.matfn_method == "svd":
                if telemetry:
                    O, it, st = matfn.polar(M, method="svd",
                                            return_iters=True,
                                            return_status=True)
                    outs.append(O)
                    its.append(it)
                    sts.append(st)
                else:
                    outs.append(matfn.polar(M, method="svd"))
            elif telemetry:
                O, it, st = matfn.polar(M, method=cfg.matfn_method,
                                        cfg=cfg.resolved_prism, key=kk,
                                        return_iters=True,
                                        return_status=True)
                outs.append(O)
                its.append(it)
                sts.append(st)
            else:
                outs.append(matfn.polar(M, method=cfg.matfn_method,
                                        cfg=cfg.resolved_prism, key=kk))
        return (outs, its, sts) if telemetry else (outs, None, None)

    def update(grads, state, params, step, key, refresh=None):
        flat_g, flat_a, treedef = _flatten_with_axes(grads, axes_tree)
        flat_p = jax.tree.leaves(params)
        flat_s = treedef.flatten_up_to(state["leaves"])
        lr = cfg.learning_rate
        new_p = [None] * len(flat_g)
        new_s = [None] * len(flat_g)
        # pass 1: momentum everywhere; AdamW leaves finish immediately,
        # matrix leaves only queue their nesterov momentum view
        views, metas, leaf_idx = [], [], []
        for i, (g, a, p, s) in enumerate(zip(flat_g, flat_a, flat_p,
                                             flat_s)):
            g = g.astype(jnp.float32)
            if base.is_matrix_param(a, p.shape, allow_embed):
                mom = cfg.momentum * s["mom"] + g
                gm = g + cfg.momentum * mom  # nesterov
                M, meta = base.to_matrix_view(gm, a)
                views.append(M)
                metas.append(meta)
                leaf_idx.append(i)
                new_s[i] = {"mom": mom}
                if allow_embed:
                    new_s[i]["tier"] = s["tier"]
                if cfg.precond_every > 1:
                    new_s[i]["ortho"] = s["ortho"]
                if cfg.precond_async:
                    # drift proxy (§12): accumulate the Frobenius
                    # movement of the momentum (the matrix the cached
                    # polar was computed from) since the last refresh
                    # dispatch; read back as dnorm/rnorm by
                    # base.precond_drift
                    new_s[i]["dnorm"] = s["dnorm"] + jnp.sqrt(
                        jnp.sum(jnp.square(mom - s["mom"])))
                    new_s[i]["rnorm"] = s["rnorm"]
            else:
                # AdamW for non-matrix params
                b1, b2 = cfg.beta1, cfg.beta2
                mom = b1 * s["mom"] + (1 - b1) * g
                nu = b2 * s["nu"] + (1 - b2) * jnp.square(g)
                t = (state["count"] + 1).astype(jnp.float32)
                mhat = mom / (1 - b1 ** t)
                vhat = nu / (1 - b2 ** t)
                alr = lr * cfg.adamw_lr_scale
                p32 = p.astype(jnp.float32) * (1.0 - alr * cfg.weight_decay) \
                    - alr * mhat / (jnp.sqrt(vhat) + cfg.eps)
                new_s[i] = {"mom": mom, "nu": nu}
                new_p[i] = p32.astype(p.dtype)
        # orthogonalize: one batched call per shape bucket (the per-leaf
        # Python loop survives only behind cfg.bucketed=False).  With
        # precond_every=K>1 the polar chains run behind the staleness
        # schedule: refreshed when count % K == 0 (or when the static
        # ``refresh`` override says so), served from the "ortho" cache
        # otherwise — a skip step moves zero matrix-function bytes.
        def compute_polars():
            if cfg.bucketed:
                if telemetry:
                    return bucketing.polar_bucketed(views, cfg, key,
                                                    with_iters=True)
                return (bucketing.polar_bucketed(views, cfg, key),
                        None, None)
            return _polar_per_leaf(views, leaf_idx, key)

        if cfg.precond_async and views:
            # §12 steady state: NEVER compute polars here.  Serve the
            # active cache, except when an in-flight refresh has had
            # precond_swap_delay steps to land — then ONE lax.cond swaps
            # every leaf's pending buffer in (a local per-shard select,
            # no matfn launches, no collectives).
            pend = [flat_s[i]["ortho_p"] for i in leaf_idx]
            act = [flat_s[i]["ortho"] for i in leaf_idx]
            pending_at = state["pending_at"]
            do_swap = (pending_at > base.NO_PENDING) & (
                state["count"] >= pending_at + cfg.precond_swap_delay)
            none_pending = jnp.full((), base.NO_PENDING, jnp.int32)
            if telemetry:
                it_p = [flat_s[i]["iters_p"] for i in leaf_idx]
                it_a = [flat_s[i]["iters"] for i in leaf_idx]
                st_p = [flat_s[i]["status_p"] for i in leaf_idx]
                st_a = [flat_s[i]["status"] for i in leaf_idx]
                polars, its, sts, new_pending_at = jax.lax.cond(
                    do_swap,
                    lambda: (pend, it_p, st_p, none_pending),
                    lambda: (act, it_a, st_a, pending_at))
            else:
                its = sts = None
                polars, new_pending_at = jax.lax.cond(
                    do_swap,
                    lambda: (pend, none_pending),
                    lambda: (act, pending_at))
            for j, i in enumerate(leaf_idx):
                new_s[i]["ortho"] = polars[j]
                new_s[i]["ortho_p"] = pend[j]
                if telemetry:
                    new_s[i]["iters_p"] = it_p[j]
                    new_s[i]["status_p"] = st_p[j]
        elif cfg.precond_every > 1 and views:
            cache_dt = jnp.dtype(cfg.cache_dtype)
            cached = [flat_s[i]["ortho"] for i in leaf_idx]
            cached_it = ([flat_s[i]["iters"] for i in leaf_idx]
                         if telemetry else None)
            cached_st = ([flat_s[i]["status"] for i in leaf_idx]
                         if telemetry else None)

            def compute_cached():
                # round to the cache dtype up front: both lax.cond
                # branches carry the same dtype, and refresh vs stale
                # steps apply identical (cache-rounded) polars
                polars, its, sts = compute_polars()
                return [O.astype(cache_dt) for O in polars], its, sts

            def stale():
                # stale steps reuse the cache AND its telemetry: "iters"
                # and "status" always describe the most recent refresh
                return (list(cached),
                        list(cached_it) if telemetry else None,
                        list(cached_st) if telemetry else None)

            if isinstance(refresh, bool):  # static: picked at trace time
                polars, its, sts = compute_cached() if refresh else stale()
            else:
                do = (state["count"] % cfg.precond_every) == 0
                polars, its, sts = jax.lax.cond(do, compute_cached, stale)
            for j, i in enumerate(leaf_idx):
                new_s[i]["ortho"] = polars[j]
        else:
            polars, its, sts = compute_polars()
        if telemetry:
            for j, i in enumerate(leaf_idx):
                new_s[i]["iters"] = its[j]
                new_s[i]["status"] = sts[j]
        # pass 2: aspect-scale, un-view, apply
        for O, meta, i in zip(polars, metas, leaf_idx):
            p = flat_p[i]
            m_, n_ = O.shape[-2], O.shape[-1]
            scale = jnp.sqrt(jnp.maximum(1.0, m_ / n_))
            upd = base.from_matrix_view(O * scale, meta)
            p32 = p.astype(jnp.float32) * (1.0 - lr * cfg.weight_decay) \
                - lr * upd
            new_p[i] = p32.astype(p.dtype)
        out_state = {"leaves": jax.tree.unflatten(treedef, new_s),
                     "count": state["count"] + 1}
        if cfg.precond_async:
            out_state["pending_at"] = (new_pending_at if views
                                       else state["pending_at"])
        return jax.tree.unflatten(treedef, new_p), out_state

    def refresh(state, key):
        """§12 refresh plane: recompute the pending polar buffers from
        the STORED momentum (the matrix the active cache will have been
        computed from by swap time) as one standalone jittable program.
        Returns per-slot partial dicts for base.install_pending."""
        slots, _ = base._flat_slots(state["leaves"])
        flat_a = jax.tree.leaves(
            axes_tree, is_leaf=lambda t: isinstance(t, tuple) and
            all(isinstance(x, (str, type(None))) for x in t))
        views, idx = [], []
        for i, (s, a) in enumerate(zip(slots, flat_a)):
            if "ortho_p" in s:
                M, _meta = base.to_matrix_view(s["mom"], a)
                views.append(M)
                idx.append(i)
        partials: list = [{} for _ in slots]
        if not views:
            return partials
        outs, its, sts = bucketing.polar_refresh(views, cfg, key)
        cache_dt = jnp.dtype(cfg.cache_dtype)
        for j, i in enumerate(idx):
            # zero-slice guard: the bootstrap dispatch runs before any
            # update, so the momentum can be exactly zero — the PRISM
            # alpha fit on zero traces is 0/0.  A zero matrix's polar
            # serves as zero (a no-op update), not NaN.
            nrm = jnp.sqrt(jnp.sum(jnp.square(views[j]), axis=(-2, -1),
                                   keepdims=True))
            O = jnp.where(nrm > 0, outs[j], jnp.zeros_like(outs[j]))
            p = {"ortho_p": O.astype(cache_dt),
                 # drift baseline resets to the dispatched matrix
                 "rnorm": jnp.sqrt(jnp.sum(jnp.square(views[j]))),
                 "dnorm": jnp.zeros((), jnp.float32)}
            if telemetry:
                p["iters_p"] = its[j]
                p["status_p"] = sts[j]
            partials[i] = p
        return partials

    return base.Optimizer(init, update, refresh)
