"""Gradient compression for cross-pod data parallelism.

int8 blockwise quantization with per-block fp32 scales (1/256 of the
bandwidth for the scales): the pod-axis gradient all-reduce moves 4x fewer
bytes than fp32 (2x vs bf16).  Two entry points:

  int8_roundtrip(tree)       quantize+dequantize in place — models the
                             numerics inside a pjit step where the
                             all-reduce itself is implicit (XLA SPMD).
  int8_psum(x, axis)         explicit quantize -> psum -> dequantize for
                             shard_map pod-DP loops (true bandwidth win).

Note (DESIGN.md §4): under pure pjit the gradient reduction is inserted by
XLA, so the *bandwidth* saving requires the explicit shard_map path; the
pjit path applies the same quantization error so convergence behavior is
faithfully modeled either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jax.Array):
    xf = x.astype(jnp.float32).reshape(-1)
    pad = (-xf.shape[0]) % BLOCK
    xp = jnp.pad(xf, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale, x.shape, pad


def _dequantize(q, scale, shape, pad):
    xf = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        xf = xf[:-pad]
    return xf.reshape(shape)


def int8_roundtrip_leaf(x: jax.Array) -> jax.Array:
    q, s, shape, pad = _quantize(x)
    return _dequantize(q, s, shape, pad).astype(x.dtype)


def int8_roundtrip(tree):
    return jax.tree.map(int8_roundtrip_leaf, tree)


def int8_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantize -> int32 psum -> dequantize, inside shard_map/pmap."""
    q, s, shape, pad = _quantize(x)
    # scales differ per shard, and a psum of dequantized fp32 blocks
    # would lose the bandwidth win — so every shard re-expresses its
    # payload against the block's shared max scale (one fp32 pmax over
    # the [-,1] scale column, 1/256 of the payload) and the int32 psum
    # of the rescaled payloads is the ONLY full-size collective:
    smax = jax.lax.pmax(s, axis_name)
    ratio = s / smax
    qr = jnp.round(q.astype(jnp.float32) * ratio).astype(jnp.int32)
    qsum = jax.lax.psum(qr, axis_name)
    out = (qsum.astype(jnp.float32) * smax).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(x.dtype)
