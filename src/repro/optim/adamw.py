"""AdamW baseline optimizer (paper Sec. 6.2 comparison)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.optim import base


def make_adamw(cfg: OptimizerConfig) -> base.Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mom": z,
                "nu": jax.tree.map(jnp.copy, z),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step, key, refresh=None):
        # refresh is the matrix-preconditioner staleness override (see
        # base.Optimizer); AdamW has no preconditioner cache to refresh
        b1, b2 = cfg.beta1, cfg.beta2
        t = (state["count"] + 1).astype(jnp.float32)
        mom = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                           g.astype(jnp.float32), state["mom"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)

        def upd(p, m, v):
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            p32 = p.astype(jnp.float32)
            p32 = p32 * (1.0 - cfg.learning_rate * cfg.weight_decay) \
                - cfg.learning_rate * mhat / (jnp.sqrt(vhat) + cfg.eps)
            return p32.astype(p.dtype)

        new_p = jax.tree.map(upd, params, mom, nu)
        return new_p, {"mom": mom, "nu": nu, "count": state["count"] + 1}

    return base.Optimizer(init, update)
