"""Optimizers: Muon(+PRISM), Shampoo(+PRISM), AdamW, compression."""
from repro.config import OptimizerConfig
from repro.optim import base, compression
from repro.optim.adamw import make_adamw
from repro.optim.muon import make_muon
from repro.optim.shampoo import make_shampoo


def make_optimizer(cfg: OptimizerConfig, axes_tree=None) -> base.Optimizer:
    if cfg.name == "muon":
        assert axes_tree is not None
        opt = make_muon(cfg, axes_tree)
    elif cfg.name == "shampoo":
        assert axes_tree is not None
        opt = make_shampoo(cfg, axes_tree)
    elif cfg.name == "adamw":
        opt = make_adamw(cfg)
    else:
        raise ValueError(f"unknown optimizer {cfg.name!r}")
    if cfg.skip_nonfinite:
        # §15 skip-step guard: roll back params AND state on any
        # non-finite gradient/update under one lax.cond (base.py)
        opt = base.skip_nonfinite(opt, cfg)
    return opt


__all__ = ["base", "compression", "make_adamw", "make_muon",
           "make_shampoo", "make_optimizer"]
