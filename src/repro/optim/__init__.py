"""Optimizers: Muon(+PRISM), Shampoo(+PRISM), AdamW, compression."""
from repro.config import OptimizerConfig
from repro.optim import base, compression
from repro.optim.adamw import make_adamw
from repro.optim.muon import make_muon
from repro.optim.shampoo import make_shampoo


def make_optimizer(cfg: OptimizerConfig, axes_tree=None) -> base.Optimizer:
    if cfg.name == "muon":
        assert axes_tree is not None
        return make_muon(cfg, axes_tree)
    if cfg.name == "shampoo":
        assert axes_tree is not None
        return make_shampoo(cfg, axes_tree)
    if cfg.name == "adamw":
        return make_adamw(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


__all__ = ["base", "compression", "make_adamw", "make_muon",
           "make_shampoo", "make_optimizer"]
