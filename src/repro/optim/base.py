"""Optimizer interface + shared utilities (pure pytree, optax-free)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    """init(params) -> state;
    update(grads, state, params, step, key, refresh=None)
        -> (new_params, new_state).

    ``refresh`` is the staleness-schedule override for cached matrix
    preconditioners (OptimizerConfig.precond_every, DESIGN.md §8):
      None  — dynamic: the optimizer decides from state["count"] under a
              lax.cond (single compiled step, both branches traced);
      bool  — static: the branch is picked at trace time, so the trainer
              can compile a skip-step variant that contains zero
              matrix-function work (and a refresh variant that always
              recomputes).  Optimizers without caches ignore it.

    The REFRESH PLANE (DESIGN.md §12) extends that contract from "skip"
    to "never-in-step": with ``OptimizerConfig.precond_async`` the update
    only ever consumes the ACTIVE preconditioner buffer (and swaps a
    PENDING one in under a lax.cond — zero matrix-function launches on
    every step), while the matfn chains themselves live in the separate
    ``refresh`` callable:

        refresh(state, key) -> flat list of per-slot partial dicts

    — one dict per state slot (the flattened order of
    ``_flat_slots(state["leaves"])``), holding exactly the entries to
    overwrite: the pending buffers (``ortho_p`` / ``Linv_p`` /
    ``Rinv_p``), the drift-reference norm ``rnorm``, a zeroed drift
    accumulator ``dnorm``, and pending telemetry twins.  The trainer jits
    and dispatches it WITHOUT blocking and installs the result via
    ``install_pending`` (pure pytree surgery — no device compute), so
    steps overlap the chains instead of waiting on them.  ``None`` for
    optimizers without a cached-preconditioner plane (AdamW).
    """

    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]
    refresh: Optional[Callable] = None


#: optimizer-state entries that belong to the in-flight half of the
#: double buffer (DESIGN.md §12): the pending preconditioners and their
#: telemetry twins.  Checkpoints may drop them (checkpoint.save(drop=))
#: — a restore then starts from a mark-stale state (discard_pending)
#: instead of ever consuming a half-written buffer.
PENDING_STATE_KEYS = frozenset({
    "ortho_p", "Linv_p", "Rinv_p",
    "iters_p", "Linv_iters_p", "Rinv_iters_p",
})

#: ``state["pending_at"]`` value meaning "no refresh in flight".  A large
#: negative sentinel rather than -1: the bootstrap dispatch BACK-DATES
#: ``pending_at = step - precond_swap_delay`` (possibly negative) so its
#: swap fires on the dispatching step itself, and that must stay
#: distinguishable from "none".
NO_PENDING = -(1 << 30)


def resolve_refresh_period(cfg, name: Optional[str] = None) -> int:
    """Effective preconditioner refresh period K for one optimizer.

    The single source of truth for the staleness clock (DESIGN.md §8/
    §12): Muon refreshes every ``precond_every`` steps; Shampoo honors
    its legacy ``precondition_every`` knob too, so its period is the max
    of the two.  ``name`` overrides ``cfg.name`` (for callers holding a
    config reused across optimizers).  Trainers, the async service and
    the optimizers themselves all derive the modulus from here.
    """
    name = cfg.name if name is None else name
    k = max(1, int(cfg.precond_every))
    if name == "shampoo":
        k = max(k, int(cfg.precondition_every))
    return k


# ----------------------------------------------------------- refresh plane

def _is_slot(x) -> bool:
    return isinstance(x, dict) and "mom" in x


def _flat_slots(leaves_tree):
    """Flatten a state["leaves"] tree up to its per-param slot dicts.

    Returns (slots, treedef); the slot order matches the flattened param
    order (the tree has the params' structure with a dict at every leaf
    position), so it aligns with the optimizers' flat gradient lists and
    with the partial-update lists an ``Optimizer.refresh`` returns.
    """
    treedef = jax.tree.structure(leaves_tree, is_leaf=_is_slot)
    return treedef.flatten_up_to(leaves_tree), treedef


def install_pending(state, partials, at_step: int):
    """Merge an ``Optimizer.refresh`` result into the state (§12).

    Pure Python pytree surgery — replaces leaf references, runs zero
    device compute, and in particular does NOT make subsequent steps'
    unchanged leaves depend on the refresh computation (the whole point
    of dispatching the chains asynchronously).  ``at_step`` stamps
    ``pending_at``: the update swaps pending -> active once
    ``count >= pending_at + precond_swap_delay``.
    """
    slots, treedef = _flat_slots(state["leaves"])
    merged = [dict(s, **p) if p else s for s, p in zip(slots, partials)]
    return dict(state, leaves=treedef.unflatten(merged),
                pending_at=jnp.asarray(at_step, jnp.int32))


def discard_pending(state):
    """Mark any in-flight pending preconditioner stale (§12): a state
    restored mid-interval (checkpoint resume, elastic restart) must
    never swap in a buffer whose payload was dropped from the checkpoint
    or written by a run with a different schedule.  No-op for states
    without a refresh plane."""
    if not isinstance(state, dict) or "pending_at" not in state:
        return state
    return dict(state, pending_at=jnp.full((), NO_PENDING, jnp.int32))


def precond_drift(state) -> jax.Array:
    """Relative drift of the cached preconditioners since the last
    refresh dispatch (§12): max over slots of ``dnorm / rnorm``, where
    ``rnorm`` is the Frobenius norm of the matrix the cache was computed
    from and ``dnorm`` the accumulated per-step movement of that matrix.
    0 for states without drift tracking.  Cheap (a handful of scalars) —
    the trainer surfaces it in the step metrics and feeds it back to the
    AsyncPrecondService's trigger."""
    if not isinstance(state, dict) or "leaves" not in state:
        return jnp.zeros((), jnp.float32)
    slots, _ = _flat_slots(state["leaves"])
    ds = [s["dnorm"] / jnp.maximum(s["rnorm"], 1e-12)
          for s in slots if _is_slot(s) and "dnorm" in s]
    if not ds:
        return jnp.zeros((), jnp.float32)
    return jnp.max(jnp.stack(ds))


class AsyncPrecondService:
    """Host-side scheduler of the double-buffered refresh plane (§12).

    Owns the Python half of the async contract: decides WHEN to dispatch
    a refresh (drift trigger with the fixed clock as ceiling), dispatches
    the jitted ``Optimizer.refresh`` without blocking, installs the
    pending buffers via ``install_pending``, and keeps the
    ``matfn_telemetry`` counters the trainer logs.

    >>> svc.matfn_telemetry                      # doctest: +SKIP
    {'refreshes': 7, 'drift_triggered': 5, 'clock_triggered': 1,
     'bootstrap': 1, 'last_drift': 0.013}
    """

    def __init__(self, opt: Optimizer, cfg, refresh_jit=None):
        assert opt.refresh is not None, \
            "optimizer has no refresh plane (AdamW?)"
        self.cfg = cfg
        self.period = resolve_refresh_period(cfg)
        self.swap_delay = int(cfg.precond_swap_delay)
        self.threshold = cfg.drift_threshold
        self._refresh = refresh_jit if refresh_jit is not None \
            else jax.jit(opt.refresh)
        self.last_dispatch: Optional[int] = None
        self.last_drift: float = 0.0
        self.counters = {"refreshes": 0, "drift_triggered": 0,
                         "clock_triggered": 0, "bootstrap": 0}

    def due(self, step: int, drift: float) -> Optional[str]:
        """None, or why a refresh should dispatch at ``step``."""
        if self.last_dispatch is None:
            return "bootstrap"
        if step <= self.last_dispatch + self.swap_delay:
            # previous refresh's swap has not run yet (it runs inside the
            # update of step last_dispatch + swap_delay): dispatching now
            # would overwrite a never-consumed pending buffer
            return None
        if step - self.last_dispatch >= self.period:
            return "clock_triggered"  # the fixed-schedule ceiling
        if self.threshold is not None and drift >= self.threshold:
            return "drift_triggered"
        return None

    def step_begin(self, state, step: int, key, drift: float = 0.0):
        """Phase 1 of the two-phase step loop: maybe dispatch a refresh.

        Non-blocking — the chains are enqueued and the pending buffers
        installed as futures; nothing here waits on device compute.  The
        bootstrap dispatch back-dates ``pending_at`` so its swap fires on
        this very step (the first step then waits on its own
        preconditioner, exactly like a blocking first step would).
        """
        self.last_drift = drift
        reason = self.due(step, drift)
        if reason is None:
            return state
        partials = self._refresh(state, key)
        at = step - self.swap_delay if reason == "bootstrap" else step
        state = install_pending(state, partials, at)
        self.last_dispatch = step
        self.counters["refreshes"] += 1
        self.counters[reason] += 1
        return state

    @property
    def matfn_telemetry(self) -> dict:
        return dict(self.counters, last_drift=self.last_drift)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def is_matrix_param(path_axes: tuple, shape: tuple,
                    allow_embed: bool = False) -> bool:
    """Muon applies to hidden weight matrices: >=2D, both matrix dims
    reasonably large, and not an embedding/vocab/codebook table.

    ``allow_embed`` lifts the table exclusion: with the §14 lowrank tier
    enabled (OptimizerConfig.lowrank_rank > 0) Muon claims vocab/codebook
    leaves too — the bucketing planner then routes any view too large or
    too rectangular for the cubic path through the sketched subspace
    chains instead of letting it fall back to scaled AdamW.
    """
    if not allow_embed and any(a in ("vocab", "codebooks")
                               for a in path_axes if a):
        return False
    dims = matrix_view_dims(path_axes, shape)
    if dims is None:
        return False
    m, n = dims
    return min(m, n) >= 16


def matrix_view_dims(path_axes: tuple, shape: tuple) -> Optional[tuple]:
    """(rows, cols) of the Muon matrix view; None if not matrix-like.

    The 'embed' logical axis marks the contraction side: the matrix is
    (embed-dim) x (product of remaining non-batch dims).  Leading 'layers'
    / 'experts' axes are batch.  Without an 'embed' tag, the last two dims
    form the matrix (generic case).
    """
    axes = tuple(path_axes)
    batch = {"layers", "experts"}
    non_batch = [(i, a) for i, a in enumerate(axes) if a not in batch]
    if len(non_batch) < 2:
        return None
    idxs = [i for i, _ in non_batch]
    names = [a for _, a in non_batch]
    if "embed" in names:
        e = idxs[names.index("embed")]
        rest = [i for i in idxs if i != e]
        m = shape[e]
        n = 1
        for i in rest:
            n *= shape[i]
        return (m, n)
    m = shape[idxs[-2]]
    n = shape[idxs[-1]]
    for i in idxs[:-2]:
        m *= shape[i]
    return (m, n)


def to_matrix_view(p: jax.Array, path_axes: tuple) -> jax.Array:
    """Reshape p to [..batch.., m, n] with 'embed' as the row dim (possibly
    transposed into place).  Inverse via from_matrix_view."""
    axes = tuple(path_axes)
    batch = {"layers", "experts"}
    batch_idx = [i for i, a in enumerate(axes) if a in batch]
    other_idx = [i for i, a in enumerate(axes) if a not in batch]
    names = [axes[i] for i in other_idx]
    if "embed" in names:
        e = other_idx[names.index("embed")]
        rest = [i for i in other_idx if i != e]
        perm = batch_idx + [e] + rest
        q = jnp.transpose(p, perm)
        lead = tuple(p.shape[i] for i in batch_idx)
        m = p.shape[e]
        n = 1
        for i in rest:
            n *= p.shape[i]
        return q.reshape(lead + (m, n)), (perm, q.shape)
    lead = tuple(p.shape[i] for i in batch_idx)
    m = p.shape[other_idx[-2]] if len(other_idx) >= 2 else 1
    rest = tuple(p.shape[i] for i in other_idx)
    q = jnp.transpose(p, batch_idx + other_idx)
    mm = 1
    for d in rest[:-1]:
        mm *= d
    return q.reshape(lead + (mm, rest[-1])), \
        (batch_idx + other_idx, q.shape)


def from_matrix_view(q: jax.Array, meta) -> jax.Array:
    perm, mid_shape = meta
    q = q.reshape(mid_shape)
    inv = [0] * len(perm)
    for i, p in enumerate(perm):
        inv[p] = i
    return jnp.transpose(q, inv)
