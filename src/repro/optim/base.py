"""Optimizer interface + shared utilities (pure pytree, optax-free)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    """init(params) -> state;
    update(grads, state, params, step, key, refresh=None)
        -> (new_params, new_state).

    ``refresh`` is the staleness-schedule override for cached matrix
    preconditioners (OptimizerConfig.precond_every, DESIGN.md §8):
      None  — dynamic: the optimizer decides from state["count"] under a
              lax.cond (single compiled step, both branches traced);
      bool  — static: the branch is picked at trace time, so the trainer
              can compile a skip-step variant that contains zero
              matrix-function work (and a refresh variant that always
              recomputes).  Optimizers without caches ignore it.
    """

    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def is_matrix_param(path_axes: tuple, shape: tuple) -> bool:
    """Muon applies to hidden weight matrices: >=2D, both matrix dims
    reasonably large, and not an embedding/vocab/codebook table."""
    if any(a in ("vocab", "codebooks") for a in path_axes if a):
        return False
    dims = matrix_view_dims(path_axes, shape)
    if dims is None:
        return False
    m, n = dims
    return min(m, n) >= 16


def matrix_view_dims(path_axes: tuple, shape: tuple) -> Optional[tuple]:
    """(rows, cols) of the Muon matrix view; None if not matrix-like.

    The 'embed' logical axis marks the contraction side: the matrix is
    (embed-dim) x (product of remaining non-batch dims).  Leading 'layers'
    / 'experts' axes are batch.  Without an 'embed' tag, the last two dims
    form the matrix (generic case).
    """
    axes = tuple(path_axes)
    batch = {"layers", "experts"}
    non_batch = [(i, a) for i, a in enumerate(axes) if a not in batch]
    if len(non_batch) < 2:
        return None
    idxs = [i for i, _ in non_batch]
    names = [a for _, a in non_batch]
    if "embed" in names:
        e = idxs[names.index("embed")]
        rest = [i for i in idxs if i != e]
        m = shape[e]
        n = 1
        for i in rest:
            n *= shape[i]
        return (m, n)
    m = shape[idxs[-2]]
    n = shape[idxs[-1]]
    for i in idxs[:-2]:
        m *= shape[i]
    return (m, n)


def to_matrix_view(p: jax.Array, path_axes: tuple) -> jax.Array:
    """Reshape p to [..batch.., m, n] with 'embed' as the row dim (possibly
    transposed into place).  Inverse via from_matrix_view."""
    axes = tuple(path_axes)
    batch = {"layers", "experts"}
    batch_idx = [i for i, a in enumerate(axes) if a in batch]
    other_idx = [i for i, a in enumerate(axes) if a not in batch]
    names = [axes[i] for i in other_idx]
    if "embed" in names:
        e = other_idx[names.index("embed")]
        rest = [i for i in other_idx if i != e]
        perm = batch_idx + [e] + rest
        q = jnp.transpose(p, perm)
        lead = tuple(p.shape[i] for i in batch_idx)
        m = p.shape[e]
        n = 1
        for i in rest:
            n *= p.shape[i]
        return q.reshape(lead + (m, n)), (perm, q.shape)
    lead = tuple(p.shape[i] for i in batch_idx)
    m = p.shape[other_idx[-2]] if len(other_idx) >= 2 else 1
    rest = tuple(p.shape[i] for i in other_idx)
    q = jnp.transpose(p, batch_idx + other_idx)
    mm = 1
    for d in rest[:-1]:
        mm *= d
    return q.reshape(lead + (mm, rest[-1])), \
        (batch_idx + other_idx, q.shape)


def from_matrix_view(q: jax.Array, meta) -> jax.Array:
    perm, mid_shape = meta
    q = q.reshape(mid_shape)
    inv = [0] * len(perm)
    for i, p in enumerate(perm):
        inv[p] = i
    return jnp.transpose(q, inv)
