"""Optimizer interface + shared utilities (pure pytree, optax-free)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    """init(params) -> state;
    update(grads, state, params, step, key, refresh=None)
        -> (new_params, new_state).

    ``refresh`` is the staleness-schedule override for cached matrix
    preconditioners (OptimizerConfig.precond_every, DESIGN.md §8):
      None  — dynamic: the optimizer decides from state["count"] under a
              lax.cond (single compiled step, both branches traced);
      bool  — static: the branch is picked at trace time, so the trainer
              can compile a skip-step variant that contains zero
              matrix-function work (and a refresh variant that always
              recomputes).  Optimizers without caches ignore it.

    The REFRESH PLANE (DESIGN.md §12) extends that contract from "skip"
    to "never-in-step": with ``OptimizerConfig.precond_async`` the update
    only ever consumes the ACTIVE preconditioner buffer (and swaps a
    PENDING one in under a lax.cond — zero matrix-function launches on
    every step), while the matfn chains themselves live in the separate
    ``refresh`` callable:

        refresh(state, key) -> flat list of per-slot partial dicts

    — one dict per state slot (the flattened order of
    ``_flat_slots(state["leaves"])``), holding exactly the entries to
    overwrite: the pending buffers (``ortho_p`` / ``Linv_p`` /
    ``Rinv_p``), the drift-reference norm ``rnorm``, a zeroed drift
    accumulator ``dnorm``, and pending telemetry twins.  The trainer jits
    and dispatches it WITHOUT blocking and installs the result via
    ``install_pending`` (pure pytree surgery — no device compute), so
    steps overlap the chains instead of waiting on them.  ``None`` for
    optimizers without a cached-preconditioner plane (AdamW).
    """

    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]
    refresh: Optional[Callable] = None


#: optimizer-state entries that belong to the in-flight half of the
#: double buffer (DESIGN.md §12): the pending preconditioners and their
#: telemetry twins.  Checkpoints may drop them (checkpoint.save(drop=))
#: — a restore then starts from a mark-stale state (discard_pending)
#: instead of ever consuming a half-written buffer.
PENDING_STATE_KEYS = frozenset({
    "ortho_p", "Linv_p", "Rinv_p",
    "iters_p", "Linv_iters_p", "Rinv_iters_p",
    "status_p", "Linv_status_p", "Rinv_status_p",
})

#: ``state["pending_at"]`` value meaning "no refresh in flight".  A large
#: negative sentinel rather than -1: the bootstrap dispatch BACK-DATES
#: ``pending_at = step - precond_swap_delay`` (possibly negative) so its
#: swap fires on the dispatching step itself, and that must stay
#: distinguishable from "none".
NO_PENDING = -(1 << 30)


def resolve_refresh_period(cfg, name: Optional[str] = None) -> int:
    """Effective preconditioner refresh period K for one optimizer.

    The single source of truth for the staleness clock (DESIGN.md §8/
    §12): Muon refreshes every ``precond_every`` steps; Shampoo honors
    its legacy ``precondition_every`` knob too, so its period is the max
    of the two.  ``name`` overrides ``cfg.name`` (for callers holding a
    config reused across optimizers).  Trainers, the async service and
    the optimizers themselves all derive the modulus from here.
    """
    name = cfg.name if name is None else name
    k = max(1, int(cfg.precond_every))
    if name == "shampoo":
        k = max(k, int(cfg.precondition_every))
    return k


# ----------------------------------------------------------- refresh plane

def _is_slot(x) -> bool:
    return isinstance(x, dict) and "mom" in x


def _flat_slots(leaves_tree):
    """Flatten a state["leaves"] tree up to its per-param slot dicts.

    Returns (slots, treedef); the slot order matches the flattened param
    order (the tree has the params' structure with a dict at every leaf
    position), so it aligns with the optimizers' flat gradient lists and
    with the partial-update lists an ``Optimizer.refresh`` returns.
    """
    treedef = jax.tree.structure(leaves_tree, is_leaf=_is_slot)
    return treedef.flatten_up_to(leaves_tree), treedef


def install_pending(state, partials, at_step: int):
    """Merge an ``Optimizer.refresh`` result into the state (§12).

    Pure Python pytree surgery — replaces leaf references, runs zero
    device compute, and in particular does NOT make subsequent steps'
    unchanged leaves depend on the refresh computation (the whole point
    of dispatching the chains asynchronously).  ``at_step`` stamps
    ``pending_at``: the update swaps pending -> active once
    ``count >= pending_at + precond_swap_delay``.
    """
    slots, treedef = _flat_slots(state["leaves"])
    merged = [dict(s, **p) if p else s for s, p in zip(slots, partials)]
    return dict(state, leaves=treedef.unflatten(merged),
                pending_at=jnp.asarray(at_step, jnp.int32))


def snapshot_overwritten_active(state, partials):
    """Per-slot snapshot of the ACTIVE (non-pending) keys a refresh
    result is about to overwrite (§15).

    ``install_pending`` merges the whole partial into the slot — the
    ``*_p`` twins stay inert until the swap, but active keys riding
    along (the ``rnorm``/``dnorm`` drift trackers, reset at dispatch)
    land immediately.  If the buffer later fails validation, the service
    restores this snapshot so a poisoned refresh leaves ZERO residue in
    the active plane (a NaN ``rnorm`` would silently disarm the drift
    trigger: NaN comparisons are False).  Pure reference capture — no
    device compute or copies."""
    slots, _ = _flat_slots(state["leaves"])
    return [{k: s[k] for k in p if k not in PENDING_STATE_KEYS and k in s}
            if p else None for s, p in zip(slots, partials)]


def discard_pending(state):
    """Mark any in-flight pending preconditioner stale (§12): a state
    restored mid-interval (checkpoint resume, elastic restart) must
    never swap in a buffer whose payload was dropped from the checkpoint
    or written by a run with a different schedule.  No-op for states
    without a refresh plane."""
    if not isinstance(state, dict) or "pending_at" not in state:
        return state
    return dict(state, pending_at=jnp.full((), NO_PENDING, jnp.int32))


def precond_drift(state) -> jax.Array:
    """Relative drift of the cached preconditioners since the last
    refresh dispatch (§12): max over slots of ``dnorm / rnorm``, where
    ``rnorm`` is the Frobenius norm of the matrix the cache was computed
    from and ``dnorm`` the accumulated per-step movement of that matrix.
    0 for states without drift tracking.  Cheap (a handful of scalars) —
    the trainer surfaces it in the step metrics and feeds it back to the
    AsyncPrecondService's trigger."""
    if not isinstance(state, dict) or "leaves" not in state:
        return jnp.zeros((), jnp.float32)
    slots, _ = _flat_slots(state["leaves"])
    ds = [s["dnorm"] / jnp.maximum(s["rnorm"], 1e-12)
          for s in slots if _is_slot(s) and "dnorm" in s]
    if not ds:
        return jnp.zeros((), jnp.float32)
    return jnp.max(jnp.stack(ds))


def _partials_finite(partials) -> jax.Array:
    """0-d bool: every float entry of a refresh result is finite.  A
    tiny jitted reduction dispatched ALONGSIDE the refresh chains (§15)
    — reading it later costs one scalar transfer, not a sync on the
    chains' GEMMs beyond what the swap itself would pay."""
    checks = [jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(partials)
              if jnp.issubdtype(l.dtype, jnp.floating)]
    if not checks:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(checks))


class AsyncPrecondService:
    """Host-side scheduler of the double-buffered refresh plane (§12).

    Owns the Python half of the async contract: decides WHEN to dispatch
    a refresh (drift trigger with the fixed clock as ceiling), dispatches
    the jitted ``Optimizer.refresh`` without blocking, installs the
    pending buffers via ``install_pending``, and keeps the
    ``matfn_telemetry`` counters the trainer logs.

    Validated install (§15): every dispatch also enqueues a tiny
    finiteness reduction over the pending twins.  The verdict is read
    just before the swap would fire; a non-finite buffer is DISCARDED
    (``discard_pending`` — the poisoned twin is never swapped active)
    and the refresh re-dispatched with capped exponential backoff
    (1, 2, 4, ... steps, capped at the refresh period).  After
    ``cfg.precond_max_retries`` consecutive failures the slot degrades
    gracefully: the update keeps serving the last good ACTIVE buffer,
    the loud ``degraded`` counter increments, and only the next regular
    clock/drift trigger tries again.

    >>> svc.matfn_telemetry                      # doctest: +SKIP
    {'refreshes': 7, 'drift_triggered': 5, 'clock_triggered': 1,
     'bootstrap': 1, 'discarded': 0, 'retries': 0, 'degraded': 0,
     'last_drift': 0.013}
    """

    def __init__(self, opt: Optimizer, cfg, refresh_jit=None):
        assert opt.refresh is not None, \
            "optimizer has no refresh plane (AdamW?)"
        self.cfg = cfg
        self.period = resolve_refresh_period(cfg)
        self.swap_delay = int(cfg.precond_swap_delay)
        self.threshold = cfg.drift_threshold
        self.max_retries = int(getattr(cfg, "precond_max_retries", 3))
        self._refresh = refresh_jit if refresh_jit is not None \
            else jax.jit(opt.refresh)
        self._validate = jax.jit(_partials_finite)
        self._pending_check = None  # in-flight finiteness verdict
        self._overwritten = None  # active-key snapshot for clean discard
        self._retry_at: Optional[int] = None
        self.failures = 0  # consecutive validation failures
        self.last_dispatch: Optional[int] = None
        self.last_drift: float = 0.0
        self.counters = {"refreshes": 0, "drift_triggered": 0,
                         "clock_triggered": 0, "bootstrap": 0,
                         "discarded": 0, "retries": 0, "degraded": 0}

    def due(self, step: int, drift: float) -> Optional[str]:
        """None, or why a refresh should dispatch at ``step``."""
        if self.last_dispatch is None:
            return "bootstrap"
        if step <= self.last_dispatch + self.swap_delay:
            # previous refresh's swap has not run yet (it runs inside the
            # update of step last_dispatch + swap_delay): dispatching now
            # would overwrite a never-consumed pending buffer.  (A
            # discarded buffer's retry is scheduled past this window, so
            # backoff re-dispatches are never blocked here.)
            return None
        if self._retry_at is not None and step >= self._retry_at:
            return "retries"  # backoff re-dispatch after a discard
        if step - self.last_dispatch >= self.period:
            return "clock_triggered"  # the fixed-schedule ceiling
        if self.threshold is not None and drift >= self.threshold:
            return "drift_triggered"
        return None

    def _check_pending(self, state, step: int, force: bool = False):
        """Read the in-flight validation verdict once the swap is about
        to fire; discard + schedule a backoff retry on failure."""
        if self._pending_check is None or self.last_dispatch is None:
            return state
        if not force and step < self.last_dispatch + self.swap_delay:
            return state  # swap not due yet — keep the check in flight
        ok = bool(self._pending_check)
        self._pending_check = None
        if ok:
            self.failures = 0
            self._retry_at = None
            self._overwritten = None
            return state
        # poisoned twin: never swap it in, and roll back the active keys
        # (drift trackers) its install overwrote — zero residue
        if self._overwritten is not None:
            state = install_pending(state, self._overwritten, 0)
            self._overwritten = None
        state = discard_pending(state)
        self.counters["discarded"] += 1
        self.failures += 1
        if self.failures >= self.max_retries:
            # degrade: keep serving the last good active buffer; only
            # the next regular clock/drift trigger re-attempts
            self.counters["degraded"] += 1
            self._retry_at = None
        else:
            backoff = min(2 ** (self.failures - 1), self.period)
            self._retry_at = step + backoff
        return state

    def step_begin(self, state, step: int, key, drift: float = 0.0):
        """Phase 1 of the two-phase step loop: validate any in-flight
        pending buffer whose swap is due, then maybe dispatch a refresh.

        Non-blocking — the chains are enqueued and the pending buffers
        installed as futures; nothing here waits on device compute
        except the one-scalar validation verdict at swap time.  The
        bootstrap dispatch back-dates ``pending_at`` so its swap fires on
        this very step (the first step then waits on its own
        preconditioner, exactly like a blocking first step would) — its
        validation is therefore read immediately too.
        """
        self.last_drift = drift
        state = self._check_pending(state, step)
        reason = self.due(step, drift)
        if reason is None:
            return state
        partials = self._refresh(state, key)
        at = step - self.swap_delay if reason == "bootstrap" else step
        self._overwritten = snapshot_overwritten_active(state, partials)
        state = install_pending(state, partials, at)
        self._pending_check = self._validate(partials)
        self.last_dispatch = step
        self._retry_at = None
        self.counters["refreshes"] += 1
        self.counters[reason] += 1
        if reason == "bootstrap":
            # bootstrap swaps inside this very step's update: the
            # verdict must be read now, not next step
            state = self._check_pending(state, step, force=True)
        return state

    @property
    def matfn_telemetry(self) -> dict:
        return dict(self.counters, last_drift=self.last_drift)


def skip_nonfinite(opt: Optimizer, cfg=None) -> Optimizer:
    """§15 skip-step guard: gate the whole (params, state) write on ONE
    fused finiteness check over the gradients and the proposed params.

    A non-finite gradient (loss spike, bf16 overflow, a poisoned batch)
    would otherwise contaminate the momentum/EMA accumulators FOREVER —
    0 * NaN is NaN, so no later step washes it out.  The guard instead
    replays the step as an exact no-op: both params and the inner state
    roll back under a single ``lax.cond`` (a per-buffer select — zero
    extra matrix-function launches, the §12 steady-state contract is
    untouched), and only a ``bad_steps`` int32 counter at the state root
    advances.  ``count`` does NOT advance on a skipped step, so the
    staleness clock never serves a cache computed across a hole.

    Checking grads AND proposed params covers both poisoning paths:
    bad inputs (grads) and bad arithmetic on good inputs (an overflowing
    EMA factor surfaces as a non-finite update before it can land).

    Wrapped via ``make_optimizer`` when ``cfg.skip_nonfinite`` — off by
    default so existing state trees stay bit-identical.  The refresh
    plane passes through unchanged (it reads ``state["leaves"]`` only,
    and install/discard_pending preserve unknown root keys).
    """
    def init(params):
        return dict(opt.init(params), bad_steps=jnp.zeros((), jnp.int32))

    def update(grads, state, params, step, key, refresh=None):
        inner = {k: v for k, v in state.items() if k != "bad_steps"}
        new_p, new_s = opt.update(grads, inner, params, step, key,
                                  refresh=refresh)
        bad = sum(jnp.sum(~jnp.isfinite(l.astype(jnp.float32)))
                  for l in jax.tree.leaves(grads) + jax.tree.leaves(new_p))
        ok = bad == 0
        out_p, out_s = jax.lax.cond(ok,
                                    lambda: (new_p, new_s),
                                    lambda: (params, inner))
        return out_p, dict(out_s, bad_steps=state["bad_steps"]
                           + (~ok).astype(jnp.int32))

    return Optimizer(init, update, opt.refresh)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    """Scale grads so their global norm is at most ``max_norm``.

    Guarded (§15): a zero tree keeps scale 1 instead of dividing by
    zero, and a NON-FINITE global norm passes the gradients through
    UNSCALED — the naive ``max_norm / gn`` would turn one inf gradient
    entry into an all-zero (gn=inf => scale 0) or all-NaN step that the
    skip-step guard downstream could no longer distinguish from a real
    signal.  The raw (possibly non-finite) norm is still returned for
    telemetry."""
    gn = global_norm(grads)
    scale = jnp.where(jnp.isfinite(gn),
                      jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12)),
                      1.0)
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def is_matrix_param(path_axes: tuple, shape: tuple,
                    allow_embed: bool = False) -> bool:
    """Muon applies to hidden weight matrices: >=2D, both matrix dims
    reasonably large, and not an embedding/vocab/codebook table.

    ``allow_embed`` lifts the table exclusion: with the §14 lowrank tier
    enabled (OptimizerConfig.lowrank_rank > 0) Muon claims vocab/codebook
    leaves too — the bucketing planner then routes any view too large or
    too rectangular for the cubic path through the sketched subspace
    chains instead of letting it fall back to scaled AdamW.
    """
    if not allow_embed and any(a in ("vocab", "codebooks")
                               for a in path_axes if a):
        return False
    dims = matrix_view_dims(path_axes, shape)
    if dims is None:
        return False
    m, n = dims
    return min(m, n) >= 16


def matrix_view_dims(path_axes: tuple, shape: tuple) -> Optional[tuple]:
    """(rows, cols) of the Muon matrix view; None if not matrix-like.

    The 'embed' logical axis marks the contraction side: the matrix is
    (embed-dim) x (product of remaining non-batch dims).  Leading 'layers'
    / 'experts' axes are batch.  Without an 'embed' tag, the last two dims
    form the matrix (generic case).
    """
    axes = tuple(path_axes)
    batch = {"layers", "experts"}
    non_batch = [(i, a) for i, a in enumerate(axes) if a not in batch]
    if len(non_batch) < 2:
        return None
    idxs = [i for i, _ in non_batch]
    names = [a for _, a in non_batch]
    if "embed" in names:
        e = idxs[names.index("embed")]
        rest = [i for i in idxs if i != e]
        m = shape[e]
        n = 1
        for i in rest:
            n *= shape[i]
        return (m, n)
    m = shape[idxs[-2]]
    n = shape[idxs[-1]]
    for i in idxs[:-2]:
        m *= shape[i]
    return (m, n)


def to_matrix_view(p: jax.Array, path_axes: tuple) -> jax.Array:
    """Reshape p to [..batch.., m, n] with 'embed' as the row dim (possibly
    transposed into place).  Inverse via from_matrix_view."""
    axes = tuple(path_axes)
    batch = {"layers", "experts"}
    batch_idx = [i for i, a in enumerate(axes) if a in batch]
    other_idx = [i for i, a in enumerate(axes) if a not in batch]
    names = [axes[i] for i in other_idx]
    if "embed" in names:
        e = other_idx[names.index("embed")]
        rest = [i for i in other_idx if i != e]
        perm = batch_idx + [e] + rest
        q = jnp.transpose(p, perm)
        lead = tuple(p.shape[i] for i in batch_idx)
        m = p.shape[e]
        n = 1
        for i in rest:
            n *= p.shape[i]
        return q.reshape(lead + (m, n)), (perm, q.shape)
    lead = tuple(p.shape[i] for i in batch_idx)
    m = p.shape[other_idx[-2]] if len(other_idx) >= 2 else 1
    rest = tuple(p.shape[i] for i in other_idx)
    q = jnp.transpose(p, batch_idx + other_idx)
    mm = 1
    for d in rest[:-1]:
        mm *= d
    return q.reshape(lead + (mm, rest[-1])), \
        (batch_idx + other_idx, q.shape)


def from_matrix_view(q: jax.Array, meta) -> jax.Array:
    perm, mid_shape = meta
    q = q.reshape(mid_shape)
    inv = [0] * len(perm)
    for i, p in enumerate(perm):
        inv[p] = i
    return jnp.transpose(q, inv)
