"""Shape-bucketed batched matrix-function engine (DESIGN.md §7).

Muon/Shampoo call ``matfn.polar`` / inverse roots once per parameter
matrix; a transformer with L distinct weight matrices therefore compiles L
independent unrolled Newton-Schulz chains and launches every kernel L
times per step.  This module collapses that dispatch layer:

  1. ``plan_buckets`` partitions the matrix views of a param tree into
     shape buckets — exact-shape groups, plus (optionally) near-miss
     shapes merged into a shared padded bucket when the area overhead
     stays under a slack bound;
  2. ``gather_bucket`` stacks each bucket into ONE [B, m, n] array
     (leading scanned-layer dims of a view flatten into B, near-miss
     shapes zero-pad to the bucket shape);
  3. one batched PRISM call runs per bucket — a single residual, a single
     shared-sketch alpha fit broadcast over B, and (with use_kernels) a
     constant number of batch-grid Pallas launches per iteration,
     independent of B and of the sketch chain length;
  4. ``scatter_bucket`` splits, un-pads and reshapes the results back.

Zero-padding is exact for the Newton-Schulz polar iterations (pad
rows/cols of X stay identically zero; the real block evolves as if
unpadded), and the sketched alpha fit is made exactly pad-blind via the
``n_real`` trace correction in ``prism.fit_alpha``.  Padding is NOT used
for the SVD method (null-space rotations can leak into the real block) or
for the coupled sqrtm family (the damped pad block perturbs the fit), so
those paths bucket exact shapes only.

The plan is pure Python over static shapes — it runs at trace time and
costs nothing inside jit.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.core import matfn


class Entry(NamedTuple):
    """One matrix view's slot inside a bucket."""

    index: int                  # position in the caller's list of views
    lead: Tuple[int, ...]       # leading (stacked-layer) dims of the view
    mshape: Tuple[int, int]     # real matrix shape (m, n)
    offset: int                 # first slice in the bucket's batch dim

    @property
    def count(self) -> int:
        c = 1
        for d in self.lead:
            c *= d
        return c


class Bucket(NamedTuple):
    shape: Tuple[int, int]      # bucket (possibly padded-to) matrix shape
    entries: Tuple[Entry, ...]
    size: int                   # total stacked batch B

    @property
    def padded(self) -> bool:
        return any(e.mshape != self.shape for e in self.entries)


def plan_buckets(shapes: Sequence[Tuple[int, ...]], *, pad: bool = False,
                 pad_slack: float = 0.25) -> Tuple[Bucket, ...]:
    """Partition view shapes [..lead.., m, n] into shape buckets.

    Exact (m, n) groups never mix orientations — (m, n) and (n, m) are
    distinct buckets.  With ``pad``, a shape joins an existing larger
    bucket target (M, N) when padding is needed ONLY on the target's
    Gram side (the min side, where polar forms its residual: cols when
    M >= N, else rows) and the padded area stays within
    M*N <= (1 + pad_slack) * m*n; targets are seeded from the largest
    shapes first so the merge is deterministic.  Gram-side-only padding
    keeps the residual's pad block coordinate-aligned (exactly I), which
    is what the n_real trace correction subtracts exactly; padding the
    other side would instead inject non-aligned rank-deficiency modes
    into the fit — analytically argmin-invariant (h(1; a) = 1) but only
    fp-approximately so near convergence — so such merges are refused.
    """
    mshapes = [(int(s[-2]), int(s[-1])) for s in shapes]
    distinct = sorted(set(mshapes), key=lambda s: (-s[0] * s[1], s))
    target = {}
    targets: List[Tuple[int, int]] = []
    for m, n in distinct:
        tgt = (m, n)
        if pad:
            for M, N in targets:
                fits = (m == M and n <= N) if M >= N else \
                    (n == N and m <= M)
                if fits and M * N <= (1 + pad_slack) * m * n:
                    tgt = (M, N)
                    break
        target[(m, n)] = tgt
        if tgt == (m, n):
            targets.append(tgt)
    groups = {}
    for i, s in enumerate(shapes):
        groups.setdefault(target[mshapes[i]], []).append(i)
    buckets = []
    for tgt in sorted(groups):
        entries, offset = [], 0
        for i in groups[tgt]:
            e = Entry(i, tuple(int(d) for d in shapes[i][:-2]),
                      mshapes[i], offset)
            entries.append(e)
            offset += e.count
        buckets.append(Bucket(tgt, tuple(entries), offset))
    return tuple(buckets)


def gather_bucket(bucket: Bucket, views: Sequence[jax.Array]) -> jax.Array:
    """Stack a bucket's views into one [B, M, N] array (zero-padded)."""
    M, N = bucket.shape
    parts = []
    for e in bucket.entries:
        v = views[e.index].reshape((e.count,) + e.mshape)
        pm, pn = M - e.mshape[0], N - e.mshape[1]
        if pm or pn:
            v = jnp.pad(v, ((0, 0), (0, pm), (0, pn)))
        parts.append(v)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def scatter_bucket(bucket: Bucket, batch: jax.Array,
                   outs: List[Optional[jax.Array]]) -> None:
    """Split [B, M, N] results back into per-view arrays (in place)."""
    for e in bucket.entries:
        m, n = e.mshape
        sl = batch[e.offset:e.offset + e.count, :m, :n]
        outs[e.index] = sl.reshape(e.lead + e.mshape)


def _gram_real_dims(bucket: Bucket) -> jax.Array:
    """Per-slice real extent of the polar Gram dimension, shape [B].

    ``newton_schulz.polar`` transposes when M < N, so the Gram residual
    lives on the min side of the BUCKET shape; each slice's real extent on
    that side feeds the n_real trace correction.
    """
    M, N = bucket.shape
    side = 1 if M >= N else 0
    reals = []
    for e in bucket.entries:
        reals.extend([e.mshape[side]] * e.count)
    return jnp.asarray(reals, jnp.int32)


def polar_bucketed(views: Sequence[jax.Array], cfg: OptimizerConfig,
                   key: Optional[jax.Array]) -> List[jax.Array]:
    """Polar factor of every matrix view via one batched call per bucket."""
    method = cfg.matfn_method
    pad = cfg.bucket_pad and method != "svd"
    buckets = plan_buckets([v.shape for v in views], pad=pad,
                           pad_slack=cfg.bucket_pad_slack)
    outs: List[Optional[jax.Array]] = [None] * len(views)
    for bi, b in enumerate(buckets):
        stacked = gather_bucket(b, views)
        if cfg.muon_local_reshard and all(e.lead for e in b.entries):
            # layers -> model, rows -> data (see make_muon): the batched NS
            # iterations then need only one [n, n] R-psum per step.  Like
            # the per-leaf path (which resharded only M.ndim >= 3 views),
            # this applies only to buckets built purely from scanned-layer
            # stacks — plain 2-D leaves keep their layout, and a mixed
            # bucket is not co-sharded unevenly over opt_layers.
            from repro.sharding_ctx import shard_activation

            stacked = shard_activation(stacked,
                                       ("opt_layers", "opt_rows", None))
        if method == "svd":
            O = matfn.polar(stacked, method="svd")
        else:
            kk = (jax.random.fold_in(key, bi) if key is not None else None)
            kw = {}
            if b.padded and method == "prism":
                kw["n_real"] = _gram_real_dims(b)
            O = matfn.polar(stacked, method=method, cfg=cfg.prism, key=kk,
                            **kw)
        scatter_bucket(b, O, outs)
    return outs  # type: ignore[return-value]


def transform_bucketed(mats: Sequence[jax.Array], fn) -> List[jax.Array]:
    """Apply ``fn(stacked, bucket, bucket_index)`` once per exact-shape
    bucket and scatter the [B, n, n] results back.

    The generic engine for matrix functions without a pad-exactness story
    (Shampoo inverse roots): fn sees the stacked bucket plus its Bucket —
    enough to gather companion arrays (cached inverses), fold a per-bucket
    PRNG key, or wrap a lax.cond around a recompute schedule.
    """
    buckets = plan_buckets([m.shape for m in mats], pad=False)
    outs: List[Optional[jax.Array]] = [None] * len(mats)
    for bi, b in enumerate(buckets):
        scatter_bucket(b, fn(gather_bucket(b, mats), b, bi), outs)
    return outs  # type: ignore[return-value]
