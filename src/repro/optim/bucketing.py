"""Shape-bucketed batched matrix-function engine (DESIGN.md §7).

Muon/Shampoo call ``matfn.polar`` / inverse roots once per parameter
matrix; a transformer with L distinct weight matrices therefore compiles L
independent unrolled Newton-Schulz chains and launches every kernel L
times per step.  This module collapses that dispatch layer:

  1. ``plan_buckets`` partitions the matrix views of a param tree into
     shape buckets — exact-shape groups, plus (optionally) near-miss
     shapes merged into a shared padded bucket when the area overhead
     stays under a slack bound;
  2. ``gather_bucket`` stacks each bucket into ONE [B, m, n] array
     (leading scanned-layer dims of a view flatten into B, near-miss
     shapes zero-pad to the bucket shape);
  3. one batched PRISM call runs per bucket — a single residual, a single
     shared-sketch alpha fit broadcast over B, and (with use_kernels) a
     constant number of batch-grid Pallas launches per iteration,
     independent of B and of the sketch chain length;
  4. ``scatter_bucket`` splits, un-pads and reshapes the results back.

Zero-padding is exact for the Newton-Schulz polar iterations (pad
rows/cols of X stay identically zero; the real block evolves as if
unpadded), and the sketched alpha fit is made exactly pad-blind via the
``n_real`` trace correction in ``prism.fit_alpha``.  Padding is NOT used
for the SVD method (null-space rotations can leak into the real block) or
for the coupled sqrtm family (the damped pad block perturbs the fit), so
those paths bucket exact shapes only.

The plan is pure Python over static shapes — it runs at trace time and
costs nothing inside jit.

Adaptive early stopping (DESIGN.md §11): with a resolved ``tol`` the
batched call per bucket runs only until its slowest slice certifies;
``polar_bucketed(with_iters=True)`` / ``transform_bucketed(
with_aux=True)`` scatter the realized per-slice iteration counts back
per view for the optimizers' telemetry state.

Mesh-sharded dispatch (DESIGN.md §8): a batched bucket call is exact
per-slice math — per-slice Frobenius normalization and a per-slice alpha
fit against a sketch S shared only through the PRNG key — so the batch
dim partitions freely across devices.  When an activation-sharding
context is installed (launcher, multi-device tests) each bucket's
[B, m, n] batch dim is shard_map'ed over the (pod, data) mesh axes:
every device runs the fitted chain on B/shards matrices instead of all
B replicated, and the slice results are all-gathered back into the full
bucket before ``scatter_bucket``.  Buckets whose B does not divide the
shard count pad with identity slices (finite, self-contained chains
that are dropped after the gather).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import sharding_ctx
from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn


class Entry(NamedTuple):
    """One matrix view's slot inside a bucket."""

    index: int                  # position in the caller's list of views
    lead: Tuple[int, ...]       # leading (stacked-layer) dims of the view
    mshape: Tuple[int, int]     # real matrix shape (m, n)
    offset: int                 # first slice in the bucket's batch dim

    @property
    def count(self) -> int:
        c = 1
        for d in self.lead:
            c *= d
        return c


class Bucket(NamedTuple):
    shape: Tuple[int, int]      # bucket (possibly padded-to) matrix shape
    entries: Tuple[Entry, ...]
    size: int                   # total stacked batch B

    @property
    def padded(self) -> bool:
        return any(e.mshape != self.shape for e in self.entries)


def plan_buckets(shapes: Sequence[Tuple[int, ...]], *, pad: bool = False,
                 pad_slack: float = 0.25) -> Tuple[Bucket, ...]:
    """Partition view shapes [..lead.., m, n] into shape buckets.

    Exact (m, n) groups never mix orientations — (m, n) and (n, m) are
    distinct buckets.  With ``pad``, a shape joins an existing larger
    bucket target (M, N) when padding is needed ONLY on the target's
    Gram side (the min side, where polar forms its residual: cols when
    M >= N, else rows) and the padded area stays within
    M*N <= (1 + pad_slack) * m*n; targets are seeded from the largest
    shapes first so the merge is deterministic.  Gram-side-only padding
    keeps the residual's pad block coordinate-aligned (exactly I), which
    is what the n_real trace correction subtracts exactly; padding the
    other side would instead inject non-aligned rank-deficiency modes
    into the fit — analytically argmin-invariant (h(1; a) = 1) but only
    fp-approximately so near convergence — so such merges are refused.
    """
    mshapes = [(int(s[-2]), int(s[-1])) for s in shapes]
    distinct = sorted(set(mshapes), key=lambda s: (-s[0] * s[1], s))
    target = {}
    targets: List[Tuple[int, int]] = []
    for m, n in distinct:
        tgt = (m, n)
        if pad:
            for M, N in targets:
                fits = (m == M and n <= N) if M >= N else \
                    (n == N and m <= M)
                if fits and M * N <= (1 + pad_slack) * m * n:
                    tgt = (M, N)
                    break
        target[(m, n)] = tgt
        if tgt == (m, n):
            targets.append(tgt)
    groups = {}
    for i, s in enumerate(shapes):
        groups.setdefault(target[mshapes[i]], []).append(i)
    buckets = []
    for tgt in sorted(groups):
        entries, offset = [], 0
        for i in groups[tgt]:
            e = Entry(i, tuple(int(d) for d in shapes[i][:-2]),
                      mshapes[i], offset)
            entries.append(e)
            offset += e.count
        buckets.append(Bucket(tgt, tuple(entries), offset))
    return tuple(buckets)


def gather_bucket(bucket: Bucket, views: Sequence[jax.Array],
                  dtype=None) -> jax.Array:
    """Stack a bucket's views into one [B, M, N] array (zero-padded).

    ``dtype`` casts each view BEFORE stacking (DESIGN.md §9): under a
    bf16 compute policy the gathered bucket — the array every chain GEMM
    streams from HBM — is materialized directly in bf16, halving the
    gather/concat footprint instead of stacking fp32 and casting inside
    the matfn call.  Zero padding is exact in any dtype.
    """
    M, N = bucket.shape
    parts = []
    for e in bucket.entries:
        v = views[e.index]
        if dtype is not None and v.dtype != dtype:
            v = v.astype(dtype)
        v = v.reshape((e.count,) + e.mshape)
        pm, pn = M - e.mshape[0], N - e.mshape[1]
        if pm or pn:
            v = jnp.pad(v, ((0, 0), (0, pm), (0, pn)))
        parts.append(v)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def scatter_bucket(bucket: Bucket, batch: jax.Array,
                   outs: List[Optional[jax.Array]]) -> None:
    """Split [B, M, N] results back into per-view arrays (in place)."""
    for e in bucket.entries:
        m, n = e.mshape
        sl = batch[e.offset:e.offset + e.count, :m, :n]
        outs[e.index] = sl.reshape(e.lead + e.mshape)


def scatter_bucket_aux(bucket: Bucket, aux: jax.Array,
                       outs: List[Optional[jax.Array]]) -> None:
    """Split a per-slice companion [B, ...] (e.g. the §11 ``iters_used``
    telemetry) back into per-view arrays of the views' lead shapes."""
    for e in bucket.entries:
        sl = aux[e.offset:e.offset + e.count]
        outs[e.index] = sl.reshape(e.lead + sl.shape[1:])


def _gram_real_dims(bucket: Bucket) -> jax.Array:
    """Per-slice real extent of the polar Gram dimension, shape [B].

    ``newton_schulz.polar`` transposes when M < N, so the Gram residual
    lives on the min side of the BUCKET shape; each slice's real extent on
    that side feeds the n_real trace correction.
    """
    M, N = bucket.shape
    side = 1 if M >= N else 0
    reals = []
    for e in bucket.entries:
        reals.extend([e.mshape[side]] * e.count)
    return jnp.asarray(reals, jnp.int32)


def resolve_fused_tier(pcfg: PrismConfig, bucket: Bucket,
                       coupled: bool = False) -> PrismConfig:
    """Pin the fused-iteration tier (DESIGN.md §10) for one bucket.

    The choice is made HERE, at trace time, from the bucket's static
    matrix shape against the VMEM budget — it is batch-size independent
    (the batch dim streams through the fused grids), so the same tier
    serves the replicated bucket and every §8 per-device slice.  "auto"
    resolves to an explicit "on"/"off" so the downstream newton_schulz
    phase loop never re-derives it; forced values pass through.
    """
    if pcfg.fuse != "auto" or not pcfg.use_kernels:
        return pcfg
    from repro.kernels import ops as kops

    m, n = bucket.shape
    mshape = (max(m, n), min(m, n))  # polar transposes to m >= n
    fits = kops.fused_fits(mshape, jnp.dtype(pcfg.dtype), coupled=coupled,
                           budget=pcfg.vmem_budget)
    return dataclasses.replace(pcfg, fuse="on" if fits else "off")


def resolve_lowrank_tier(cfg: OptimizerConfig,
                         mshape: Tuple[int, int]) -> Optional[int]:
    """Sketch width l when a bucket routes the §14 lowrank tier, None for
    the cubic (§7/§10) tiers.

    Like ``resolve_fused_tier`` this is a trace-time, batch-size-blind
    choice from the bucket's static matrix shape.  The tier engages when
    (a) it is enabled (``cfg.lowrank_rank > 0``) and the view's shape
    crosses the size threshold — max dim above ``lowrank_max_dim`` or
    aspect ratio at least ``lowrank_aspect`` — and (b) projection
    actually wins: l = rank + oversample leaves a strict subspace
    (l < min(m, n)) and the modeled projected-chain FLOPs beat the cubic
    path (kernels/ops.py), so pathological knob choices degrade to the
    exact tiers instead of a slower "acceleration".
    """
    if not cfg.lowrank_rank:
        return None
    if cfg.matfn_method not in ("prism", "newton_schulz"):
        return None
    m, n = int(mshape[-2]), int(mshape[-1])
    hi, lo = max(m, n), min(m, n)
    if hi <= cfg.lowrank_max_dim and hi < cfg.lowrank_aspect * lo:
        return None
    l = cfg.lowrank_rank + cfg.lowrank_oversample
    if l >= lo:
        return None
    from repro.kernels import ops as kops

    pcfg = cfg.resolved_prism
    it = pcfg.iterations + pcfg.warm_alpha_iters
    if kops.lowrank_polar_flops((hi, lo), l, iters=it,
                                degree=pcfg.degree) >= \
            kops.polar_flops((hi, lo), iters=it, degree=pcfg.degree):
        return None
    return l


#: telemetry encoding of the per-bucket kernel tier (the int32 "tier"
#: entry Muon carries per matrix leaf when the §14 tier is enabled)
TIER_CODES = {"grid": 0, "fused": 1, "lowrank": 2}


def resolve_tier(cfg: OptimizerConfig, mshape: Tuple[int, int]) -> str:
    """Name of the kernel tier the planner picks for a view shape:
    "lowrank" (§14) | "fused" (§10) | "grid" (§7).  Pure static-shape
    logic — usable from tests/telemetry without building a Bucket."""
    if resolve_lowrank_tier(cfg, mshape) is not None:
        return "lowrank"
    pcfg = resolve_fused_tier(
        cfg.resolved_prism,
        Bucket((int(mshape[-2]), int(mshape[-1])), (), 0))
    return "fused" if pcfg.use_kernels and pcfg.fuse == "on" else "grid"


# ------------------------------------------------------------------ sharding

def mesh_batch_axes(cfg: Optional[OptimizerConfig]):
    """(mesh, axes) for batch-dim sharding, or (None, ()) when inactive.

    Active iff ``cfg.precond_shard == "auto"``, an activation-sharding
    context is installed, and the mesh has a >1-sized batch axis.  Only
    the pure data-parallel axes partition the bucket — the model axis
    keeps its role of sharding the matrices themselves (TP), so each
    model-slice group computes the same batch slice redundantly, exactly
    as the forward pass replicates data-parallel work across model.
    """
    if cfg is None or getattr(cfg, "precond_shard", "off") != "auto":
        return None, ()
    ctx = sharding_ctx.current()
    if ctx is None:
        return None, ()
    mesh, rules = ctx
    # pipeline runs repurpose pod as a stage axis and install an
    # "opt_batch" override (launch/sharding.py::pipeline_rules) so the
    # bucket batch dim partitions over the remaining DP axes only
    allowed = rules.get("opt_batch", ("pod", "data"))
    axes = tuple(a for a in allowed
                 if a in mesh.axis_names and mesh.shape[a] > 1)
    return (mesh, axes) if axes else (None, ())


def shard_over_batch(fn: Callable, mesh, axes: Tuple[str, ...],
                     stacked: jax.Array,
                     slice_args: Sequence[jax.Array] = (),
                     slice_pads: Sequence = (),
                     out_ranks: Optional[Tuple[int, ...]] = None):
    """Run ``fn(stacked, *slice_args)`` with the leading batch dim
    partitioned over mesh ``axes`` via shard_map; all-gather the result.

    ``slice_args`` are per-slice companions ([B, ...], e.g. the n_real
    trace-correction vector) that shard with the batch; ``slice_pads``
    gives the fill value appended to each when B pads up to a multiple of
    the shard count.  Batch padding uses identity slices: every PRISM/NS
    path normalizes and fits per slice, so pad slices run finite,
    self-contained chains that cannot perturb the real ones and are
    sliced away after the gather.

    ``out_ranks``: when fn returns a TUPLE of batch-leading arrays (the
    §11 telemetry path returns (O [B, M, N], iters_used [B])), gives each
    output's rank so the shard_map out_specs can be built; every output
    is all-gathered over the batch dim and un-padded.  None (default)
    keeps the single-array contract.  Note the §11 while_loops run
    PER-SHARD under this partitioning: each device iterates only until
    its own slowest slice certifies — adaptivity composes with §8
    sharding for free.
    """
    from jax.sharding import PartitionSpec as P

    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    B, M, N = stacked.shape
    pad = (-B) % n_shards
    if pad:
        eye = jnp.broadcast_to(jnp.eye(M, N, dtype=stacked.dtype),
                               (pad, M, N))
        stacked = jnp.concatenate([stacked, eye], axis=0)
        slice_args = [
            jnp.concatenate([s, jnp.full((pad,) + s.shape[1:], v,
                                         dtype=s.dtype)], axis=0)
            for s, v in zip(slice_args, slice_pads)]
    ax = axes if len(axes) > 1 else axes[0]

    def local(x, *extras):
        return jax.tree.map(
            lambda o: jax.lax.all_gather(o, ax, axis=0, tiled=True),
            fn(x, *extras))

    def batch_spec(r):
        return P(*((ax,) + (None,) * (r - 1)))

    out_specs = (P(*((None,) * stacked.ndim)) if out_ranks is None else
                 tuple(P(*((None,) * r)) for r in out_ranks))
    out = sharding_ctx.compat_shard_map(
        local, mesh=mesh,
        in_specs=tuple(batch_spec(a.ndim)
                       for a in [stacked, *slice_args]),
        out_specs=out_specs)(stacked, *slice_args)
    return jax.tree.map(lambda o: o[:B], out) if pad else out


def polar_bucketed(views: Sequence[jax.Array], cfg: OptimizerConfig,
                   key: Optional[jax.Array],
                   with_iters: bool = False):
    """Polar factor of every matrix view via one batched call per bucket.

    Buckets gather directly in the engine's compute dtype
    (``cfg.matfn_dtype`` via the resolved MatfnPrecision policy) — the
    SVD method excepted, whose LAPACK path is pinned fp32 (DESIGN.md §9).

    ``with_iters`` (NS family only, i.e. method prism/newton_schulz)
    additionally returns per-view ``iters_used`` AND guardian ``status``
    telemetry (DESIGN.md §11/§15): the realized iteration count and the
    int8 prism.STATUS_* code of every slice, scattered back to each
    view's lead shape — returns (outs, iters, statuses).  With ``cfg``'s
    resolved ``tol`` set the counts are data-dependent (each bucket's
    while_loop exits when its slowest slice certifies); otherwise they
    are the static budget and the statuses all-zeros.
    """
    method = cfg.matfn_method
    pcfg = cfg.resolved_prism
    compute = None if method == "svd" else \
        cfg.matfn_precision.compute_dtype
    pad = cfg.bucket_pad and method != "svd"
    if with_iters:
        assert method in ("prism", "newton_schulz"), method
    buckets = plan_buckets([v.shape for v in views], pad=pad,
                           pad_slack=cfg.bucket_pad_slack)
    mesh, mesh_axes = mesh_batch_axes(cfg)
    outs: List[Optional[jax.Array]] = [None] * len(views)
    iters: List[Optional[jax.Array]] = [None] * len(views)
    statuses: List[Optional[jax.Array]] = [None] * len(views)
    for bi, b in enumerate(buckets):
        stacked = gather_bucket(b, views, dtype=compute)
        local_reshard = (cfg.muon_local_reshard
                         and all(e.lead for e in b.entries))
        if local_reshard:
            # layers -> model, rows -> data (see make_muon): the batched NS
            # iterations then need only one [n, n] R-psum per step.  Like
            # the per-leaf path (which resharded only M.ndim >= 3 views),
            # this applies only to buckets built purely from scanned-layer
            # stacks — plain 2-D leaves keep their layout, and a mixed
            # bucket is not co-sharded unevenly over opt_layers.  Takes
            # precedence over the batch-dim shard_map engine: the two are
            # alternative distribution strategies for the same bucket.
            stacked = sharding_ctx.shard_activation(
                stacked, ("opt_layers", "opt_rows", None))
        kk = (jax.random.fold_in(key, bi) if key is not None else None)
        lowrank_l = resolve_lowrank_tier(cfg, b.shape)
        if lowrank_l is not None:
            # §14 lowrank tier: the projected chains live on the l side,
            # which is never padded (plan_buckets pads the Gram side of
            # the FULL view only, and zero pad rows/cols stay zero
            # through the sketch -> project -> lift composition), so no
            # n_real correction is threaded.  The §10 fuse choice
            # resolves inside the inner polar calls from the SMALL
            # [m, l] / [l, n] shapes (newton_schulz._fused_tier), not
            # from the full bucket shape.
            from repro.core import lowrank as lr

            def run(x, _kk=kk, _l=lowrank_l):
                return lr.polar_lowrank(
                    x, cfg.lowrank_rank, cfg.lowrank_oversample,
                    cfg=pcfg, key=_kk, method=method,
                    return_iters=with_iters, return_status=with_iters)

            n_real = None
        else:
            n_real = (_gram_real_dims(b)
                      if b.padded and method == "prism" else None)
            pcfg_b = resolve_fused_tier(pcfg, b)

            def run(x, *nr, _kk=kk, _pcfg=pcfg_b):
                if method == "svd":
                    return matfn.polar(x, method="svd")
                kw = {"n_real": nr[0]} if nr else {}
                if with_iters:  # NS family only (asserted above)
                    kw["return_iters"] = True
                    kw["return_status"] = True
                return matfn.polar(x, method=method, cfg=_pcfg, key=_kk,
                                   **kw)

        if mesh is not None and not local_reshard:
            gram_full = min(b.shape)  # pad slices carry no intra-slice pad
            O = shard_over_batch(
                run, mesh, mesh_axes, stacked,
                slice_args=() if n_real is None else (n_real,),
                slice_pads=() if n_real is None else (gram_full,),
                out_ranks=(3, 1, 1) if with_iters else None)
        else:
            O = run(stacked) if n_real is None else run(stacked, n_real)
        if with_iters:
            O, it, st = O
            scatter_bucket_aux(b, it, iters)
            scatter_bucket_aux(b, st, statuses)
        scatter_bucket(b, O, outs)
    if with_iters:
        return outs, iters, statuses
    return outs  # type: ignore[return-value]


def polar_refresh(views: Sequence[jax.Array], cfg: OptimizerConfig,
                  key: Optional[jax.Array]):
    """The Muon preconditioner refresh as one standalone callable
    (DESIGN.md §12): polar factors of every view, telemetry included iff
    ``cfg.matfn_telemetry``.  Returns ``(outs, iters, statuses)`` with
    ``iters``/``statuses`` None when telemetry is off.

    This is the exact computation a blocking in-step refresh runs —
    factored out of the update so the async service can jit and dispatch
    it as its own program (and so the in-step path and the refresh plane
    can never drift apart).  Dispatch tier (§7 bucketing, §8 sharding,
    §10 fusion, §11 adaptivity) all resolve inside ``polar_bucketed``
    as usual.
    """
    if not cfg.bucketed:
        outs, its, sts = [], [], []
        for i, M in enumerate(views):
            kk = jax.random.fold_in(key, i) if key is not None else None
            if cfg.matfn_method == "svd":
                outs.append(matfn.polar(M, method="svd"))
            elif cfg.matfn_telemetry:
                O, it, st = matfn.polar(M, method=cfg.matfn_method,
                                        cfg=cfg.resolved_prism, key=kk,
                                        return_iters=True,
                                        return_status=True)
                outs.append(O)
                its.append(it)
                sts.append(st)
            else:
                outs.append(matfn.polar(M, method=cfg.matfn_method,
                                        cfg=cfg.resolved_prism, key=kk))
        if cfg.matfn_telemetry:
            return outs, its, sts
        return outs, None, None
    if cfg.matfn_telemetry:
        return polar_bucketed(views, cfg, key, with_iters=True)
    return polar_bucketed(views, cfg, key), None, None


def transform_bucketed(mats: Sequence[jax.Array], fn,
                       cfg: Optional[OptimizerConfig] = None,
                       with_aux: bool = False):
    """Apply ``fn(stacked, bucket, bucket_index)`` once per exact-shape
    bucket and scatter the [B, n, n] results back.

    ``with_aux``: an int N (bool True == 1) — fn returns
    (out [B, n, n], aux_1 [B], ..., aux_N [B]), per-slice companions
    (the §11 ``iters_used`` and §15 ``status`` telemetry of Shampoo's
    inverse roots) scattered back alongside; returns
    (outs, auxs_1, ..., auxs_N).  Each aux must be per-slice like the
    output itself, so it shards/gathers with the batch dim unchanged.

    The generic engine for matrix functions without a pad-exactness story
    (Shampoo inverse roots).  Gathers stay fp32 here: the stacked arrays
    are fp32 EMA Kronecker factors whose eps-ridge must be applied in
    fp32 before the chain casts down (DESIGN.md §9) — fn owns the cast.  With a ``cfg`` and an active sharding
    context the batch dim shard_maps over the mesh like
    ``polar_bucketed`` (identity pad slices are SPD, so the Shampoo
    inverse-root chains on them stay finite) — fn's ``stacked`` argument
    is then a LOCAL, possibly identity-padded batch slice, NOT the full
    bucket.  fn must therefore be per-slice (elementwise over the batch
    dim); use the Bucket/index only for static metadata (shape, PRNG
    folding), never to index companion arrays by entry offset.

    Fused tier: fn's inner matfn calls carry their own PrismConfig, so
    the §10 tier resolves inside the iteration family (newton_schulz
    ``_fused_tier``) from the same static bucket shape — callers pick it
    up with zero changes, exactly like ``polar_bucketed``.
    """
    n_aux = int(with_aux)
    buckets = plan_buckets([m.shape for m in mats], pad=False)
    mesh, mesh_axes = mesh_batch_axes(cfg)
    outs: List[Optional[jax.Array]] = [None] * len(mats)
    auxs = [[None] * len(mats) for _ in range(n_aux)]
    for bi, b in enumerate(buckets):
        stacked = gather_bucket(b, mats)
        if mesh is not None:
            out = shard_over_batch(
                lambda x, _b=b, _bi=bi: fn(x, _b, _bi),
                mesh, mesh_axes, stacked,
                out_ranks=(3,) + (1,) * n_aux if n_aux else None)
        else:
            out = fn(stacked, b, bi)
        if n_aux:
            out, *aux = out
            for k in range(n_aux):
                scatter_bucket_aux(b, aux[k], auxs[k])
        scatter_bucket(b, out, outs)
    if n_aux:
        return (outs, *auxs)
    return outs  # type: ignore[return-value]
