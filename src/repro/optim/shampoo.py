"""Shampoo optimizer with PRISM inverse p-th roots (paper Sec. 6.2).

W <- W - lr * L^{-1/p} G R^{-1/p}   with p = 2 by default (Shi et al. 23,
Morwani et al. 25), L/R the EMA Kronecker preconditioners G G^T / G^T G.

``matfn_method`` selects how the inverse roots are computed:
  prism (coupled PRISM-NS, distribution-free) | polar_express (coupled)
  | newton (PRISM DB-Newton) | eigh (the classical baseline).

Dims above ``max_precond_dim`` fall back to a diagonal (AdaGrad)
preconditioner on that side.  Preconditioned updates are norm-grafted to
the raw gradient norm for stability; inverse roots are recomputed every
``precondition_every`` steps and cached in the state.

Inverse-root dispatch is shape-bucketed by default (optim/bucketing.py):
the L and R preconditioners of every matrix leaf — across leaves — stack
into one [B, n, n] batched call per distinct n, under a single recompute
cond per bucket.  ``cfg.bucketed=False`` restores the per-leaf loop.
With an activation-sharding context each bucket's batch dim additionally
shard_maps over the (pod, data) mesh axes (DESIGN.md §8).

The refresh period is max(cfg.precond_every, cfg.precondition_every) —
the former is the unified staleness knob shared with Muon, the latter
the legacy Shampoo-only one.  ``update(..., refresh=<bool>)`` overrides
the schedule statically: the skip branch then compiles with zero
inverse-root work instead of a runtime lax.cond.

Precision (DESIGN.md §9): the EMA Kronecker factors L/R and their
eps-ridge stay fp32 (they are long-lived accumulators); the inverse-root
CHAINS run at ``cfg.matfn_dtype`` compute with fp32 accumulation, and
the cached "Linv"/"Rinv" store in ``cfg.cache_dtype`` — bf16 halves the
cached inverse-root state; preconditioning promotes back to fp32 when
the bf16 inverse multiplies the fp32 gradient.

Adaptive early stopping (DESIGN.md §11): ``cfg.matfn_tol`` lets each
inverse-root bucket iterate only until its slowest slice certifies;
the realized counts ride in the state as "Linv_iters"/"Rinv_iters"
(``cfg.matfn_telemetry``), refreshed together with the caches.

Async refresh plane (DESIGN.md §12): with ``cfg.precond_async`` the
inverse-root chains never run inside ``update``.  Full-matrix sides
carry pending "Linv_p"/"Rinv_p" twins recomputed by the standalone
``refresh`` member (from the stored EMA factors) and swapped
pending -> active under one lax.cond after ``precond_swap_delay``
steps; the update accumulates the joint-side drift proxy
("dnorm"/"rnorm", Frobenius movement of the cached L/R factors) for
the drift-triggered schedule.  Diagonal fallback sides are exempt —
they are recomputed exactly every step either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.core import matfn
from repro.optim import base, bucketing
from repro.optim.muon import _flatten_with_axes


def _inv_root(A, p, cfg: OptimizerConfig, key, with_iters: bool = False):
    """A^{-1/p} per ``cfg.matfn_method``; ``with_iters`` appends the
    §11 ``iters_used`` telemetry AND the §15 int8 guardian status
    (data-dependent under an adaptive ``cfg.matfn_tol``; fit-free
    baselines report 0 — they certify nothing and cannot diverge out
    of a fixed schedule)."""
    # the eps-ridge is applied to the fp32 EMA factor BEFORE any cast:
    # a bf16 ridge would round away eps against trace-scale entries (§9)
    eps = cfg.shampoo_eps
    n = A.shape[-1]
    Ad = A + eps * jnp.trace(A, axis1=-2, axis2=-1)[..., None, None] \
        * jnp.eye(n, dtype=A.dtype) / n + eps * jnp.eye(n, dtype=A.dtype)
    pc = cfg.resolved_prism
    m = cfg.matfn_method

    def plain(out):
        return (out, jnp.zeros(A.shape[:-2], jnp.int32),
                jnp.zeros(A.shape[:-2], jnp.int8)) if with_iters else out

    if m == "eigh":
        return plain(matfn.inv_proot(Ad, p=p, method="eigh"))
    if m == "polar_express" and p == 2:
        return plain(matfn.sqrtm(Ad, method="polar_express",
                                 iters=pc.iterations, dtype=pc.dtype)[1])
    if m == "newton" and p == 2:
        # DB-Newton is Cholesky-based: pinned fp32 (DESIGN.md §9)
        return plain(matfn.sqrtm(Ad, method="newton",
                                 iters=pc.iterations)[1])
    if p == 2:
        if with_iters:
            (_, isq), it, st = matfn.sqrtm(Ad, method="prism", cfg=pc,
                                           key=key, iters=pc.iterations,
                                           return_iters=True,
                                           return_status=True)
            return isq, it, st
        return matfn.sqrtm(Ad, method="prism", cfg=pc, key=key,
                           iters=pc.iterations)[1]
    return matfn.inv_proot(Ad, p=p, method="prism", key=key,
                           iters=pc.iterations, dtype=jnp.dtype(pc.dtype),
                           tol=pc.tol, return_iters=with_iters,
                           return_status=with_iters,
                           divergence_factor=pc.divergence_factor)


def make_shampoo(cfg: OptimizerConfig, axes_tree,
                 p_root: int = 2) -> base.Optimizer:
    maxd = cfg.max_precond_dim
    # §11 telemetry: with an adaptive matfn_tol the realized inverse-root
    # iteration counts ride in the state per preconditioner side
    # ("Linv_iters"/"Rinv_iters"), refreshed with the caches
    telemetry = cfg.matfn_telemetry

    def init(params):
        flat_p, flat_a, treedef = _flatten_with_axes(params, axes_tree)
        state = []
        for pp, a in zip(flat_p, flat_a):
            mom = jnp.zeros(pp.shape, jnp.float32)
            if base.is_matrix_param(a, pp.shape):
                M, _ = base.to_matrix_view(jnp.zeros(pp.shape, jnp.float32),
                                           a)
                m, n = M.shape[-2], M.shape[-1]
                lead = M.shape[:-2]
                s = {"mom": mom}
                cache_dt = jnp.dtype(cfg.cache_dtype)
                if m <= maxd:
                    s["L"] = jnp.zeros(lead + (m, m), jnp.float32)
                    s["Linv"] = jnp.zeros(lead + (m, m), cache_dt)
                    if telemetry:
                        s["Linv_iters"] = jnp.zeros(lead, jnp.int32)
                        s["Linv_status"] = jnp.zeros(lead, jnp.int8)
                else:
                    s["diagL"] = jnp.zeros(lead + (m,), jnp.float32)
                if n <= maxd:
                    s["R"] = jnp.zeros(lead + (n, n), jnp.float32)
                    s["Rinv"] = jnp.zeros(lead + (n, n), cache_dt)
                    if telemetry:
                        s["Rinv_iters"] = jnp.zeros(lead, jnp.int32)
                        s["Rinv_status"] = jnp.zeros(lead, jnp.int8)
                else:
                    s["diagR"] = jnp.zeros(lead + (n,), jnp.float32)
                if cfg.precond_async:
                    # §12 double buffer: pending twins for the cached
                    # sides + the joint-side drift-proxy scalars
                    if "Linv" in s:
                        s["Linv_p"] = jnp.zeros_like(s["Linv"])
                        if telemetry:
                            s["Linv_iters_p"] = jnp.zeros(lead, jnp.int32)
                            s["Linv_status_p"] = jnp.zeros(lead, jnp.int8)
                    if "Rinv" in s:
                        s["Rinv_p"] = jnp.zeros_like(s["Rinv"])
                        if telemetry:
                            s["Rinv_iters_p"] = jnp.zeros(lead, jnp.int32)
                            s["Rinv_status_p"] = jnp.zeros(lead, jnp.int8)
                    if "Linv" in s or "Rinv" in s:
                        s["dnorm"] = jnp.zeros((), jnp.float32)
                        s["rnorm"] = jnp.zeros((), jnp.float32)
                state.append(s)
            else:
                state.append({"mom": mom,
                              "nu": jnp.zeros(pp.shape, jnp.float32)})
        out = {"leaves": jax.tree.unflatten(treedef, state),
               "count": jnp.zeros((), jnp.int32)}
        if cfg.precond_async:
            out["pending_at"] = jnp.full((), base.NO_PENDING, jnp.int32)
        return out

    def _fresh_invs(jobs, key):
        """Freshly computed inverse roots for ``jobs`` — the single body
        shared by the in-step recompute branch AND the §12 refresh plane,
        so the two can never drift apart.  ``jobs`` is a flat list of
        ``(slot, "Linv"/"Rinv", A, side)``; returns ``(invs, its, sts)``
        with ``its``/``sts`` None unless telemetry.  Bucketed: one
        batched call per shape bucket across ALL jobs, keys folded by
        bucket; per-leaf: keys folded by (slot, side)."""
        cache_dt = jnp.dtype(cfg.cache_dtype)
        mats = [A for (_, _, A, _) in jobs]
        if cfg.bucketed:
            def one_bucket(stacked, b, bi):
                kk = (jax.random.fold_in(key, bi)
                      if key is not None else None)
                # cast INSIDE the per-bucket fn so lax.cond branches and
                # the sharded all-gather both carry the cache dtype
                if telemetry:
                    inv, it, st = _inv_root(stacked, p_root, cfg, kk,
                                            with_iters=True)
                    return inv.astype(cache_dt), it, st
                return _inv_root(stacked, p_root, cfg, kk).astype(cache_dt)

            out = bucketing.transform_bucketed(
                mats, one_bucket, cfg, with_aux=2 if telemetry else 0)
            return out if telemetry else (out, None, None)
        outs, its, sts = [], [], []
        for (i, _, A, side) in jobs:
            kk = jax.random.fold_in(key, i) if key is not None else None
            if kk is not None and side:
                kk = jax.random.fold_in(kk, 1)
            if telemetry:
                inv, it, st = _inv_root(A, p_root, cfg, kk,
                                        with_iters=True)
                outs.append(inv.astype(cache_dt))
                its.append(it)
                sts.append(st)
            else:
                outs.append(_inv_root(A, p_root, cfg, kk).astype(cache_dt))
        return (outs, its, sts) if telemetry else (outs, None, None)

    def _inv_roots(jobs, prevs, prev_its, prev_sts, recompute, key):
        """The in-step staleness schedule: all jobs under ONE recompute
        cond — the cache-hit branch returns the per-leaf cached inverses
        untouched, so steps between recomputes move zero preconditioner
        bytes.  A static (Python bool) ``recompute`` picks the branch at
        trace time instead — the skip variant contains no inverse-root
        ops."""
        def stale():
            return (list(prevs),
                    list(prev_its) if telemetry else None,
                    list(prev_sts) if telemetry else None)

        def compute():
            return _fresh_invs(jobs, key)

        if isinstance(recompute, bool):
            return compute() if recompute else stale()
        return jax.lax.cond(recompute, compute, stale)

    def update(grads, state, params, step, key, refresh=None):
        flat_g, flat_a, treedef = _flatten_with_axes(grads, axes_tree)
        flat_p = jax.tree.leaves(params)
        flat_s = treedef.flatten_up_to(state["leaves"])
        lr = cfg.learning_rate
        every = base.resolve_refresh_period(cfg, "shampoo")
        recompute = (refresh if isinstance(refresh, bool)
                     else (state["count"] % every) == 0)
        beta2 = 0.999
        new_p = [None] * len(flat_g)
        new_s = [None] * len(flat_g)
        # pass 1: EMA the Kronecker factors; queue the inverse-root jobs
        # jobs: (leaf, "Linv"/"Rinv", A, prev, prev_iters, prev_status,
        #        key_ix)
        matrix, jobs = [], []
        for i, (g, a, pp, s) in enumerate(zip(flat_g, flat_a, flat_p,
                                              flat_s)):
            g = g.astype(jnp.float32)
            if not base.is_matrix_param(a, pp.shape):
                b1, b2 = cfg.beta1, cfg.beta2
                mom = b1 * s["mom"] + (1 - b1) * g
                nu = b2 * s["nu"] + (1 - b2) * jnp.square(g)
                t = (state["count"] + 1).astype(jnp.float32)
                p32 = pp.astype(jnp.float32) * (1.0 - lr * cfg.weight_decay) \
                    - lr * (mom / (1 - b1 ** t)) / (
                        jnp.sqrt(nu / (1 - b2 ** t)) + cfg.eps)
                new_s[i] = {"mom": mom, "nu": nu}
                new_p[i] = p32.astype(pp.dtype)
                continue
            G, meta = base.to_matrix_view(g, a)
            ns = {"mom": None}
            if "L" in s:
                L = beta2 * s["L"] + jnp.einsum("...mk,...nk->...mn", G, G)
                ns["L"] = L
                jobs.append((i, "Linv", L, s["Linv"],
                             s.get("Linv_iters"), s.get("Linv_status"), 0))
            else:
                ns["diagL"] = beta2 * s["diagL"] + jnp.sum(G * G, axis=-1)
            if "R" in s:
                R = beta2 * s["R"] + jnp.einsum("...km,...kn->...mn", G, G)
                ns["R"] = R
                jobs.append((i, "Rinv", R, s["Rinv"],
                             s.get("Rinv_iters"), s.get("Rinv_status"), 1))
            else:
                ns["diagR"] = beta2 * s["diagR"] + jnp.sum(G * G, axis=-2)
            if cfg.precond_async and ("L" in s or "R" in s):
                # drift proxy (§12): joint Frobenius movement of the
                # cached EMA factors since the last refresh dispatch
                dsq = jnp.zeros((), jnp.float32)
                if "L" in s:
                    dsq = dsq + jnp.sum(jnp.square(ns["L"] - s["L"]))
                if "R" in s:
                    dsq = dsq + jnp.sum(jnp.square(ns["R"] - s["R"]))
                ns["dnorm"] = s["dnorm"] + jnp.sqrt(dsq)
                ns["rnorm"] = s["rnorm"]
            matrix.append((i, G, meta))
            new_s[i] = ns
        # inverse roots: one batched call per shape bucket across ALL
        # leaves' L and R factors (per-leaf loop behind cfg.bucketed=False)
        prevs = [prev for (_, _, _, prev, _, _, _) in jobs]
        prev_its = [it for (_, _, _, _, it, _, _) in jobs]
        prev_sts = [st for (_, _, _, _, _, st, _) in jobs]
        new_pending_at = None
        if cfg.precond_async:
            # §12 steady state: no inverse-root work in-step.  Serve the
            # active caches, or — once the in-flight refresh has had
            # precond_swap_delay steps to land — swap every pending twin
            # in under ONE lax.cond (a local per-shard select).
            pending_at = state["pending_at"]
            new_pending_at = pending_at
            if jobs:
                pend = [flat_s[i][name + "_p"]
                        for (i, name, _, _, _, _, _) in jobs]
                do_swap = (pending_at > base.NO_PENDING) & (
                    state["count"] >= pending_at + cfg.precond_swap_delay)
                none_pending = jnp.full((), base.NO_PENDING, jnp.int32)
                if telemetry:
                    it_p = [flat_s[i][name + "_iters_p"]
                            for (i, name, _, _, _, _, _) in jobs]
                    st_p = [flat_s[i][name + "_status_p"]
                            for (i, name, _, _, _, _, _) in jobs]
                    invs, its, sts, new_pending_at = jax.lax.cond(
                        do_swap,
                        lambda: (pend, it_p, st_p, none_pending),
                        lambda: (list(prevs), list(prev_its),
                                 list(prev_sts), pending_at))
                else:
                    its = sts = None
                    invs, new_pending_at = jax.lax.cond(
                        do_swap,
                        lambda: (pend, none_pending),
                        lambda: (list(prevs), pending_at))
                for j, (i, name, _, _, _, _, _) in enumerate(jobs):
                    new_s[i][name + "_p"] = pend[j]
                    if telemetry:
                        new_s[i][name + "_iters_p"] = it_p[j]
                        new_s[i][name + "_status_p"] = st_p[j]
            else:
                invs = []
                its = sts = ([] if telemetry else None)
        else:
            jobs4 = [(i, name, A, side)
                     for (i, name, A, _, _, _, side) in jobs]
            invs, its, sts = _inv_roots(jobs4, prevs, prev_its, prev_sts,
                                        recompute, key)
        for j, (i, name, _, _, _, _, _) in enumerate(jobs):
            new_s[i][name] = invs[j]
            if telemetry:
                new_s[i][name + "_iters"] = its[j]
                new_s[i][name + "_status"] = sts[j]
        # pass 2: precondition, graft, momentum, apply
        for i, G, meta in matrix:
            s, ns = flat_s[i], new_s[i]
            pp = flat_p[i]
            if "Linv" in ns:
                PG = ns["Linv"] @ G
            else:
                PG = G / (ns["diagL"][..., None] ** (1.0 / (2 * p_root))
                          + cfg.shampoo_eps)
            if "Rinv" in ns:
                PG = PG @ ns["Rinv"]
            else:
                PG = PG / (ns["diagR"][..., None, :] ** (1.0 / (2 * p_root))
                           + cfg.shampoo_eps)
            # norm grafting to the raw gradient
            gn = jnp.sqrt(jnp.sum(G * G, axis=(-2, -1), keepdims=True))
            pn = jnp.sqrt(jnp.sum(PG * PG, axis=(-2, -1), keepdims=True))
            PG = PG * gn / jnp.maximum(pn, 1e-12)
            upd = base.from_matrix_view(PG, meta)
            mom = cfg.momentum * s["mom"] + upd
            ns["mom"] = mom
            p32 = pp.astype(jnp.float32) * (1.0 - lr * cfg.weight_decay) \
                - lr * mom
            new_p[i] = p32.astype(pp.dtype)
        out_state = {"leaves": jax.tree.unflatten(treedef, new_s),
                     "count": state["count"] + 1}
        if cfg.precond_async:
            out_state["pending_at"] = new_pending_at
        return jax.tree.unflatten(treedef, new_p), out_state

    def refresh(state, key):
        """§12 refresh plane: recompute the pending inverse-root twins
        from the STORED EMA factors as one standalone jittable program.
        Returns per-slot partial dicts for base.install_pending."""
        slots, _ = base._flat_slots(state["leaves"])
        partials: list = [{} for _ in slots]
        jobs = []
        for i, s in enumerate(slots):
            if "Linv_p" in s:
                jobs.append((i, "Linv", s["L"], 0))
            if "Rinv_p" in s:
                jobs.append((i, "Rinv", s["R"], 1))
        if not jobs:
            return partials
        invs, its, sts = _fresh_invs(jobs, key)
        for j, (i, name, _, _) in enumerate(jobs):
            partials[i][name + "_p"] = invs[j]
            if telemetry:
                partials[i][name + "_iters_p"] = its[j]
                partials[i][name + "_status_p"] = sts[j]
        for i, s in enumerate(slots):
            if partials[i]:
                # drift baseline resets to the dispatched factors
                rsq = jnp.zeros((), jnp.float32)
                if "Linv_p" in s:
                    rsq = rsq + jnp.sum(jnp.square(s["L"]))
                if "Rinv_p" in s:
                    rsq = rsq + jnp.sum(jnp.square(s["R"]))
                partials[i]["rnorm"] = jnp.sqrt(rsq)
                partials[i]["dnorm"] = jnp.zeros((), jnp.float32)
        return partials

    return base.Optimizer(init, update, refresh)
