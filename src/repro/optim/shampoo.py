"""Shampoo optimizer with PRISM inverse p-th roots (paper Sec. 6.2).

W <- W - lr * L^{-1/p} G R^{-1/p}   with p = 2 by default (Shi et al. 23,
Morwani et al. 25), L/R the EMA Kronecker preconditioners G G^T / G^T G.

``matfn_method`` selects how the inverse roots are computed:
  prism (coupled PRISM-NS, distribution-free) | polar_express (coupled)
  | newton (PRISM DB-Newton) | eigh (the classical baseline).

Dims above ``max_precond_dim`` fall back to a diagonal (AdaGrad)
preconditioner on that side.  Preconditioned updates are norm-grafted to
the raw gradient norm for stability; inverse roots are recomputed every
``precondition_every`` steps and cached in the state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.core import matfn
from repro.optim import base
from repro.optim.muon import _flatten_with_axes


def _inv_root(A, p, cfg: OptimizerConfig, key):
    eps = cfg.shampoo_eps
    n = A.shape[-1]
    Ad = A + eps * jnp.trace(A, axis1=-2, axis2=-1)[..., None, None] \
        * jnp.eye(n, dtype=A.dtype) / n + eps * jnp.eye(n, dtype=A.dtype)
    m = cfg.matfn_method
    if m == "eigh":
        return matfn.inv_proot(Ad, p=p, method="eigh")
    if m == "polar_express" and p == 2:
        return matfn.sqrtm(Ad, method="polar_express",
                           iters=cfg.prism.iterations)[1]
    if m == "newton" and p == 2:
        return matfn.sqrtm(Ad, method="newton",
                           iters=cfg.prism.iterations)[1]
    if p == 2:
        return matfn.sqrtm(Ad, method="prism", cfg=cfg.prism, key=key,
                           iters=cfg.prism.iterations)[1]
    return matfn.inv_proot(Ad, p=p, method="prism", key=key,
                           iters=cfg.prism.iterations)


def make_shampoo(cfg: OptimizerConfig, axes_tree,
                 p_root: int = 2) -> base.Optimizer:
    maxd = cfg.max_precond_dim

    def init(params):
        flat_p, flat_a, treedef = _flatten_with_axes(params, axes_tree)
        state = []
        for pp, a in zip(flat_p, flat_a):
            mom = jnp.zeros(pp.shape, jnp.float32)
            if base.is_matrix_param(a, pp.shape):
                M, _ = base.to_matrix_view(jnp.zeros(pp.shape, jnp.float32),
                                           a)
                m, n = M.shape[-2], M.shape[-1]
                lead = M.shape[:-2]
                s = {"mom": mom}
                if m <= maxd:
                    s["L"] = jnp.zeros(lead + (m, m), jnp.float32)
                    s["Linv"] = jnp.zeros(lead + (m, m), jnp.float32)
                else:
                    s["diagL"] = jnp.zeros(lead + (m,), jnp.float32)
                if n <= maxd:
                    s["R"] = jnp.zeros(lead + (n, n), jnp.float32)
                    s["Rinv"] = jnp.zeros(lead + (n, n), jnp.float32)
                else:
                    s["diagR"] = jnp.zeros(lead + (n,), jnp.float32)
                state.append(s)
            else:
                state.append({"mom": mom,
                              "nu": jnp.zeros(pp.shape, jnp.float32)})
        return {"leaves": jax.tree.unflatten(treedef, state),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step, key):
        flat_g, flat_a, treedef = _flatten_with_axes(grads, axes_tree)
        flat_p = jax.tree.leaves(params)
        flat_s = treedef.flatten_up_to(state["leaves"])
        lr = cfg.learning_rate
        recompute = (state["count"] % cfg.precondition_every) == 0
        new_p, new_s = [], []
        for i, (g, a, pp, s) in enumerate(zip(flat_g, flat_a, flat_p,
                                              flat_s)):
            g = g.astype(jnp.float32)
            p32 = pp.astype(jnp.float32)
            if base.is_matrix_param(a, pp.shape):
                G, meta = base.to_matrix_view(g, a)
                ns = {"mom": None}
                beta2 = 0.999
                kk = jax.random.fold_in(key, i) if key is not None else None
                if "L" in s:
                    L = beta2 * s["L"] + jnp.einsum("...mk,...nk->...mn",
                                                    G, G)
                    Linv = jax.lax.cond(
                        recompute,
                        lambda: _inv_root(L, p_root, cfg, kk),
                        lambda: s["Linv"])
                    ns.update(L=L, Linv=Linv)
                    PG = Linv @ G
                else:
                    dL = beta2 * s["diagL"] + jnp.sum(G * G, axis=-1)
                    ns.update(diagL=dL)
                    PG = G / (dL[..., None] ** (1.0 / (2 * p_root))
                              + cfg.shampoo_eps)
                if "R" in s:
                    R = beta2 * s["R"] + jnp.einsum("...km,...kn->...mn",
                                                    G, G)
                    Rinv = jax.lax.cond(
                        recompute,
                        lambda: _inv_root(R, p_root, cfg,
                                          jax.random.fold_in(kk, 1)
                                          if kk is not None else None),
                        lambda: s["Rinv"])
                    ns.update(R=R, Rinv=Rinv)
                    PG = PG @ Rinv
                else:
                    dR = beta2 * s["diagR"] + jnp.sum(G * G, axis=-2)
                    ns.update(diagR=dR)
                    PG = PG / (dR[..., None, :] ** (1.0 / (2 * p_root))
                               + cfg.shampoo_eps)
                # norm grafting to the raw gradient
                gn = jnp.sqrt(jnp.sum(G * G, axis=(-2, -1), keepdims=True))
                pn = jnp.sqrt(jnp.sum(PG * PG, axis=(-2, -1), keepdims=True))
                PG = PG * gn / jnp.maximum(pn, 1e-12)
                upd = base.from_matrix_view(PG, meta)
                mom = cfg.momentum * s["mom"] + upd
                ns["mom"] = mom
                p32 = p32 * (1.0 - lr * cfg.weight_decay) - lr * mom
                new_s.append(ns)
            else:
                b1, b2 = cfg.beta1, cfg.beta2
                mom = b1 * s["mom"] + (1 - b1) * g
                nu = b2 * s["nu"] + (1 - b2) * jnp.square(g)
                t = (state["count"] + 1).astype(jnp.float32)
                alr = lr
                p32 = p32 * (1.0 - alr * cfg.weight_decay) - alr * (
                    mom / (1 - b1 ** t)) / (
                        jnp.sqrt(nu / (1 - b2 ** t)) + cfg.eps)
                new_s.append({"mom": mom, "nu": nu})
            new_p.append(p32.astype(pp.dtype))
        return (jax.tree.unflatten(treedef, new_p),
                {"leaves": jax.tree.unflatten(treedef, new_s),
                 "count": state["count"] + 1})

    return base.Optimizer(init, update)
