from repro.checkpoint.checkpoint import (latest_step, restore,
                                         restore_params, save, verify_step)

__all__ = ["latest_step", "restore", "restore_params", "save",
           "verify_step"]
