"""Checkpointing: atomic, async-capable, elastic-reshard on restore.

Format: one directory per step, ``step_{n:08d}/``, containing
``tree.npz`` (flattened leaves keyed by path) + ``MANIFEST`` (per-leaf
crc32 integrity record, json) + ``META`` (done marker).  Writes go to a
temp dir and are renamed into place (atomic on POSIX), so a crash
mid-write never corrupts the latest checkpoint — the restart path simply
resumes from the newest *complete* step.

Integrity (DESIGN.md §15): the MANIFEST records a zlib.crc32 per leaf,
written INSIDE the same atomic rename as the payload, so checksum and
data can never be torn apart by a crash.  ``_complete_steps`` requires
it (a step without a manifest is not a checkpoint), and ``restore``
verifies every leaf it loads — on mismatch (bit rot, a truncated or
bit-flipped npz that still unpickles) it falls back to the newest step
that DOES verify instead of resurrecting poisoned state.

``restore`` re-shards every leaf onto the *current* mesh via device_put
with the target sharding: restarting on a different device count (elastic
scaling) works as long as the logical shapes still divide the new mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        k = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        out[k] = np.asarray(jax.device_get(leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3,
         async_write: bool = False,
         drop=()) -> Optional[threading.Thread]:
    """Save tree at step; returns the writer thread if async.

    ``drop``: collection of key names — any leaf whose path contains one
    of them is excluded from the file (e.g. the in-flight pending
    preconditioner buffers of the §12 async refresh plane, which a
    restore must discard anyway: restore(allow_missing=...) keeps the
    target's own value for them)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)  # device_get happens synchronously (snapshot)
    if drop:
        drop = frozenset(drop)
        arrays = {k: v for k, v in arrays.items()
                  if not drop.intersection(k.split(_SEP))}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "tree.npz"), **arrays)
        # per-leaf integrity manifest, inside the same atomic rename as
        # the payload (§15): checksum and data commit together or not
        # at all
        manifest = {"step": step,
                    "crc32": {k: _crc(v) for k, v in arrays.items()}}
        with open(os.path.join(tmp, "MANIFEST"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "META"), "w") as f:
            f.write(str(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _complete_steps(ckpt_dir: str):
    """Steps with a committed (renamed-into-place) directory carrying
    both the done marker AND the integrity manifest — a step without a
    MANIFEST is not a checkpoint (§15)."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, d)
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(full, "META")) \
                and os.path.exists(os.path.join(full, "MANIFEST")):
            steps.append(int(d[len("step_"):]))
    return sorted(steps)


def verify_step(ckpt_dir: str, step: int) -> bool:
    """True iff ``step``'s payload matches its manifest bit-for-bit:
    same leaf set, every crc32 equal.  Any read/parse error counts as
    corrupt, never raises."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "MANIFEST")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "tree.npz"))
        crcs = manifest["crc32"]
        if set(crcs) != set(data.files):
            return False
        return all(_crc(data[k]) == crcs[k] for k in data.files)
    except Exception:
        return False


def _gc(ckpt_dir: str, keep: int):
    steps = _complete_steps(ckpt_dir)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _complete_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None, allow_missing=()) -> tuple[int, Any]:
    """Restore into the structure of ``target`` (tree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching tree of NamedShardings for
    elastic re-shard; None keeps default placement.  ``allow_missing``:
    key names that may legitimately be absent from the file (saved with
    ``drop=``) — the target's own leaf is kept for those instead of
    raising.

    Integrity (§15): the chosen step is crc-verified against its
    MANIFEST before any leaf is consumed.  With ``step=None`` a corrupt
    newest step falls back to the newest step that DOES verify (bit rot
    costs at most one checkpoint interval, not the run); an explicitly
    requested corrupt step raises — the caller asked for that exact
    state and must not silently get another."""
    if step is None:
        valid = [s for s in reversed(_complete_steps(ckpt_dir))
                 if verify_step(ckpt_dir, s)]
        if not valid:
            raise FileNotFoundError(
                f"no complete, uncorrupted checkpoint in {ckpt_dir}")
        step = valid[0]
    elif not verify_step(ckpt_dir, step):
        raise ValueError(
            f"checkpoint step {step} in {ckpt_dir} fails crc32 "
            f"verification against its MANIFEST (corrupt or torn write)")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "tree.npz")
    data = np.load(path)
    allow_missing = frozenset(allow_missing)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_flat):
        k = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                      for q in p)
        if k not in data.files and \
                allow_missing.intersection(k.split(_SEP)):
            out.append(leaf if sh is None else jax.device_put(leaf, sh))
            continue
        arr = data[k]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {want}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out)


def restore_params(ckpt_dir: str, target_params: Any,
                   step: Optional[int] = None,
                   shardings: Any = None) -> tuple[int, Any]:
    """Train→serve handoff (DESIGN.md §16): restore ONLY the model
    params out of a full training checkpoint (a ``{"params", "opt"}``
    tree as written by train/state.py) — the optimizer half is never
    read, so serving restarts don't pay for preconditioner state.

    ``target_params`` is the serving model's param tree (arrays or
    ShapeDtypeStructs, e.g. ``model.param_shapes()``); ``shardings`` an
    optional matching tree for elastic re-shard onto the serving mesh.
    Inherits all §15 integrity semantics from ``restore``: a corrupt
    newest step falls back to the newest step that verifies.
    """
    sh = None if shardings is None else {"params": shardings}
    step, tree = restore(ckpt_dir, {"params": target_params}, step=step,
                         shardings=sh)
    return step, tree["params"]
