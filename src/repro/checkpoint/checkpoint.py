"""Checkpointing: atomic, async-capable, elastic-reshard on restore.

Format: one directory per step, ``step_{n:08d}/``, containing
``tree.npz`` (flattened leaves keyed by path) + ``META`` (done marker).
Writes go to a temp dir and are renamed into place (atomic on POSIX), so a
crash mid-write never corrupts the latest checkpoint — the restart path
simply resumes from the newest *complete* step.

``restore`` re-shards every leaf onto the *current* mesh via device_put
with the target sharding: restarting on a different device count (elastic
scaling) works as long as the logical shapes still divide the new mesh.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        k = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        out[k] = np.asarray(jax.device_get(leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3,
         async_write: bool = False,
         drop=()) -> Optional[threading.Thread]:
    """Save tree at step; returns the writer thread if async.

    ``drop``: collection of key names — any leaf whose path contains one
    of them is excluded from the file (e.g. the in-flight pending
    preconditioner buffers of the §12 async refresh plane, which a
    restore must discard anyway: restore(allow_missing=...) keeps the
    target's own value for them)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)  # device_get happens synchronously (snapshot)
    if drop:
        drop = frozenset(drop)
        arrays = {k: v for k, v in arrays.items()
                  if not drop.intersection(k.split(_SEP))}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "tree.npz"), **arrays)
        with open(os.path.join(tmp, "META"), "w") as f:
            f.write(str(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _complete_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, d)
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(full, "META")):
            steps.append(int(d[len("step_"):]))
    return sorted(steps)


def _gc(ckpt_dir: str, keep: int):
    steps = _complete_steps(ckpt_dir)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _complete_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None, allow_missing=()) -> tuple[int, Any]:
    """Restore into the structure of ``target`` (tree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching tree of NamedShardings for
    elastic re-shard; None keeps default placement.  ``allow_missing``:
    key names that may legitimately be absent from the file (saved with
    ``drop=``) — the target's own leaf is kept for those instead of
    raising."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "tree.npz")
    data = np.load(path)
    allow_missing = frozenset(allow_missing)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_flat):
        k = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                      for q in p)
        if k not in data.files and \
                allow_missing.intersection(k.split(_SEP)):
            out.append(leaf if sh is None else jax.device_put(leaf, sh))
            continue
        arr = data[k]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {want}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out)
