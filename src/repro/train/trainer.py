"""Fault-tolerant training loop.

Features (DESIGN.md §4):
  * checkpoint/restart: atomic checkpoints every N steps (optionally via a
    background writer thread); on start, resumes from the newest complete
    checkpoint and re-shards it onto the *current* mesh (elastic scaling);
  * deterministic stateless data: batch_for_step(step) is pure, so resume
    replays the exact token stream with no iterator state;
  * straggler / hang detection: per-step wall times vs a running median;
    steps slower than ``straggler_slack`` x median are flagged (on a real
    fleet this feeds the slow-host eviction hook) and a heartbeat file is
    touched every step for external watchdogs;
  * multi-pod: the same code lowers under the production mesh — the
    launcher passes (mesh, shardings); on CPU tests mesh=None runs local.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.config import OptimizerConfig, TrainConfig
from repro.data import DataConfig, make_batch_fn
from repro.models.transformer import Model
from repro.optim import make_optimizer
from repro.train.state import make_train_step, master_params


class Trainer:
    def __init__(self, model: Model, ocfg: OptimizerConfig,
                 tcfg: TrainConfig, dcfg: DataConfig,
                 mesh=None, shardings: Optional[Dict[str, Any]] = None):
        self.model = model
        self.ocfg, self.tcfg, self.dcfg = ocfg, tcfg, dcfg
        self.mesh = mesh
        self.opt = make_optimizer(ocfg, model.logical_axes())
        self.batch_fn = make_batch_fn(model.cfg, dcfg)
        step_fn = make_train_step(model, self.opt, ocfg)
        # refresh (arg 4) is static: with precond_every=K>1 the loop picks
        # the refresh/skip step variant per step in Python (exact at step
        # 0), and the skip variant compiles with ZERO matrix-function
        # work.  K=1 passes None throughout — a single compiled step.
        if mesh is not None and shardings is not None:
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(shardings["params"], shardings["opt"],
                              shardings["batch"], None),
                out_shardings=(shardings["params"], shardings["opt"], None),
                donate_argnums=(0, 1), static_argnums=(4,))
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1),
                                   static_argnums=(4,))
        self._ckpt_thread = None
        self.step_times: list = []
        self.straggler_events = 0

    # ------------------------------------------------------------- state

    def init_state(self, seed: int = 0):
        params = master_params(self.model.init(jax.random.PRNGKey(seed)))
        opt_state = self.opt.init(params)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0):
        cdir = self.tcfg.checkpoint_dir
        if ckpt.latest_step(cdir) is not None:
            params, opt_state, _ = self.init_state(seed)
            tree = {"params": params, "opt": opt_state}
            step, restored = ckpt.restore(cdir, tree)
            print(f"[trainer] resumed from step {step}", flush=True)
            return restored["params"], restored["opt"], step
        return self.init_state(seed)

    def _checkpoint(self, step: int, params, opt_state):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()  # one in-flight write at a time
        self._ckpt_thread = ckpt.save(
            self.tcfg.checkpoint_dir, step,
            {"params": params, "opt": opt_state},
            keep=self.tcfg.keep_checkpoints,
            async_write=self.tcfg.async_checkpoint)

    # ------------------------------------------------------------- loop

    def run(self, steps: Optional[int] = None, seed: int = 0,
            on_metrics: Optional[Callable] = None):
        steps = steps or self.tcfg.steps
        params, opt_state, start = self.restore_or_init(seed)
        hb_path = os.path.join(self.tcfg.checkpoint_dir, "HEARTBEAT")
        os.makedirs(self.tcfg.checkpoint_dir, exist_ok=True)
        losses = []
        # effective staleness period: shampoo honors its legacy knob too,
        # so the static schedule matches the dynamic in-state one
        K = self.ocfg.precond_every
        if self.ocfg.name == "shampoo":
            K = max(K, self.ocfg.precondition_every)
        for t in range(start, steps):
            t0 = time.perf_counter()
            batch = self.batch_fn(jnp.asarray(t))
            refresh = (t % K == 0) if K > 1 else None
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, jnp.asarray(t, jnp.int32),
                refresh)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if t > start:  # exclude compile step from straggler stats
                self.step_times.append(dt)
                med = float(np.median(self.step_times))
                if dt > self.tcfg.straggler_slack * med and \
                        len(self.step_times) > 5:
                    self.straggler_events += 1
                    print(f"[trainer] straggler: step {t} took {dt:.2f}s "
                          f"(median {med:.2f}s)", flush=True)
            with open(hb_path, "w") as f:
                f.write(f"{t} {time.time()}")
            loss = float(metrics["loss"])
            losses.append(loss)
            if t % self.tcfg.log_every == 0:
                print(f"[trainer] step {t} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt:.2f}s", flush=True)
            if on_metrics is not None:
                on_metrics(t, metrics)
            if self.tcfg.checkpoint_every and \
                    (t + 1) % self.tcfg.checkpoint_every == 0:
                self._checkpoint(t + 1, params, opt_state)
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return params, opt_state, losses
