"""Fault-tolerant training loop.

Features (DESIGN.md §4):
  * checkpoint/restart: atomic checkpoints every N steps (optionally via a
    background writer thread); on start, resumes from the newest complete
    checkpoint and re-shards it onto the *current* mesh (elastic scaling);
  * deterministic stateless data: batch_for_step(step) is pure, so resume
    replays the exact token stream with no iterator state;
  * straggler / hang detection: per-step wall times vs a running median;
    steps slower than ``straggler_slack`` x median are flagged (on a real
    fleet this feeds the slow-host eviction hook) and a heartbeat file is
    touched every step for external watchdogs;
  * multi-pod: the same code lowers under the production mesh — the
    launcher passes (mesh, shardings); on CPU tests mesh=None runs local.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.config import OptimizerConfig, TrainConfig
from repro.data import DataConfig, make_batch_fn
from repro.models.transformer import Model
from repro.optim import base, make_optimizer
from repro.train import fault
from repro.train.state import (make_pipeline_train_step, make_train_step,
                               master_params)


class Trainer:
    def __init__(self, model: Model, ocfg: OptimizerConfig,
                 tcfg: TrainConfig, dcfg: DataConfig,
                 mesh=None, shardings: Optional[Dict[str, Any]] = None,
                 inject=None):
        self.model = model
        self.ocfg, self.tcfg, self.dcfg = ocfg, tcfg, dcfg
        self.mesh = mesh
        self.shardings = shardings
        self.opt = make_optimizer(ocfg, model.logical_axes())
        self.batch_fn = make_batch_fn(model.cfg, dcfg)
        self.pipelined = tcfg.pipeline_stages > 1
        if self.pipelined:
            # 1F1B over the pod axis (launch/pipeline.py): requires the
            # production-style mesh with pod == pipeline_stages
            assert mesh is not None and "pod" in mesh.axis_names, \
                "pipeline_stages > 1 needs a mesh with a pod axis"
            assert mesh.shape["pod"] == tcfg.pipeline_stages, \
                (mesh.shape, tcfg.pipeline_stages)
            step_fn = make_pipeline_train_step(model, self.opt, ocfg,
                                               mesh, tcfg.n_micro,
                                               inject=inject)
        else:
            step_fn = make_train_step(model, self.opt, ocfg, inject=inject)
        # the unjitted step stays reachable for trace-only observability
        # (benchmarks count its Pallas launches via ops.count_launches)
        self.raw_step_fn = step_fn
        # refresh (arg 4) is static: with precond_every=K>1 the loop picks
        # the refresh/skip step variant per step in Python (exact at step
        # 0), and the skip variant compiles with ZERO matrix-function
        # work.  K=1 passes None throughout — a single compiled step.
        if mesh is not None and shardings is not None:
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=(shardings["params"], shardings["opt"],
                              shardings["batch"], None),
                out_shardings=(shardings["params"], shardings["opt"], None),
                donate_argnums=(0, 1), static_argnums=(4,))
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1),
                                   static_argnums=(4,))
        # §12 async refresh plane: a host-side service owns WHEN to
        # dispatch the (separately jitted) refresh program; the step
        # itself then always runs the refresh=False variant — steady
        # state compiles with zero matrix-function launches.
        self.precond = (base.AsyncPrecondService(self.opt, ocfg)
                        if ocfg.precond_async else None)
        self._last_drift = 0.0
        self._ckpt_thread = None
        self.step_times: list = []
        self.straggler_events = 0

    @property
    def matfn_telemetry(self) -> Dict[str, Any]:
        """Refresh/drift counters of the async service ({} when sync)."""
        return {} if self.precond is None else self.precond.matfn_telemetry

    # ------------------------------------------------------------- state

    def init_state(self, seed: int = 0):
        params = master_params(self.model.init(jax.random.PRNGKey(seed)))
        opt_state = self.opt.init(params)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0):
        cdir = self.tcfg.checkpoint_dir
        if ckpt.latest_step(cdir) is not None:
            params, opt_state, _ = self.init_state(seed)
            tree = {"params": params, "opt": opt_state}
            # pending buffers are dropped at save time (§12), so keep the
            # freshly initialized zeros for any key absent on disk...
            step, restored = ckpt.restore(
                cdir, tree, allow_missing=base.PENDING_STATE_KEYS)
            # ...and mark the refresh plane stale: a resumed run must
            # never swap in a buffer it did not dispatch itself
            opt_state = fault.discard_inflight(restored["opt"])
            print(f"[trainer] resumed from step {step}", flush=True)
            return restored["params"], opt_state, step
        return self.init_state(seed)

    def _checkpoint(self, step: int, params, opt_state):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()  # one in-flight write at a time
        self._ckpt_thread = ckpt.save(
            self.tcfg.checkpoint_dir, step,
            {"params": params, "opt": opt_state},
            keep=self.tcfg.keep_checkpoints,
            async_write=self.tcfg.async_checkpoint,
            # in-flight pending preconditioners are schedule-local state:
            # dropping them keeps checkpoints smaller and restore marks
            # the plane stale anyway (discard_inflight)
            drop=base.PENDING_STATE_KEYS)

    # ------------------------------------------------------------- loop

    def run(self, steps: Optional[int] = None, seed: int = 0,
            on_metrics: Optional[Callable] = None):
        steps = steps or self.tcfg.steps
        params, opt_state, start = self.restore_or_init(seed)
        hb_path = os.path.join(self.tcfg.checkpoint_dir, "HEARTBEAT")
        os.makedirs(self.tcfg.checkpoint_dir, exist_ok=True)
        losses = []
        # effective staleness period (shared with the optimizers and the
        # async service via the single resolve_refresh_period helper)
        K = base.resolve_refresh_period(self.ocfg)
        for t in range(start, steps):
            t0 = time.perf_counter()
            batch = self.batch_fn(jnp.asarray(t))
            if self.precond is not None:
                # §12 two-phase step.  Phase 1: maybe dispatch a refresh
                # (non-blocking — the chains overlap the step below);
                # drift is read from the PREVIOUS step's metrics, so no
                # extra device sync here.  Phase 2: the step itself, with
                # refresh=False pinned statically — the only compiled
                # step variant, and it contains zero matfn launches.
                opt_state = self.precond.step_begin(
                    opt_state, t,
                    jax.random.fold_in(jax.random.PRNGKey(1), t),
                    drift=self._last_drift)
                if self.shardings is not None and \
                        self.precond.last_dispatch == t:
                    # the refresh program's outputs carry compiler-chosen
                    # shardings; pin the freshly installed pending
                    # buffers back onto the step's expected layout (all
                    # other leaves already match -> no-copy)
                    opt_state = jax.device_put(opt_state,
                                               self.shardings["opt"])
                refresh = False
            else:
                refresh = (t % K == 0) if K > 1 else None
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, jnp.asarray(t, jnp.int32),
                refresh)
            jax.block_until_ready(metrics["loss"])
            if self.precond is not None:
                self._last_drift = float(metrics["precond_drift"])
            dt = time.perf_counter() - t0
            if t > start:  # exclude compile step from straggler stats
                self.step_times.append(dt)
                med = float(np.median(self.step_times))
                if dt > self.tcfg.straggler_slack * med and \
                        len(self.step_times) > 5:
                    self.straggler_events += 1
                    print(f"[trainer] straggler: step {t} took {dt:.2f}s "
                          f"(median {med:.2f}s)", flush=True)
            now = time.time()
            with open(hb_path, "w") as f:
                f.write(f"{t} {now}")
            if self.pipelined:
                # per-stage heartbeats: an external supervisor watching a
                # single stage (the unit that fails on a real fleet) gets
                # the same Watchdog-parseable "<step> <time>" contract
                for s in range(self.tcfg.pipeline_stages):
                    with open(f"{hb_path}.stage{s}", "w") as f:
                        f.write(f"{t} {now}")
            loss = float(metrics["loss"])
            losses.append(loss)
            if t % self.tcfg.log_every == 0:
                print(f"[trainer] step {t} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt:.2f}s", flush=True)
            if on_metrics is not None:
                on_metrics(t, metrics)
            if self.tcfg.checkpoint_every and \
                    (t + 1) % self.tcfg.checkpoint_every == 0:
                self._checkpoint(t + 1, params, opt_state)
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return params, opt_state, losses
