from repro.train.state import make_train_step, master_params
from repro.train.trainer import Trainer

__all__ = ["Trainer", "make_train_step", "master_params"]
