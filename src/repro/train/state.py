"""Train-step construction: mixed precision, clipping, compression, Muon.

Master parameters live in fp32; the forward/backward runs in each param's
model dtype (bf16 matrices, fp32 norms/ssm constants).  The PRISM sketch
key is derived from the step counter inside the jitted step, so the step
signature stays (params, opt_state, batch, step) — clean to lower and to
checkpoint.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.models.transformer import Model
from repro.optim import base, compression


def master_params(params):
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def make_train_step(model: Model, opt: base.Optimizer,
                    ocfg: OptimizerConfig) -> Callable:
    """Build train_step(params, opt_state, batch, step, refresh=None).

    ``refresh`` is the preconditioner staleness override (base.Optimizer):
    jit it as a STATIC argument (static_argnums=(4,)) so a Python bool
    compiles separate refresh/skip variants — the skip variant contains
    zero matrix-function work.  None keeps the dynamic in-state schedule.
    """
    cast_tree = model.param_dtypes()

    def train_step(params, opt_state, batch, step, refresh=None):
        if ocfg.grads_dtype == "bfloat16":
            # differentiate wrt the bf16 compute params: the DP gradient
            # reduce-scatter then moves bf16 (half the wire bytes); the
            # fp32 master update converts afterwards.
            pc = jax.tree.map(lambda x, dt: x.astype(dt), params, cast_tree)
            (loss, metrics), grads = jax.value_and_grad(
                lambda q: model.loss(q, batch), has_aux=True)(pc)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def loss_fn(p):
                pc = jax.tree.map(lambda x, dt: x.astype(dt), p, cast_tree)
                return model.loss(pc, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
        grads, gnorm = base.clip_by_global_norm(grads, ocfg.grad_clip_norm)
        if ocfg.gradient_compression == "int8":
            grads = compression.int8_roundtrip(grads)
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        params, opt_state = opt.update(grads, opt_state, params, step, key,
                                       refresh=refresh)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        if ocfg.precond_async:
            # §12: surface the cached-preconditioner drift proxy so the
            # host-side AsyncPrecondService can trigger refreshes (a few
            # scalars — free next to the loss readback)
            metrics["precond_drift"] = base.precond_drift(opt_state)
        return params, opt_state, metrics

    return train_step


def opt_state_shardings(mesh, opt: base.Optimizer, param_shapes,
                        param_shardings):
    """Sharding tree for the optimizer state: per-param buffers matching
    the param's shape inherit its sharding; everything else replicates."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state_shapes = jax.eval_shape(opt.init, param_shapes)
    rep = NamedSharding(mesh, P())
    if "leaves" not in state_shapes:
        # adamw: state trees mirror params exactly
        def like(tree):
            return jax.tree.map(
                lambda s, sh: sh if hasattr(s, "shape") and s.shape else rep,
                tree, param_shardings)

        return {k: (like(v) if isinstance(v, dict) else rep)
                for k, v in state_shapes.items()}

    is_slot = lambda x: isinstance(x, dict) and "mom" in x
    from repro.launch.sharding import (PRECOND_CACHE_STATE_KEYS,
                                       precond_cache_sharding)

    def per_param(slot, pshape, pshard):
        out = {}
        for k, v in slot.items():
            if tuple(v.shape) == tuple(pshape.shape):
                out[k] = pshard
            elif k in PRECOND_CACHE_STATE_KEYS and len(v.shape) >= 2:
                # cached preconditioners whose layout differs from the
                # param (matrix views / factor squares): ZeRO-style
                # lead->model, rows->data instead of full replication.
                # Pending twins ("*_p", §12) shard identically, so the
                # double-buffer swap is a local per-shard select.
                out[k] = precond_cache_sharding(mesh, tuple(v.shape))
            else:
                out[k] = rep
        return out

    leaves = jax.tree.map(per_param, state_shapes["leaves"], param_shapes,
                          param_shardings, is_leaf=is_slot)
    # non-leaf scalars ("count", and "pending_at" under §12) replicate
    return dict({k: rep for k in state_shapes if k != "leaves"},
                leaves=leaves)
