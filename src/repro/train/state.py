"""Train-step construction: mixed precision, clipping, compression, Muon.

Master parameters live in fp32; the forward/backward runs in each param's
model dtype (bf16 matrices, fp32 norms/ssm constants).  The PRISM sketch
key is derived from the step counter inside the jitted step, so the step
signature stays (params, opt_state, batch, step) — clean to lower and to
checkpoint.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.models.transformer import Model
from repro.optim import base, compression


def master_params(params):
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def make_train_step(model: Model, opt: base.Optimizer,
                    ocfg: OptimizerConfig, inject=None) -> Callable:
    """Build train_step(params, opt_state, batch, step, refresh=None).

    ``refresh`` is the preconditioner staleness override (base.Optimizer):
    jit it as a STATIC argument (static_argnums=(4,)) so a Python bool
    compiles separate refresh/skip variants — the skip variant contains
    zero matrix-function work.  None keeps the dynamic in-state schedule.

    ``inject``: optional traced gradient hook ``f(grads, step) -> grads``
    applied BEFORE clipping — the §15 chaos drill's deterministic fault
    injector (train/chaos.py).  Must be pure jax (e.g. a ``jnp.where``
    on the step counter) so the step compiles once and the injection
    fires data-dependently at the target step; None is a no-op.
    """
    cast_tree = model.param_dtypes()

    def train_step(params, opt_state, batch, step, refresh=None):
        if ocfg.grads_dtype == "bfloat16":
            # differentiate wrt the bf16 compute params: the DP gradient
            # reduce-scatter then moves bf16 (half the wire bytes); the
            # fp32 master update converts afterwards.
            pc = jax.tree.map(lambda x, dt: x.astype(dt), params, cast_tree)
            (loss, metrics), grads = jax.value_and_grad(
                lambda q: model.loss(q, batch), has_aux=True)(pc)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def loss_fn(p):
                pc = jax.tree.map(lambda x, dt: x.astype(dt), p, cast_tree)
                return model.loss(pc, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
        if inject is not None:
            grads = inject(grads, step)
        grads, gnorm = base.clip_by_global_norm(grads, ocfg.grad_clip_norm)
        if ocfg.gradient_compression == "int8":
            grads = compression.int8_roundtrip(grads)
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        params, opt_state = opt.update(grads, opt_state, params, step, key,
                                       refresh=refresh)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        if ocfg.precond_async:
            # §12: surface the cached-preconditioner drift proxy so the
            # host-side AsyncPrecondService can trigger refreshes (a few
            # scalars — free next to the loss readback)
            metrics["precond_drift"] = base.precond_drift(opt_state)
        return params, opt_state, metrics

    return train_step


PIPELINE_FAMILIES = ("dense", "moe", "ssm")


def pipeline_split_params(params, n_stages: int):
    """Split params into (shared, stage-stacked layers).

    The master tree keeps the standard [L, ...] layer stacking — the
    stage view [S, L/S, ...] is a pure reshape, so checkpoints are
    stage-count independent (elastic across pipeline_stages).  When the
    layer stack is sharded over "pod" on dim 0 (launch/sharding.py
    pipeline rules), the reshape is layout-preserving: each pod already
    holds exactly its stage slice."""
    lay = params["layers"]
    shared = {k: v for k, v in params.items() if k != "layers"}

    def split(p):
        L = p.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return p.reshape((n_stages, L // n_stages) + p.shape[1:])

    return shared, jax.tree.map(split, lay)


def pipeline_merge_layer_grads(g_lay_stacked):
    """Inverse of pipeline_split_params on the layers subtree."""
    return jax.tree.map(
        lambda p: p.reshape((p.shape[0] * p.shape[1],) + p.shape[2:]),
        g_lay_stacked)


def make_pipeline_stage_fn(model: Model):
    """Adapt Model to the one_f_one_b stage contract.

    Every stage runs the same SPMD program: cast its fp32 master slices
    to model dtypes, embed (first stage only, via lax.cond), run its
    layer slice through the backbone, and seed its loss terms —
    chunked CE on the last stage, per-stage MoE aux everywhere.  Shared
    params travel replicated, so tied embeddings fall out of the psum
    over stage gradients."""
    cfg = model.cfg
    assert cfg.family in PIPELINE_FAMILIES, cfg.family
    dtypes = model.param_dtypes()
    sh_dtypes = {k: v for k, v in dtypes.items() if k != "layers"}
    lay_dtypes = dtypes["layers"]

    def stage_fn(shared, lay, tokens, x, is_first, is_last):
        shc = jax.tree.map(lambda a, t: a.astype(t), shared, sh_dtypes)
        lac = jax.tree.map(lambda a, t: a.astype(t), lay, lay_dtypes)
        x0 = jax.lax.cond(is_first,
                          lambda: model._embed_tokens(shc, tokens),
                          lambda: x)
        mb, S = x0.shape[0], x0.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                     (mb, S))
        y, aux = model._backbone({"layers": lac}, x0, positions)
        ce = jax.lax.cond(
            is_last,
            lambda: model._ce_from_hidden(shc, y, tokens),
            lambda: jnp.float32(0.0))
        return y, jnp.stack([ce, jnp.asarray(aux, jnp.float32)])

    return stage_fn


def pipeline_loss_and_grads(model: Model, mesh, n_micro: int,
                            axis: str = "pod"):
    """Build loss_and_grads(params, batch) -> (loss, grads, metrics)
    running the 1F1B schedule over ``axis`` (launch/pipeline.py)."""
    from repro.launch import pipeline

    cfg = model.cfg
    n_stages = mesh.shape[axis]
    stage_fn = make_pipeline_stage_fn(model)

    def loss_and_grads(params, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro
        tok_micro = tokens.reshape(n_micro, mb, S)
        shared, lay_stacked = pipeline_split_params(params, n_stages)
        act = jax.ShapeDtypeStruct((mb, S, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        loss_parts, g_shared, g_lay = pipeline.pipeline_grads(
            mesh, stage_fn, shared, lay_stacked, tok_micro, act,
            n_micro, axis=axis)
        grads = dict(g_shared, layers=pipeline_merge_layer_grads(g_lay))
        ce, aux = loss_parts[0], loss_parts[1]
        metrics = {"ce": ce, "aux": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}
        return ce + aux, grads, metrics

    return loss_and_grads


def make_pipeline_train_step(model: Model, opt: base.Optimizer,
                             ocfg: OptimizerConfig, mesh, n_micro: int,
                             axis: str = "pod", inject=None) -> Callable:
    """1F1B variant of make_train_step (same signature/jit contract,
    including the ``inject`` chaos hook).

    Gradients come out of the pipeline engine in fp32 (differentiated
    wrt the fp32 masters), so ``grads_dtype="bfloat16"`` — a data-
    parallel wire-format optimization — is not applicable here."""
    assert ocfg.grads_dtype != "bfloat16", \
        "pipeline training differentiates wrt fp32 masters"
    loss_and_grads = pipeline_loss_and_grads(model, mesh, n_micro,
                                             axis=axis)

    def train_step(params, opt_state, batch, step, refresh=None):
        loss, grads, metrics = loss_and_grads(params, batch)
        if inject is not None:
            grads = inject(grads, step)
        grads, gnorm = base.clip_by_global_norm(grads, ocfg.grad_clip_norm)
        if ocfg.gradient_compression == "int8":
            grads = compression.int8_roundtrip(grads)
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        params, opt_state = opt.update(grads, opt_state, params, step, key,
                                       refresh=refresh)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        if ocfg.precond_async:
            metrics["precond_drift"] = base.precond_drift(opt_state)
        return params, opt_state, metrics

    return train_step


def opt_state_shardings(mesh, opt: base.Optimizer, param_shapes,
                        param_shardings):
    """Sharding tree for the optimizer state: per-param buffers matching
    the param's shape inherit its sharding; everything else replicates."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state_shapes = jax.eval_shape(opt.init, param_shapes)
    rep = NamedSharding(mesh, P())
    if "leaves" not in state_shapes:
        # adamw: state trees mirror params exactly
        def like(tree):
            return jax.tree.map(
                lambda s, sh: sh if hasattr(s, "shape") and s.shape else rep,
                tree, param_shardings)

        return {k: (like(v) if isinstance(v, dict) else rep)
                for k, v in state_shapes.items()}

    is_slot = lambda x: isinstance(x, dict) and "mom" in x
    from repro.launch.sharding import (PRECOND_CACHE_STATE_KEYS,
                                       precond_cache_sharding)

    def per_param(slot, pshape, pshard):
        out = {}
        for k, v in slot.items():
            if tuple(v.shape) == tuple(pshape.shape):
                out[k] = pshard
            elif k in PRECOND_CACHE_STATE_KEYS and len(v.shape) >= 2:
                # cached preconditioners whose layout differs from the
                # param (matrix views / factor squares): ZeRO-style
                # lead->model, rows->data instead of full replication.
                # Pending twins ("*_p", §12) shard identically, so the
                # double-buffer swap is a local per-shard select.
                out[k] = precond_cache_sharding(mesh, tuple(v.shape))
            else:
                out[k] = rep
        return out

    leaves = jax.tree.map(per_param, state_shapes["leaves"], param_shapes,
                          param_shardings, is_leaf=is_slot)
    # non-leaf scalars ("count", and "pending_at" under §12) replicate
    return dict({k: rep for k in state_shapes if k != "leaves"},
                leaves=leaves)
