"""Chaos drill: deterministic fault injection against the §15 guardians.

Each drill injects exactly one fault into a smoke-scale training run and
asserts the matching containment layer recovers to a finite-loss
continuation:

  nan_grad        — all-NaN gradient at step k -> the skip-step guard
                    (optim.base.skip_nonfinite) replays the step as a
                    bit-exact no-op; ``bad_steps`` == 1, every loss
                    finite, params identical to the pre-step iterate.
  spectrum_spike  — a rank-1 gradient spike at step k slams the momentum
                    spectrum -> the §12 drift proxy jumps and the
                    AsyncPrecondService dispatches a drift-triggered
                    refresh instead of serving a stale preconditioner.
  ckpt_corrupt    — bit-flips the newest checkpoint payload -> crc32
                    MANIFEST verification rejects it and ``restore``
                    falls back to the newest VALID step.
  sigkill         — SIGKILL mid-step of the pipeline fault drill
                    (train/fault.py) -> relaunch resumes from the newest
                    complete checkpoint and continues BITWISE against an
                    uninterrupted reference.
  hang            — the child stalls (heartbeat stops) -> the Watchdog
                    trips 'stale' and the drill aborts the child with a
                    per-stage heartbeat diagnostic instead of hanging CI.

All injections are deterministic: the gradient hooks are traced
``jnp.where`` selects on the step counter (``inject`` arg of
train/state.make_train_step — zero recompiles, the fault fires
data-dependently at exactly step k), the corruption flips fixed bytes,
and the kill triggers off the heartbeat file.

Run as ``python -m repro.train.chaos [--inject all]``; each drill prints
one ``CHAOS_REPORT <json>`` line and the process exits non-zero on the
first containment failure.  tests/test_fault.py runs the injection
matrix in CI (chaos leg).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Optional

INJECTIONS = ("nan_grad", "spectrum_spike", "ckpt_corrupt", "sigkill",
              "hang")


# ------------------------------------------------------------ injectors

def make_injector(kind: str, at_step: int, spike: float = 1e6):
    """Traced gradient hook ``f(grads, step) -> grads`` for
    train/state.make_train_step: pure jax, so the compiled step is
    identical to the healthy one and the fault fires data-dependently
    at exactly ``at_step``."""
    import jax
    import jax.numpy as jnp

    if kind == "nan_grad":
        def inject(grads, step):
            # additive NaN poisons every leaf (0 * NaN = NaN): the worst
            # case a diverged loss / bad batch can produce
            poison = jnp.where(step == at_step, jnp.float32(jnp.nan),
                               jnp.float32(0.0))
            return jax.tree.map(lambda g: g + poison, grads)
        return inject
    if kind == "spectrum_spike":
        def inject(grads, step):
            # rank-1 spike: one huge entry redirects the post-clip
            # gradient (clipping preserves direction), slamming the
            # momentum spectrum the cached polar was computed from —
            # the §12 drift proxy, not the finiteness guard, must react
            amp = jnp.where(step == at_step, jnp.float32(spike),
                            jnp.float32(0.0))

            def one(g):
                if g.ndim < 2:
                    return g
                return g.at[(0,) * g.ndim].add(amp)

            return jax.tree.map(one, grads)
        return inject
    raise ValueError(f"no traced injector for {kind!r}")


def corrupt_checkpoint(ckpt_dir: str, step: int, nbytes: int = 16) -> str:
    """Deterministically bit-flip ``nbytes`` in the middle of a step's
    payload (tree.npz), leaving META/MANIFEST intact — the signature of
    storage bit rot / a torn write that still looks complete."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "tree.npz")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size // 2)
        block = f.read(nbytes)
        f.seek(size // 2)
        f.write(bytes(b ^ 0xFF for b in block))
    return path


# ------------------------------------------------------ in-process drills

def build_chaos_trainer(ckpt_dir: str, *, inject=None, steps: int = 8,
                        checkpoint_every: int = 0,
                        async_precond: bool = False,
                        drift_slack: float = 0.0,
                        grad_clip_norm: float = 1.0,
                        skip_nonfinite: bool = True):
    """Smoke-scale single-host Trainer (mesh=None) with every §15 guard
    armed: skip-step protection, divergence quarantine (rides the
    adaptive tol), and — under ``async_precond`` — the validated
    drift-triggered refresh plane."""
    from repro.config import OptimizerConfig, PrismConfig, TrainConfig
    from repro.configs import get_smoke_config
    from repro.data import DataConfig
    from repro.models import build
    from repro.train.trainer import Trainer

    cfg = get_smoke_config("qwen3-14b").replace(
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        dtype="float32")
    model = build(cfg)
    ocfg = OptimizerConfig(
        name="muon", matfn_method="prism", matfn_tol=1e-2,
        skip_nonfinite=skip_nonfinite, grad_clip_norm=grad_clip_norm,
        precond_every=16 if async_precond else 1,
        precond_async=async_precond,
        precond_swap_delay=1 if async_precond else 2,
        precond_drift_slack=drift_slack,
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=3,
                          sketch_dim=8, tol=1e-2))
    tcfg = TrainConfig(steps=steps, checkpoint_dir=ckpt_dir,
                       checkpoint_every=checkpoint_every, log_every=100,
                       async_checkpoint=False)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=4, seed=0, markov_rank=8)
    return Trainer(model, ocfg, tcfg, dcfg, inject=inject)


def drill_nan_grad(workdir: str, at_step: int = 3, steps: int = 6) -> dict:
    """NaN gradient at step k: the skip-step guard must eat it."""
    import jax
    import numpy as np

    trainer = build_chaos_trainer(
        os.path.join(workdir, "nan_grad"), steps=steps,
        inject=make_injector("nan_grad", at_step))
    params, opt_state, losses = trainer.run()
    bad = int(opt_state["bad_steps"])
    finite = all(math.isfinite(l) for l in losses)
    params_finite = all(bool(np.all(np.isfinite(np.asarray(l))))
                        for l in jax.tree.leaves(params))
    ok = finite and params_finite and bad == 1 and len(losses) == steps
    return {"injection": "nan_grad", "at_step": at_step,
            "bad_steps": bad, "losses_finite": finite,
            "params_finite": params_finite,
            "recovered": ok}


def drill_spectrum_spike(workdir: str, at_step: int = 4,
                         steps: int = 10) -> dict:
    """Rank-1 spike at step k: the drift proxy must jump and trigger an
    async refresh (the preconditioner tracks the new spectrum instead of
    serving a stale one until the clock ceiling)."""
    drifts = {}

    def on_metrics(t, metrics):
        drifts[t] = float(metrics["precond_drift"])

    trainer = build_chaos_trainer(
        os.path.join(workdir, "spike"), steps=steps, async_precond=True,
        # a huge clip ceiling lets the spike's magnitude reach the
        # momentum (the drill targets the drift plane, not the clipper);
        # threshold = matfn_tol * (slack-1) = 0.59 relative drift sits
        # above the settled pre-spike regime, far below the spike's jump
        drift_slack=60.0, grad_clip_norm=1e9,
        inject=make_injector("spectrum_spike", at_step))
    params, opt_state, losses = trainer.run(on_metrics=on_metrics)
    tele = trainer.matfn_telemetry
    finite = all(math.isfinite(l) for l in losses)
    pre = max(drifts.get(at_step - 2, 0.0), drifts.get(at_step - 1, 0.0))
    post = max(v for t, v in drifts.items() if t >= at_step)
    jumped = post > 5.0 * max(pre, 1e-12)
    redispatched = trainer.precond.last_dispatch is not None \
        and trainer.precond.last_dispatch > at_step
    ok = finite and jumped and redispatched \
        and tele["drift_triggered"] >= 1 and tele["discarded"] == 0
    return {"injection": "spectrum_spike", "at_step": at_step,
            "drift_pre": pre, "drift_post": post,
            "refresh_after_spike": redispatched,
            "drift_triggered": tele["drift_triggered"],
            "refreshes": tele["refreshes"], "losses_finite": finite,
            "recovered": ok}


def drill_ckpt_corrupt(workdir: str, steps: int = 6) -> dict:
    """Bit rot in the newest checkpoint: restore must reject it via the
    crc32 MANIFEST and fall back to the newest valid step."""
    from repro.checkpoint import checkpoint as ckpt

    d = os.path.join(workdir, "ckpt_corrupt")
    trainer = build_chaos_trainer(d, steps=steps, checkpoint_every=2)
    trainer.run()
    complete = ckpt._complete_steps(d)
    newest = complete[-1]
    corrupt_checkpoint(d, newest)
    rejected = not ckpt.verify_step(d, newest)
    # a fresh trainer (the restart) must resume from the newest VALID step
    trainer2 = build_chaos_trainer(d, steps=steps + 2, checkpoint_every=2)
    params, opt_state, losses = trainer2.run()
    resumed_from = (steps + 2) - len(losses)  # losses are post-resume
    finite = bool(losses) and all(math.isfinite(l) for l in losses)
    ok = bool(rejected and resumed_from < newest
              and resumed_from in complete and finite)
    return {"injection": "ckpt_corrupt", "corrupted_step": newest,
            "manifest_rejected": rejected, "resumed_from": resumed_from,
            "losses_finite": finite, "recovered": ok}


# ----------------------------------------------------- subprocess drills

def _drill_env():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["REPRO_KERNEL_MODE"] = env.get("REPRO_KERNEL_MODE", "ref")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    return env


def _losses(stdout: str) -> dict:
    out = {}
    for line in stdout.splitlines():
        if line.startswith("DRILL_LOSS "):
            _, t, h = line.split()
            out[int(t)] = h
    return out


def drill_sigkill(workdir: str, steps: int = 5,
                  timeout_s: int = 560) -> dict:
    """SIGKILL mid-step of the pipeline fault drill, then resume: the
    relaunch must continue BITWISE against an uninterrupted reference
    (sync preconditioners; compose of train/fault.py)."""
    from repro.train.fault import Watchdog, latest_restart_point

    def cmd(d):
        return [sys.executable, "-m", "repro.train.fault",
                "--ckpt_dir", d, "--steps", str(steps),
                "--ckpt_every", "2"]

    ref_dir = os.path.join(workdir, "sigkill_ref")
    kill_dir = os.path.join(workdir, "sigkill")
    ref = subprocess.run(cmd(ref_dir), env=_drill_env(),
                         capture_output=True, text=True,
                         timeout=timeout_s)
    ref_losses = _losses(ref.stdout)
    assert sorted(ref_losses) == list(range(steps)), \
        ref.stdout + ref.stderr[-4000:]

    proc = subprocess.Popen(cmd(kill_dir), env=_drill_env(),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    wd = Watchdog(os.path.join(kill_dir, "HEARTBEAT"))
    deadline = time.time() + timeout_s
    killed = False
    while time.time() < deadline:
        if proc.poll() is not None:
            break
        hb = wd.read()
        if hb is not None and hb[0] >= 2 and \
                (latest_restart_point(kill_dir) or 0) >= 2:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=30)
            killed = True
            break
        time.sleep(0.2)
    if not killed:
        proc.kill()
        raise AssertionError("drill never reached a killable checkpoint: "
                             + proc.stdout.read())
    resumed = subprocess.run(cmd(kill_dir), env=_drill_env(),
                             capture_output=True, text=True,
                             timeout=timeout_s)
    post = _losses(resumed.stdout)
    bitwise = bool(post) and all(h == ref_losses[t]
                                 for t, h in post.items())
    ok = bitwise and "resumed from step" in resumed.stdout \
        and min(post) >= 2 and max(post) == steps - 1
    return {"injection": "sigkill", "killed_after_step": wd.read()[0],
            "resumed_steps": sorted(post), "bitwise": bitwise,
            "recovered": ok}


def drill_hang(workdir: str, at_step: int = 2, stale_after_s: float = 5.0,
               timeout_s: int = 560) -> dict:
    """Hang injection: the child's heartbeat stalls at step k; the
    Watchdog must trip 'stale' so the drill aborts with a per-stage
    diagnostic instead of waiting forever (the CI-hang failure mode)."""
    from repro.train.fault import Watchdog, WatchdogConfig

    d = os.path.join(workdir, "hang")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.train.chaos", "--child-hang",
         "--workdir", d, "--at-step", str(at_step)],
        env=_drill_env(), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    hb_path = os.path.join(d, "HEARTBEAT")
    wd = Watchdog(hb_path, WatchdogConfig(stale_after_s=stale_after_s))
    deadline = time.time() + timeout_s
    verdict = None
    while time.time() < deadline:
        if proc.poll() is not None:
            verdict = "exited"
            break
        if wd.check() == "stale":
            hb = wd.read()
            if hb is not None and hb[0] >= at_step:
                verdict = "stale"  # the injected hang, not a slow step
                break
        time.sleep(0.5)
    # per-stage diagnostic: which stage last heartbeat, how long ago —
    # on a real fleet this names the host to evict
    now = time.time()
    stages = {}
    for s in range(2):
        hb = Watchdog(f"{hb_path}.stage{s}").read()
        stages[f"stage{s}"] = (None if hb is None
                               else {"step": hb[0],
                                     "age_s": round(now - hb[1], 1)})
    proc.kill()
    proc.wait(timeout=30)
    ok = verdict == "stale" and all(
        v is not None and v["step"] == at_step for v in stages.values())
    return {"injection": "hang", "watchdog": verdict,
            "stalled_at_step": at_step, "stages": stages,
            "recovered": ok}


def _child_hang(workdir: str, at_step: int):
    """Child half of drill_hang: a pipeline fault-drill run whose host
    loop stalls after step k (heartbeats stop; devices idle) — the
    signature of a wedged collective / hung host."""
    from repro.train.fault import build_pipeline_trainer

    trainer, enter = build_pipeline_trainer(
        ckpt_dir=workdir, steps=64, checkpoint_every=0)

    def stall(t, metrics):
        if t >= at_step:
            time.sleep(1 << 20)

    with enter():
        trainer.run(on_metrics=stall)


# ----------------------------------------------------------------- main

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--inject", default="all",
                    choices=INJECTIONS + ("all",))
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--at-step", type=int, default=None)
    ap.add_argument("--child-hang", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    workdir = args.workdir or tempfile.mkdtemp(prefix="repro_chaos_")

    if args.child_hang:
        _child_hang(workdir, args.at_step if args.at_step is not None
                    else 2)
        return

    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    drills = {"nan_grad": drill_nan_grad,
              "spectrum_spike": drill_spectrum_spike,
              "ckpt_corrupt": drill_ckpt_corrupt,
              "sigkill": drill_sigkill,
              "hang": drill_hang}
    names = INJECTIONS if args.inject == "all" else (args.inject,)
    failed = []
    for name in names:
        kw = {}
        if args.at_step is not None and name not in ("ckpt_corrupt",
                                                     "sigkill"):
            kw["at_step"] = args.at_step
        report = drills[name](workdir, **kw)
        print("CHAOS_REPORT " + json.dumps(report), flush=True)
        if not report["recovered"]:
            failed.append(name)
    if failed:
        print(f"CHAOS_FAILED {failed}", flush=True)
        raise SystemExit(1)
    print("CHAOS_OK", flush=True)


if __name__ == "__main__":
    main()
