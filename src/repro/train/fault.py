"""Fault-tolerance watchdog utilities + the kill/resume drill.

On a real fleet a per-host supervisor watches the trainer's HEARTBEAT file
(touched every step) and escalates: log -> preempt slow host -> restart
from the newest checkpoint.  ``Watchdog`` implements the detection logic
in a runner-agnostic way so it is unit-testable on CPU; the trainer writes
the heartbeat, this class judges it.

``python -m repro.train.fault`` is the drill half: a self-contained
pipeline training run (1F1B over an 8-device host mesh) that prints each
step's loss as a bit-exact hex float.  tests/test_fault.py launches it as
a subprocess, SIGKILLs it mid-run on a heartbeat trigger, relaunches it,
and asserts the resumed losses continue bitwise from the newest complete
checkpoint (sync preconditioners) or continue training with the async
plane re-bootstrapped by ``discard_inflight`` (the documented staleness
reset, DESIGN.md §12/§13).
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass
from typing import Optional


@dataclass
class WatchdogConfig:
    stale_after_s: float = 300.0     # no heartbeat -> presume hang
    max_step_regression: int = 0     # heartbeat step must not go backwards


class Watchdog:
    def __init__(self, heartbeat_path: str,
                 cfg: Optional[WatchdogConfig] = None):
        self.path = heartbeat_path
        # never a shared default instance: dataclass defaults are mutable,
        # so one watchdog tweaking its thresholds must not leak into the
        # next (regression-tested in tests/test_fault.py)
        self.cfg = WatchdogConfig() if cfg is None else cfg
        self.last_step: Optional[int] = None

    def read(self):
        """(step, wall_time) from the heartbeat file, or None."""
        try:
            with open(self.path) as f:
                step_s, t_s = f.read().split()
            return int(step_s), float(t_s)
        except (FileNotFoundError, ValueError):
            return None

    def check(self, now: Optional[float] = None) -> str:
        """'ok' | 'missing' | 'stale' | 'regressed'."""
        now = time.time() if now is None else now
        hb = self.read()
        if hb is None:
            return "missing"
        step, t = hb
        if now - t > self.cfg.stale_after_s:
            return "stale"
        if self.last_step is not None and \
                step < self.last_step - self.cfg.max_step_regression:
            return "regressed"
        self.last_step = step
        return "ok"

    def should_restart(self, now: Optional[float] = None) -> bool:
        return self.check(now) in ("stale", "regressed")


def discard_inflight(opt_state):
    """Mark any in-flight pending preconditioner stale after a restore
    (DESIGN.md §12).  Checkpoints drop the pending buffers
    (checkpoint.save(drop=optim.base.PENDING_STATE_KEYS)), so a resumed
    run holds zeros there; clearing ``pending_at`` guarantees the swap
    cond never consumes them — the async service re-bootstraps on the
    first post-restore step instead.  No-op for states without a refresh
    plane, so the trainer calls it unconditionally."""
    from repro.optim import base

    return base.discard_pending(opt_state)


def latest_restart_point(ckpt_dir: str) -> Optional[int]:
    """Step to restart from after a fault (newest COMPLETE checkpoint —
    crash-mid-write temp dirs are ignored by construction)."""
    from repro.checkpoint import latest_step

    if not os.path.isdir(ckpt_dir):
        return None
    return latest_step(ckpt_dir)


# --------------------------------------------------------------- drill

def build_pipeline_trainer(*, arch: str = "qwen3-14b", stages: int = 2,
                           n_micro: int = 4, steps: int = 8,
                           checkpoint_every: int = 2, ckpt_dir: str,
                           async_precond: bool = False, seq_len: int = 32,
                           global_batch: int = 8, data: int = 2,
                           model_ax: int = 2, use_kernels: bool = False,
                           num_layers: Optional[int] = None):
    """Construct (trainer, enter_ctx) for a smoke-scale 1F1B pipeline run
    on the host mesh: pod=stages slices the layer stack, (data, model)
    shard each stage's params/optimizer exactly like the production
    launcher.  Caller is responsible for having pinned JAX_PLATFORMS /
    XLA_FLAGS before jax was imported (device count = stages*data*model).

    Returns the Trainer plus the context manager that must wrap run()
    (mesh + pipeline-adapted activation rules)."""
    import contextlib

    import jax
    import jax.numpy as jnp

    from repro.config import OptimizerConfig, PrismConfig, TrainConfig
    from repro.configs import get_smoke_config
    from repro.data import DataConfig
    from repro.launch import sharding as sh
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build
    from repro.optim import make_optimizer
    from repro.sharding_ctx import activation_sharding
    from repro.train.state import opt_state_shardings
    from repro.train.trainer import Trainer

    cfg = get_smoke_config(arch).replace(
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        dtype="float32")
    if num_layers is not None:
        # deeper pipelines need num_layers % stages == 0 (smoke = 2)
        cfg = cfg.replace(num_layers=num_layers)
    model = build(cfg)
    ocfg = OptimizerConfig(
        name="muon", matfn_method="prism", precond_every=4,
        precond_async=async_precond, matfn_tol=1e-2,
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=3,
                          sketch_dim=8, tol=1e-2, use_kernels=use_kernels))
    tcfg = TrainConfig(steps=steps, checkpoint_dir=ckpt_dir,
                       checkpoint_every=checkpoint_every, log_every=100,
                       async_checkpoint=False, pipeline_stages=stages,
                       n_micro=n_micro)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=0, markov_rank=8)

    mesh = make_debug_mesh(data=data, model=model_ax, multi_pod=True,
                           pods=stages)
    prules = sh.pipeline_rules(sh.param_rules(cfg, mesh))
    arules = sh.pipeline_rules(sh.activation_rules(cfg, mesh))
    pshapes = model.param_shapes()
    master = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
    pshard = sh.tree_shardings(mesh, model.logical_axes(), prules, pshapes)
    opt = make_optimizer(ocfg, model.logical_axes())
    sshard = opt_state_shardings(mesh, opt, master, pshard)
    shardings = {"params": pshard, "opt": sshard,
                 "batch": sh.train_batch_shardings(mesh, cfg,
                                                   pipeline=True)}

    @contextlib.contextmanager
    def enter():
        with mesh, activation_sharding(mesh, arules):
            yield

    with enter():
        trainer = Trainer(model, ocfg, tcfg, dcfg, mesh, shardings)
    return trainer, enter


def main(argv=None):
    """Drill child process: pipeline training that narrates bit-exact
    losses; see module docstring.  Parent controls device count via
    XLA_FLAGS before launch."""
    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--ckpt_dir", required=True)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--n_micro", type=int, default=4)
    ap.add_argument("--ckpt_every", type=int, default=2)
    ap.add_argument("--async_precond", action="store_true")
    args = ap.parse_args(argv)

    trainer, enter = build_pipeline_trainer(
        stages=args.stages, n_micro=args.n_micro, steps=args.steps,
        checkpoint_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        async_precond=args.async_precond)

    def narrate(t, metrics):
        # hex round-trips the float64 readback exactly -> the parent can
        # compare resumed losses bitwise against the uninterrupted run
        print(f"DRILL_LOSS {t} {float(metrics['loss']).hex()}",
              flush=True)

    with enter():
        trainer.run(on_metrics=narrate)
    import json

    print("DRILL_DONE " + json.dumps(trainer.matfn_telemetry), flush=True)


if __name__ == "__main__":
    main()
