"""Fault-tolerance watchdog utilities.

On a real fleet a per-host supervisor watches the trainer's HEARTBEAT file
(touched every step) and escalates: log -> preempt slow host -> restart
from the newest checkpoint.  ``Watchdog`` implements the detection logic
in a runner-agnostic way so it is unit-testable on CPU; the trainer writes
the heartbeat, this class judges it.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Optional


@dataclass
class WatchdogConfig:
    stale_after_s: float = 300.0     # no heartbeat -> presume hang
    max_step_regression: int = 0     # heartbeat step must not go backwards


class Watchdog:
    def __init__(self, heartbeat_path: str,
                 cfg: WatchdogConfig = WatchdogConfig()):
        self.path = heartbeat_path
        self.cfg = cfg
        self.last_step: Optional[int] = None

    def read(self):
        """(step, wall_time) from the heartbeat file, or None."""
        try:
            with open(self.path) as f:
                step_s, t_s = f.read().split()
            return int(step_s), float(t_s)
        except (FileNotFoundError, ValueError):
            return None

    def check(self, now: Optional[float] = None) -> str:
        """'ok' | 'missing' | 'stale' | 'regressed'."""
        now = time.time() if now is None else now
        hb = self.read()
        if hb is None:
            return "missing"
        step, t = hb
        if now - t > self.cfg.stale_after_s:
            return "stale"
        if self.last_step is not None and \
                step < self.last_step - self.cfg.max_step_regression:
            return "regressed"
        self.last_step = step
        return "ok"

    def should_restart(self, now: Optional[float] = None) -> bool:
        return self.check(now) in ("stale", "regressed")


def discard_inflight(opt_state):
    """Mark any in-flight pending preconditioner stale after a restore
    (DESIGN.md §12).  Checkpoints drop the pending buffers
    (checkpoint.save(drop=optim.base.PENDING_STATE_KEYS)), so a resumed
    run holds zeros there; clearing ``pending_at`` guarantees the swap
    cond never consumes them — the async service re-bootstraps on the
    first post-restore step instead.  No-op for states without a refresh
    plane, so the trainer calls it unconditionally."""
    from repro.optim import base

    return base.discard_pending(opt_state)


def latest_restart_point(ckpt_dir: str) -> Optional[int]:
    """Step to restart from after a fault (newest COMPLETE checkpoint —
    crash-mid-write temp dirs are ignored by construction)."""
    from repro.checkpoint import latest_step

    if not os.path.isdir(ckpt_dir):
        return None
    return latest_step(ckpt_dir)
