"""Markdown link check for the docs layer (CI's docs leg).

Dependency-free: scans the repo's markdown for inline links/images and
verifies that every RELATIVE target resolves to a real file (and, for
``file#anchor`` targets, that the anchor matches a heading's GitHub-style
slug in the target file).  External http(s)/mailto links are not fetched
— CI has no network policy for docs — only malformed empty targets fail.

    python tools/check_markdown_links.py [paths...]

With no arguments checks README.md, DESIGN.md, PAPER.md, ROADMAP.md,
CHANGES.md and docs/**/*.md.
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# inline [text](target) / ![alt](target); reference-style links are not
# used in this repo.  Targets with spaces are not valid here.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]*)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, spaces to
    hyphens."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def _anchors(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = _CODE_FENCE.sub("", f.read())
    return {_slug(m.group(1)) for m in _HEADING.finditer(text)}


def check_file(path: str) -> list:
    errs = []
    with open(path, encoding="utf-8") as f:
        raw = f.read()
    # links inside fenced code blocks are examples, not navigation
    text = _CODE_FENCE.sub("", raw)
    for m in _LINK.finditer(text):
        target = m.group(1)
        where = f"{os.path.relpath(path, ROOT)}: ({target})"
        if not target:
            errs.append(f"{where} empty link target")
            continue
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            dest = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(dest):
                errs.append(f"{where} missing file {target!r}")
                continue
        else:  # same-file anchor
            dest = path
        if frag is not None:
            if os.path.isdir(dest) or not dest.endswith(".md"):
                continue  # only markdown anchors are checkable
            if _slug(frag) not in _anchors(dest):
                errs.append(f"{where} missing anchor #{frag}")
    return errs


def main(argv) -> int:
    paths = argv or (
        [p for p in ("README.md", "DESIGN.md", "PAPER.md", "ROADMAP.md",
                     "CHANGES.md")
         if os.path.exists(os.path.join(ROOT, p))]
        + glob.glob(os.path.join(ROOT, "docs", "**", "*.md"),
                    recursive=True))
    errs = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(ROOT, p)
        if not os.path.exists(full):
            errs.append(f"{p}: file not found")
            continue
        file_errs = check_file(full)
        errs.extend(file_errs)
        print(f"check_markdown_links: {os.path.relpath(full, ROOT)} "
              f"({'FAIL' if file_errs else 'ok'})", flush=True)
    for e in errs:
        print(f"check_markdown_links: ERROR {e}", flush=True)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
