"""1F1B pipeline training throughput + bubble model (DESIGN.md §13).

Sweeps registry SMOKE models x pipeline depth x microbatch count on the
8-device host mesh (pod x data x model), training for real with the
async PRISM-Muon engine, and records:

  * tokens/sec and the per-step wall-time trajectory (step 0 = compile);
  * the analytic bubble model ticks = n_micro + 2*(n_stages-1),
    bubble_fraction = 2*(n_stages-1)/ticks — schema-enforced to DECREASE
    in n_micro at fixed depth (more microbatches amortize fill+drain);
  * the §12/§13 composition contract: the steady-state pipeline step
    under ``precond_async`` compiles with ZERO matrix-function launches
    (every chain lives in the refresh program the service dispatches
    into the bubbles), counted by tracing with the kernel wrappers.

Each cell runs in a subprocess so the forced 8-device CPU world (and,
for launch counting, interpret-mode kernels) never leaks into the
parent.  Writes the committed baseline BENCH_pipeline_train.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit, pick, smoke

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "BENCH_pipeline_train.json")

MODELS = ["mixtral-8x7b", "qwen3-14b"]
SMOKE_MODELS = ["qwen3-14b"]
STAGES = [2, 4]
SMOKE_STAGES = [2]
N_MICRO = [2, 4, 8]
SMOKE_N_MICRO = [2, 4]
SEQ_LEN = 32
GLOBAL_BATCH = 16


def _child_env(n_devices: int = 8, interpret: bool = False):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    if interpret:
        # launch counting traces the kernel wrappers: pin interpret mode
        # with the size cutoff disabled, else the ref oracles short-
        # circuit dispatch and every count is a vacuous 0
        env["REPRO_KERNEL_MODE"] = "interpret"
        env["REPRO_INTERPRET_MAX_ELEMS"] = "0"
    else:
        env["REPRO_KERNEL_MODE"] = "ref"
    return env


def _run_child(args, interpret: bool = False):
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_pipeline_train",
         "--child", *[str(a) for a in args]],
        cwd=ROOT, env=_child_env(interpret=interpret),
        capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("ROW "):
            return json.loads(line[4:])
    raise RuntimeError(f"pipeline bench child produced no ROW:\n"
                       f"{out.stdout}\n{out.stderr[-4000:]}")


# ------------------------------------------------------------- child


def _child_main(argv):
    import argparse
    import tempfile
    import time

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--stages", type=int, required=True)
    ap.add_argument("--n_micro", type=int, required=True)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--count_launches", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch.pipeline import bubble_fraction, n_ticks_1f1b
    from repro.train.fault import build_pipeline_trainer

    # 8 host devices however the depth slices them: deeper pipelines
    # trade the model axis for stages
    data, model_ax = (2, 2) if args.stages == 2 else (2, 1)
    trainer, enter = build_pipeline_trainer(
        arch=args.arch, stages=args.stages, n_micro=args.n_micro,
        steps=args.steps, checkpoint_every=0,
        ckpt_dir=tempfile.mkdtemp(prefix="bench_pipe_"),
        async_precond=True, seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
        data=data, model_ax=model_ax,
        use_kernels=args.count_launches,
        num_layers=max(2, args.stages))

    row = {
        "model": args.arch, "stages": args.stages,
        "n_micro": args.n_micro, "seq_len": SEQ_LEN,
        "global_batch": GLOBAL_BATCH, "steps": args.steps,
        "ticks": n_ticks_1f1b(args.stages, args.n_micro),
        "bubble_fraction": bubble_fraction(args.stages, args.n_micro),
    }
    if args.count_launches:
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops

        with enter():
            params, opt_state, _ = trainer.init_state()
            batch = trainer.batch_fn(jnp.asarray(0))
            row["steady_matfn_launches"] = ops.count_launches(
                lambda p, s, b: trainer.raw_step_fn(
                    p, s, b, jnp.asarray(0, jnp.int32), False),
                params, opt_state, batch)
            row["refresh_matfn_launches"] = ops.count_launches(
                lambda s: trainer.opt.refresh(s, jax.random.PRNGKey(0)),
                opt_state)
        print("ROW " + json.dumps(row), flush=True)
        return

    step_s = []
    t_last = [None]

    def on_metrics(t, metrics):
        now = time.perf_counter()
        if t_last[0] is not None:
            step_s.append(now - t_last[0])
        t_last[0] = now

    with enter():
        t0 = time.perf_counter()
        _, _, losses = trainer.run(on_metrics=on_metrics)
        total = time.perf_counter() - t0
    tokens = GLOBAL_BATCH * SEQ_LEN
    # trajectory: per-step wall times AFTER the compile step, plus the
    # loss curve as the training-for-real receipt
    row.update({
        "step_s": [round(s, 4) for s in trainer.step_times],
        "tokens_per_sec": tokens / (sorted(trainer.step_times)
                                    [len(trainer.step_times) // 2]),
        "total_s": round(total, 2),
        "losses": [round(float(l), 4) for l in losses],
    })
    print("ROW " + json.dumps(row), flush=True)


# ------------------------------------------------------------- parent


def run(write_json: bool = True) -> None:
    models = pick(MODELS, SMOKE_MODELS)
    stages = pick(STAGES, SMOKE_STAGES)
    micros = pick(N_MICRO, SMOKE_N_MICRO)
    steps = pick(5, 3)
    rows, launch_rows = [], []
    for arch in models:
        for S in stages:
            for M in micros:
                row = _run_child(["--arch", arch, "--stages", S,
                                  "--n_micro", M, "--steps", steps])
                rows.append(row)
                emit(f"pipeline_{arch}_S{S}_M{M}",
                     1e6 * row["step_s"][-1] if row["step_s"] else 0.0,
                     tokens_per_sec=round(row["tokens_per_sec"], 1),
                     bubble=round(row["bubble_fraction"], 3),
                     loss_last=row["losses"][-1])
        if not smoke():
            # §12/§13 composition contract, once per model (trace-only)
            lrow = _run_child(["--arch", arch, "--stages", 2,
                               "--n_micro", 4, "--count_launches"],
                              interpret=True)
            launch_rows.append(lrow)
            emit(f"pipeline_{arch}_launches", 0.0,
                 steady=lrow["steady_matfn_launches"],
                 refresh=lrow["refresh_matfn_launches"])
    if not (write_json and not smoke()):
        return
    import jax

    out = {
        "benchmark": "pipeline_train",
        "backend": jax.default_backend(),
        "seq_len": SEQ_LEN,
        "global_batch": GLOBAL_BATCH,
        "notes": [
            "1F1B over pod on the 8-device host mesh; stages=2 uses "
            "(pod=2,data=2,model=2), stages=4 uses (pod=4,data=2,"
            "model=1) — data parallelism is explicit inside the fully-"
            "manual engine (microbatch dim sharded over data)",
            "tokens_per_sec from the median post-compile step; step_s "
            "is the full per-step trajectory, losses the training "
            "receipt",
            "bubble_fraction = 2*(S-1)/(M+2*(S-1)) — the analytic 1F1B "
            "model; CPU wall clock realizes it only loosely (host "
            "timesharing), the schema enforces the model itself",
            "launches: the steady async pipeline step compiles with "
            "ZERO matfn launches — every chain lives in the refresh "
            "program dispatched into the bubbles (§12/§13)",
        ],
        "results": rows,
        "launches": launch_rows,
    }
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child_main(sys.argv[2:])
    else:
        run()
