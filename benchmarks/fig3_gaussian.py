"""Paper Fig. 3 / D.1: degree-5 polar methods on Gaussian matrices with
aspect ratios gamma in {1, 4, 50}; convergence of ||I - X^T X||_F and the
PRISM alpha_k trajectory."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (emit, flops_per_iter, iters_to_tol, pick,
                               time_call)
from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

CFG = PrismConfig(degree=2, sketch_dim=8)
MAX_ITERS = 25


def run():
    key = jax.random.PRNGKey(7)
    M_BASE = 400
    for gamma in pick([1, 4, 50], [1, 50]):
        n = max(M_BASE // gamma, 8)
        m = n * gamma
        A = rm.gaussian(key, m, n)
        _, ip = matfn.polar(A, method="prism", cfg=CFG, key=key,
                            iters=MAX_ITERS, return_info=True)
        _, ic = matfn.polar(A, method="newton_schulz", cfg=CFG,
                            iters=MAX_ITERS, return_info=True)
        _, fpe = matfn.polar(A, method="polar_express", iters=MAX_ITERS,
                             return_info=True)
        itp = iters_to_tol(ip.residual_fro, n)
        itc = iters_to_tol(ic.residual_fro, n)
        itpe = iters_to_tol(fpe, n)
        alphas = np.asarray(ip.alphas)[:, ...].reshape(MAX_ITERS)
        wall = time_call(
            jax.jit(lambda A: matfn.polar(A, method="prism", cfg=CFG,
                                          key=key, iters=10)), A)
        emit(f"fig3_gaussian_gamma{gamma}", wall * 1e6 / 10,
             iters_prism=itp, iters_ns=itc, iters_pe=itpe,
             flops_speedup_vs_ns=round(
                 itc * flops_per_iter("ns", m, n)
                 / (itp * flops_per_iter("prism", m, n)), 2),
             alpha_first=round(float(alphas[0]), 3),
             alpha_last=round(float(alphas[-1]), 3),
             final_res=float(np.asarray(ip.residual_fro)[-1]))


if __name__ == "__main__":
    run()
