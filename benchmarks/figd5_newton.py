"""Paper Fig. D.5: PRISM-accelerated DB-Newton vs classical DB-Newton vs
PRISM-Newton-Schulz for the (inverse) square root."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, iters_to_tol, time_call
from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

CFG = PrismConfig(degree=2, sketch_dim=8)
N = 256


def _bench(tag, A, key):
    sq_ref, _ = matfn.sqrtm(A, method="eigh")
    rows = {}
    for meth, iters, kw in [("newton", 20, {}),
                            ("newton_classical", 20, {}),
                            ("prism", 30, dict(cfg=CFG, key=key))]:
        (sq, _), info = matfn.sqrtm(A, method=meth, iters=iters,
                                    return_info=True, **kw)
        rows[meth] = (iters_to_tol(info.residual_fro, N),
                      float(jnp.linalg.norm(sq - sq_ref)
                            / jnp.linalg.norm(sq_ref)))
    wall = time_call(
        jax.jit(lambda A: matfn.sqrtm(A, method="newton", iters=10)[0]), A)
    emit(tag, wall * 1e6 / 10,
         iters_prism_newton=rows["newton"][0],
         iters_db_classical=rows["newton_classical"][0],
         iters_prism_ns=rows["prism"][0],
         err_prism_newton=f"{rows['newton'][1]:.1e}",
         err_db=f"{rows['newton_classical'][1]:.1e}",
         err_prism_ns=f"{rows['prism'][1]:.1e}")


def run():
    key = jax.random.PRNGKey(17)
    G = rm.gaussian(key, N, N) / np.sqrt(N)
    _bench("figd5_wishart_gamma1", G.T @ G + 1e-6 * jnp.eye(N), key)
    H = rm.htmp(key, 2 * N, N, 0.1)
    _bench("figd5_htmp_kappa0.1", H.T @ H + 1e-6 * jnp.eye(N), key)


if __name__ == "__main__":
    run()
