"""Paper Fig. 6: Muon-trained LM — PolarExpress vs PRISM-5 vs PRISM-3 vs
AdamW.

CPU-scaled version of the paper's GPT-2 run (10L/1024d on FineWeb): a
4-layer/256d model of the same family trained on the synthetic bigram
stream (learnable structure), same iteration budgets as the paper (5 for
PolarExpress & PRISM-3, 3 for PRISM-5; warm alpha for the first 3 iters,
per paper App. C).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick
from repro.config import OptimizerConfig, PrismConfig
from repro.configs import get_config
from repro.data import DataConfig, make_batch_fn
from repro.models import build
from repro.optim import base, make_optimizer

STEPS = 40  # smoke: 26 (loss_step25 stays valid)


def _train(tag, ocfg, seed=0):
    cfg = get_config("gpt2-paper").replace(
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=1024, vocab_size=2048, dtype="float32", emb_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = make_optimizer(ocfg, model.logical_axes())
    state = opt.init(params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                      global_batch=16, markov_rank=32)
    batch_fn = make_batch_fn(cfg, dcfg)

    @jax.jit
    def step_fn(params, state, step):
        batch = batch_fn(step)
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        grads, _ = base.clip_by_global_norm(grads, 1.0)
        params, state = opt.update(grads, state, params, step,
                                   jax.random.fold_in(
                                       jax.random.PRNGKey(3), step))
        return params, state, loss

    losses = []
    t0 = None
    steps = pick(STEPS, 26)
    for t in range(steps):
        params, state, loss = step_fn(params, state, jnp.asarray(t))
        jax.block_until_ready(loss)
        if t == 0:
            t0 = time.perf_counter()
        losses.append(float(loss))
    wall = (time.perf_counter() - t0) / (steps - 1)
    return losses, wall


def run():
    pe = OptimizerConfig(name="muon", learning_rate=6e-3, momentum=0.95,
                         weight_decay=0.01, matfn_method="polar_express",
                         prism=PrismConfig(iterations=5))
    p5 = OptimizerConfig(name="muon", learning_rate=6e-3, momentum=0.95,
                         weight_decay=0.01, matfn_method="prism",
                         prism=PrismConfig(degree=2, iterations=3,
                                           warm_alpha_iters=3, sketch_dim=8))
    p3 = OptimizerConfig(name="muon", learning_rate=6e-3, momentum=0.95,
                         weight_decay=0.01, matfn_method="prism",
                         prism=PrismConfig(degree=1, iterations=5,
                                           warm_alpha_iters=3, sketch_dim=8))
    adamw = OptimizerConfig(name="adamw", learning_rate=3e-4,
                            weight_decay=0.1)
    for tag, ocfg in pick([("polar_express", pe), ("prism5", p5),
                           ("prism3", p3), ("adamw", adamw)],
                          [("prism5", p5), ("adamw", adamw)]):
        losses, wall = _train(tag, ocfg)
        emit(f"fig6_muon_{tag}", wall * 1e6,
             loss_step10=round(losses[10], 4),
             loss_step25=round(losses[25], 4),
             loss_final=round(losses[-1], 4))


if __name__ == "__main__":
    run()
