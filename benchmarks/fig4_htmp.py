"""Paper Fig. 4 / D.2: degree-5 polar methods on HTMP heavy-tailed
matrices (Hodgkinson et al. 2025), kappa in {0.1, 0.5, 100}."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (emit, flops_per_iter, iters_to_tol, pick,
                               time_call)
from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

CFG = PrismConfig(degree=2, sketch_dim=8)
MAX_ITERS = 40
M, N = 512, 256  # paper uses 8000 x 4000 on an A100; CPU-scaled


def run():
    key = jax.random.PRNGKey(11)
    for kappa in pick([0.1, 0.5, 100.0], [0.1]):
        A = rm.htmp(key, M, N, kappa)
        _, ip = matfn.polar(A, method="prism", cfg=CFG, key=key,
                            iters=MAX_ITERS, return_info=True)
        _, ic = matfn.polar(A, method="newton_schulz", cfg=CFG,
                            iters=MAX_ITERS, return_info=True)
        _, fpe = matfn.polar(A, method="polar_express", iters=MAX_ITERS,
                             return_info=True)
        itp = iters_to_tol(ip.residual_fro, N)
        itc = iters_to_tol(ic.residual_fro, N)
        itpe = iters_to_tol(fpe, N)
        alphas = np.asarray(ip.alphas).reshape(MAX_ITERS)
        wall = time_call(
            jax.jit(lambda A: matfn.polar(A, method="prism", cfg=CFG,
                                          key=key, iters=10)), A)
        emit(f"fig4_htmp_kappa{kappa:g}", wall * 1e6 / 10,
             iters_prism=itp, iters_ns=itc, iters_pe=itpe,
             flops_speedup_vs_ns=round(
                 itc * flops_per_iter("ns", M, N)
                 / (itp * flops_per_iter("prism", M, N)), 2),
             alpha_first=round(float(alphas[0]), 3),
             alpha_last=round(float(alphas[-1]), 3))


if __name__ == "__main__":
    run()
