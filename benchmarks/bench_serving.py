"""Serving engine: continuous vs static batching under offered load (§16).

Drives the slot-table engine (serving/engine.py) with seeded Poisson
traces (serving/loadgen.py) at ≥3 offered-QPS points per arch and
records p50/p99 full-request latency, throughput, and the slot-occupancy
trajectory.  Latency/throughput numbers run on the VIRTUAL clock (every
decode launch costs ``STEP_DT_MS``, every prefill launch the same) so
the committed baseline is deterministic and hardware-independent — the
real per-step wall cost on this container's CPU is reported alongside
for honesty.

Two schema-enforced contracts ride in the baseline:
  * continuous admission strictly out-runs static (admit only when the
    table has drained) batching in tokens/s on the same mixed-length
    trace — the reason the engine exists;
  * the decode step compiles at most 2 distinct shapes across a whole
    run (in practice exactly 1 — the slot table never changes shape).

Writes BENCH_serving.json on full runs; smoke emits the CSV subset only.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pick, smoke, time_call
from repro.configs import get_smoke_config
from repro.models import build
from repro.serving import Engine, EngineConfig, make_trace

OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_serving.json")

ARCHS = ["qwen3-14b", "mixtral-8x7b"]
SMOKE_ARCHS = ["qwen3-14b"]
QPS_POINTS = [10.0, 20.0, 40.0]
SMOKE_QPS = [20.0, 40.0]
SAT_QPS = 200.0   # backlogged regime for the continuous-vs-static contract
SLOTS = 4
CACHE_LEN = 64
STEP_DT_MS = 10.0
N_REQ = 24
SMOKE_N_REQ = 8
PROMPT_LENS = (3, 5, 8, 12)
GEN_LENS = (2, 4, 8)


def _run_engine(model, params, trace, admission: str):
    eng = Engine(model, params, EngineConfig(
        slots=SLOTS, cache_len=CACHE_LEN, greedy=True, eos_id=0,
        admission=admission))
    res = eng.run(trace, step_dt=STEP_DT_MS / 1e3)
    return res


def _bench_arch(arch: str, qps_points, n_req: int) -> dict:
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    points = []
    decode_shapes = 0
    prefill_launches = 0
    for qps in qps_points:
        trace = make_trace(0, n_requests=n_req, qps=qps,
                           vocab_size=cfg.vocab_size,
                           prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS)
        res = _run_engine(model, params, trace, "continuous")
        lat = res.latency_percentiles()
        occ = np.asarray(res.occupancy, np.float64)
        decode_shapes = max(decode_shapes, res.decode_step_shapes)
        prefill_launches += res.n_prefill_launches
        points.append({
            "qps": qps,
            "completed": len(res.completions),
            "p50_s": lat["p50"],
            "p99_s": lat["p99"],
            "tokens_per_s": res.tokens_per_s,
            "decode_steps": res.n_decode_steps,
            "occupancy_mean": float(occ.mean()),
            "occupancy_max": int(occ.max()),
            # first 32 steps of the trajectory: enough to see the table
            # fill/drain shape without bloating the baseline
            "occupancy_traj": [int(o) for o in res.occupancy[:32]],
        })
        emit(f"serving_{arch}_qps{qps:g}", lat["p50"] * 1e6,
             p99_s=round(lat["p99"], 4),
             tok_s=round(res.tokens_per_s, 1),
             occ_mean=round(float(occ.mean()), 2))

    # throughput invariant: same mixed trace, both admission policies,
    # at SATURATING load (arrivals outpace service) — below saturation
    # throughput is arrival-bound and the policies trivially tie; the
    # engine's reason to exist is the backlogged regime, where static
    # admission convoys on the longest request in each drained batch
    sat = SAT_QPS
    trace = make_trace(0, n_requests=n_req, qps=sat,
                       vocab_size=cfg.vocab_size,
                       prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS)
    cont = _run_engine(model, params, trace, "continuous")
    stat = _run_engine(model, params, trace, "static")
    emit(f"serving_{arch}_cont_vs_static", 0.0,
         cont_tok_s=round(cont.tokens_per_s, 1),
         static_tok_s=round(stat.tokens_per_s, 1),
         cont_steps=cont.n_decode_steps, static_steps=stat.n_decode_steps)

    # honest wall cost of one slot-table decode launch on this backend
    eng = Engine(model, params, EngineConfig(slots=SLOTS,
                                             cache_len=CACHE_LEN))

    def _timed():
        # the decode step donates its cache: thread it through so every
        # timed call hands in a live buffer
        nxt, eng.cache = eng._decode(
            eng.params, eng.cache,
            jnp.zeros((SLOTS, 1), jnp.int32),
            jnp.zeros((SLOTS, 1), jnp.int32),
            jnp.ones((SLOTS,), bool),
            jax.random.PRNGKey(0),
            jnp.zeros((SLOTS,), jnp.int32))
        return nxt

    step_ms = time_call(_timed) * 1e3

    return {
        "arch": cfg.name,
        "family": cfg.family,
        "slots": SLOTS,
        "cache_len": CACHE_LEN,
        "n_requests": n_req,
        "step_dt_ms": STEP_DT_MS,
        "decode_step_shapes": decode_shapes,
        "prefill_launches": prefill_launches,
        "qps_points": points,
        "sat_qps": SAT_QPS,
        "continuous_tokens_per_s": cont.tokens_per_s,
        "static_tokens_per_s": stat.tokens_per_s,
        "decode_ms_per_step_wall": step_ms,
    }


def run(write_json: bool = True) -> None:
    rows = [_bench_arch(a, pick(QPS_POINTS, SMOKE_QPS),
                        pick(N_REQ, SMOKE_N_REQ))
            for a in pick(ARCHS, SMOKE_ARCHS)]
    if not (write_json and not smoke()):
        return
    doc = {
        "benchmark": "serving",
        "backend": jax.default_backend(),
        "step_dt_ms": STEP_DT_MS,
        "notes": [
            "latency/throughput on the deterministic virtual clock "
            "(one launch = step_dt_ms); decode_ms_per_step_wall is the "
            "jit-warmed real cost of one slot-table launch on this "
            "container's CPU",
            "continuous_tokens_per_s > static_tokens_per_s is the §16 "
            "engine contract on a mixed-length seeded trace "
            "(schema-enforced)",
            "decode_step_shapes <= 2 is the jit-cache contract: the "
            "slot table never changes shape (schema-enforced)",
        ],
        "results": rows,
    }
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()
