"""Replicated vs mesh-sharded bucketed PRISM polar (DESIGN.md §8).

The workload models one Muon orthogonalization pass over a bucket of B
same-shape momentum matrices.  The replicated engine runs the full
[B, n, n] chain on every device (PR-1 state of the world); the sharded
engine shard_maps the batch dim over the mesh's data axis, so each
device runs the chain on B/shards slices and all-gathers the result.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device
_count=8 (the parent test/benchmark world stays single-device), on a
(4, 2) (data, model) host mesh.  Respects REPRO_KERNEL_MODE: the parent
environment is passed through, so CI's ref mode never falls into the
Pallas interpreter; host-CPU "devices" share the same cores, so the
wall-clock ratio understates the real-mesh win — the honest transferable
number is work_per_device, which drops by the data-axis size.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit, pick

CELLS = [(256, 16), (512, 8)]
SMOKE_CELLS = [(256, 16)]  # subset of CELLS: smoke rows match full rows

CHILD = textwrap.dedent("""
    import os
    os.environ.setdefault("REPRO_KERNEL_MODE", "ref")
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import time
    import jax, jax.numpy as jnp
    from repro.config import OptimizerConfig, PrismConfig
    from repro.launch.mesh import compat_make_mesh
    from repro.optim import bucketing
    from repro.sharding_ctx import activation_sharding

    n, B = int(sys.argv[1]), int(sys.argv[2])
    key = jax.random.PRNGKey(0)
    views = [jax.random.normal(jax.random.fold_in(key, i), (n, n))
             for i in range(B)]
    cfg = OptimizerConfig(prism=PrismConfig(degree=2, iterations=3,
                                            warm_alpha_iters=1,
                                            sketch_dim=8))

    def bench(fn):
        jfn = jax.jit(fn)
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(views))
        compile_s = time.perf_counter() - t0
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(views))
            ts.append(time.perf_counter() - t0)
        return compile_s, min(ts)

    rep_c, rep_t = bench(lambda vs: bucketing.polar_bucketed(vs, cfg,
                                                             key))
    mesh = compat_make_mesh((4, 2), ("data", "model"))
    with mesh, activation_sharding(
            mesh, {"opt_layers": "model", "opt_rows": "data"}):
        sh_c, sh_t = bench(lambda vs: bucketing.polar_bucketed(vs, cfg,
                                                               key))
    print("RESULT", rep_c, rep_t, sh_c, sh_t)
""")


def run():
    for n, B in pick(CELLS, SMOKE_CELLS):
        env = dict(os.environ)
        env.pop("JAX_PLATFORMS", None)
        env["PYTHONPATH"] = "src"
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        out = subprocess.run(
            [sys.executable, "-c", CHILD, str(n), str(B)], cwd=root,
            env=env, capture_output=True, text=True, timeout=600)
        line = [l for l in out.stdout.splitlines()
                if l.startswith("RESULT")]
        if not line:
            emit(f"sharded_precond_n{n}_B{B}", 0.0, status="ERROR",
                 err=out.stderr.strip().splitlines()[-1][:120]
                 if out.stderr.strip() else "no output")
            continue
        rep_c, rep_t, sh_c, sh_t = map(float, line[0].split()[1:])
        emit(f"sharded_precond_n{n}_B{B}", 1e6 * sh_t,
             replicated_ms=round(1e3 * rep_t, 2),
             sharded_ms=round(1e3 * sh_t, 2),
             replicated_compile_s=round(rep_c, 2),
             sharded_compile_s=round(sh_c, 2),
             data_shards=4,
             work_per_device_slices=f"{B}->{-(-B // 4)}")


if __name__ == "__main__":
    run()
