"""Paper Fig. 1: speedup over classical Newton-Schulz as sigma_min varies.

PolarExpress is optimized for sigma in [1e-3, 1] (hence [1e-6, 1] on the
square-root problem); PRISM assumes nothing.  We sweep sigma_min over
[1e-12, 1/2], run every method to convergence, and report the speedup in
GEMM-FLOPs-to-tolerance (the hardware-independent version of the paper's
GPU-time speedup) plus CPU wall time per call at a fixed iteration count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, flops_per_iter, iters_to_tol, pick,
                               time_call)
from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

N, M = 256, 256
MAX_ITERS = 60
CFG = PrismConfig(degree=2, sketch_dim=8)


def _flops_to_tol(method, info_res, n, m):
    it = iters_to_tol(info_res, n)
    per = flops_per_iter("prism" if method == "prism" else "other", m, n)
    return it, it * per


def run():
    key = jax.random.PRNGKey(42)
    for smin in pick([1e-12, 1e-9, 1e-6, 1e-3, 1e-1, 0.5], [1e-6, 0.5]):
        A = rm.log_uniform_spectrum(key, M, N, smin)
        # --- polar factor
        _, ip = matfn.polar(A, method="prism", cfg=CFG, key=key,
                            iters=MAX_ITERS, return_info=True)
        _, ic = matfn.polar(A, method="newton_schulz", cfg=CFG,
                            iters=MAX_ITERS, return_info=True)
        _, fpe = matfn.polar(A, method="polar_express", iters=MAX_ITERS,
                             return_info=True)
        itp, fp_ = _flops_to_tol("prism", ip.residual_fro, N, M)
        itc, fc = _flops_to_tol("c", ic.residual_fro, N, M)
        itpe, fpe_ = _flops_to_tol("pe", fpe, N, M)
        wall = time_call(
            jax.jit(lambda A: matfn.polar(A, method="prism", cfg=CFG,
                                          key=key, iters=10)), A)
        emit(f"fig1_polar_smin{smin:g}", wall * 1e6 / 10,
             iters_prism=itp, iters_ns=itc, iters_pe=itpe,
             speedup_prism_vs_ns=round(fc / fp_, 2),
             speedup_pe_vs_ns=round(fc / fpe_, 2))
        # --- square root (spectrum on eigenvalues => sigma_min^2 regime)
        S = rm.spd_with_eigs(key, N, jnp.exp(jnp.linspace(
            np.log(smin), 0.0, N)))
        (_, _), isp = matfn.sqrtm(S, method="prism", cfg=CFG, key=key,
                                  iters=MAX_ITERS, return_info=True)
        (_, _), isc = matfn.sqrtm(S, method="newton_schulz", cfg=CFG,
                                  iters=MAX_ITERS, return_info=True)
        (_, _), ispe = matfn.sqrtm(S, method="polar_express",
                                   iters=MAX_ITERS, return_info=True)
        itp, fp_ = _flops_to_tol("prism", isp.residual_fro, N, N)
        itc, fc = _flops_to_tol("c", isc.residual_fro, N, N)
        itpe, fpe_ = _flops_to_tol("pe", ispe, N, N)
        emit(f"fig1_sqrt_smin{smin:g}", 0.0,
             iters_prism=itp, iters_ns=itc, iters_pe=itpe,
             speedup_prism_vs_ns=round(fc / fp_, 2),
             speedup_pe_vs_ns=round(fc / fpe_, 2))


if __name__ == "__main__":
    run()
