"""Paper Fig. 5: Shampoo with eigendecomposition / PolarExpress / PRISM
inverse-root preconditioners.

CPU-scaled stand-in for ResNet-20/CIFAR: a small conv-free image MLP-mixer
-style classifier on synthetic CIFAR-shaped data with learnable structure
(class-dependent templates + noise).  We compare the three inverse-root
backends inside the same Shampoo configuration: loss after a fixed step
budget + wall time per optimizer step (the paper's axis is wall time).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pick
from repro.config import OptimizerConfig, PrismConfig
from repro.optim import base, make_optimizer

D_IN, D_H, N_CLS = 3 * 32 * 32, 512, 10
STEPS, BATCH = 30, 128  # smoke: 16 steps (see _steps())


def _init_params(key):
    ks = jax.random.split(key, 4)
    s = lambda k, a, b: jax.random.normal(k, (a, b)) / np.sqrt(a)
    return {"w1": s(ks[0], D_IN, D_H), "w2": s(ks[1], D_H, D_H),
            "w3": s(ks[2], D_H, N_CLS)}


AXES = {"w1": ("embed", "mlp"), "w2": ("embed", "mlp"),
        "w3": ("embed", "mlp")}


def _data(key, step):
    k = jax.random.fold_in(key, step)
    k1, k2, k3 = jax.random.split(k, 3)
    y = jax.random.randint(k1, (BATCH,), 0, N_CLS)
    templates = jax.random.normal(jax.random.PRNGKey(0), (N_CLS, D_IN))
    x = templates[y] + 2.0 * jax.random.normal(k2, (BATCH, D_IN))
    return x, y


def _loss(params, x, y):
    h = jax.nn.relu(x @ params["w1"])
    h = jax.nn.relu(h @ params["w2"])
    logits = h @ params["w3"]
    return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(BATCH), y])


def _train(method):
    ocfg = OptimizerConfig(
        name="shampoo", learning_rate=3e-3, matfn_method=method,
        precondition_every=5, max_precond_dim=2048,
        prism=PrismConfig(degree=2, iterations=5, sketch_dim=8,
                          warm_alpha_iters=0))
    key = jax.random.PRNGKey(1)
    params = _init_params(key)
    opt = make_optimizer(ocfg, AXES)
    state = opt.init(params)

    @jax.jit
    def step_fn(params, state, step):
        x, y = _data(key, step)
        loss, grads = jax.value_and_grad(_loss)(params, x, y)
        grads, _ = base.clip_by_global_norm(grads, 1.0)
        params, state = opt.update(grads, state, params, step,
                                   jax.random.fold_in(key, step))
        return params, state, loss

    losses = []
    t0 = None
    steps = pick(STEPS, 16)
    for t in range(steps):
        params, state, loss = step_fn(params, state, jnp.asarray(t))
        jax.block_until_ready(loss)
        if t == 0:
            t0 = time.perf_counter()  # exclude compile
        losses.append(float(loss))
    wall = (time.perf_counter() - t0) / (steps - 1)
    return losses, wall


def run():
    for method in ["prism", "polar_express", "eigh"]:
        losses, wall = _train(method)
        emit(f"fig5_shampoo_{method}", wall * 1e6,
             loss_step5=round(losses[5], 4),
             loss_step15=round(losses[15], 4),
             loss_final=round(losses[-1], 4))


if __name__ == "__main__":
    run()
