"""Per-leaf loop vs shape-bucketed batched PRISM polar (DESIGN.md §7),
with a dtype axis for the mixed-precision engine (DESIGN.md §9).

The workload models Muon over a stack of B same-shape layer weight
matrices (the transformer hot path): the per-leaf engine calls
``matfn.polar`` once per matrix inside one jit (B unrolled chains), the
bucketed engine stacks the leaves and runs ONE batched chain.

Reported per (n, B) cell:
  * wall clock per optimizer-step-equivalent call (ref-mode jnp GEMMs —
    the honest CPU number; on TPU the same dispatch structure holds),
    for BOTH matfn dtypes: ``bucketed_ms`` (fp32) and
    ``bucketed_bf16_ms`` (bf16 compute / fp32 accumulate),
  * the modeled HBM bytes one fitted PRISM-NS iteration streams over the
    bucket per dtype (``hbm_bytes_fp32`` / ``hbm_bytes_bf16``) — the
    accelerator-transferable number: bf16 operands halve chain traffic
    while accumulators/traces stay fp32.  On CPU, XLA emulates bf16 via
    fp32 upcasts, so bf16 wall clock is expected NEUTRAL-to-slower here
    (``bf16_speedup`` documents it); the HBM model is the TPU claim,
  * compile time of the first call (B unrolled chains vs one),
  * Pallas launches per step for the kernel path (counted by tracing with
    REPRO_KERNEL_MODE=interpret and the interpret-size cutoff disabled —
    counting only traces): per-leaf scales as B * (2 + d), bucketed
    stays constant at 2 + d, and the count is dtype-independent,
  * the §10 fused-iteration tier's contract (``launches_fused``: 1 launch
    per warm tail + 2 per fitted iteration, dtype-blind) and its modeled
    HBM bytes (``hbm_bytes_fused_*`` per fitted iteration,
    ``hbm_bytes_warm_tail_*`` per whole tail) next to the §7 numbers.

The ``adaptive`` section (DESIGN.md §11) documents instance-adaptive
iteration counts: at one shared residual target ``tol``, a
well-conditioned Gaussian bucket certifies in strictly fewer mean
iterations (``iters_mean``) than the fixed count a certificate-free
engine must provision for the same target — which is what the
ill-conditioned bucket's slowest member needed (``iters_max_ill``, the
fixed-iters baseline).  ``resid_max*`` record the oracle residuals so
"equal residual targets" is checkable in the committed baseline.

Writes the committed baseline BENCH_batched_matfn.json so later PRs have
a perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick, smoke
from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn
from repro.optim import bucketing

SIZES = [256, 1024]
BATCHES = [1, 8, 32]
# smoke sweeps are SUBSETS of the full grids, so every smoke CSV row has
# a same-named full-run/baseline counterpart
SMOKE_SIZES = [256]
SMOKE_BATCHES = [1, 8]
OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_batched_matfn.json")


def _prism_cfg(n: int, use_kernels: bool = False,
               dtype: str = "float32", fuse: str = "off") -> PrismConfig:
    # the per-leaf/bucketed engines pin fuse="off" so their cells keep
    # measuring the §7 batch-grid tier; the fused engine forces "on" so
    # its cells document the §10 contract on every n (the auto tier's
    # budget decision is recorded separately as fused_fits)
    return PrismConfig(degree=2, iterations=3 if n <= 256 else 2,
                       warm_alpha_iters=1, sketch_dim=8,
                       use_kernels=use_kernels, dtype=dtype, fuse=fuse)


def _engines(n: int, use_kernels: bool = False, dtype: str = "float32",
             fuse: str = "off"):
    cfg = _prism_cfg(n, use_kernels, dtype, fuse)

    def per_leaf(views, key):
        return [matfn.polar(v, method="prism", cfg=cfg,
                            key=jax.random.fold_in(key, i))
                for i, v in enumerate(views)]

    def bucketed(views, key):
        ocfg = OptimizerConfig(prism=cfg, matfn_dtype=dtype)
        return bucketing.polar_bucketed(views, ocfg, key)

    return per_leaf, bucketed


def hbm_bytes_per_iter(n: int, B: int, dtype: str, degree: int = 2,
                       sketch_pad: int = 128) -> int:
    """Modeled HBM bytes one fitted PRISM-NS iteration streams for a
    [B, n, n] bucket in the given compute dtype (DESIGN.md §9), on the
    §7 batch-grid tier.

    gram reads X once and writes R; the fused sketch chain re-reads R
    once per power (V stays in VMEM); each of the d Horner GEMMs reads
    (acc, R, X) and writes acc.  Traces/alphas are O(p) fp32 scalars —
    negligible and dtype-pinned, so they are omitted: operand bytes are
    the whole story, which is exactly why bf16 halves the number.
    """
    item = 2 if dtype == "bfloat16" else 4
    mats = B * n * n
    max_power = 4 * degree + 2
    gram = 2 * mats                      # read X, write R
    chain = max_power * mats + B * n * sketch_pad  # R per power + St once
    horner = degree * 4 * mats           # read acc, R, X; write acc
    return item * (gram + chain + horner)


def hbm_bytes_per_iter_fused(n: int, B: int, dtype: str,
                             sketch_pad: int = 128) -> int:
    """Modeled HBM bytes one FITTED iteration streams on the fused tier
    (DESIGN.md §10): launch 1 reads X and St and writes R (the chain's V
    never leaves VMEM — R is formed and consumed in-launch, so the
    max_power re-reads of the §7 model vanish); launch 2 reads X and R
    and writes X.  Independent of degree (the Horner accumulator stays
    in VMEM) and of max_power.
    """
    item = 2 if dtype == "bfloat16" else 4
    mats = B * n * n
    return item * (5 * mats + B * n * sketch_pad)


def hbm_bytes_warm_tail(n: int, B: int, dtype: str) -> int:
    """Modeled HBM bytes of an ENTIRE fused warm tail: one read + one
    write of X, however many iterations it spans (§10).  The §7 tier
    streams ~2(1+d) matrices per warm iteration instead."""
    item = 2 if dtype == "bfloat16" else 4
    return item * 2 * B * n * n


def _count_launches(fn, views, key) -> int:
    from repro.kernels import ops

    return ops.count_launches(lambda vs: fn(vs, key), views)


# adaptive-section sweep (small n: the ill-conditioned bucket runs its
# full budget of ref-mode O(n^3) iterations on CPU)
ADAPTIVE_SIZES = [64, 256]
SMOKE_ADAPTIVE_SIZES = [64]
ADAPTIVE_B = 8
ADAPTIVE_TOL = 2e-2
ADAPTIVE_BUDGET = 16


def run_adaptive(key):
    """Instance-adaptive iteration counts (DESIGN.md §11): one residual
    target, two spectra — Gaussian certifies early, near-rank-deficient
    sets the fixed-iters baseline a certificate-free engine would run."""
    import numpy as np

    from repro.core import random_matrices as rm

    rows = []
    for n in pick(ADAPTIVE_SIZES, SMOKE_ADAPTIVE_SIZES):
        acfg = PrismConfig(degree=2, iterations=ADAPTIVE_BUDGET,
                           warm_alpha_iters=1, sketch_dim=8,
                           tol=ADAPTIVE_TOL)
        gauss = jnp.stack([rm.gaussian(jax.random.fold_in(key, 300 + i),
                                       n, n) for i in range(ADAPTIVE_B)])
        ill = jnp.stack([rm.log_uniform_spectrum(
            jax.random.fold_in(key, 400 + i), n, n, 1e-4)
            for i in range(ADAPTIVE_B)])

        def resid(A, X):
            G = jnp.swapaxes(X, -1, -2) @ X
            return jnp.linalg.norm(jnp.eye(n) - G, axis=(-2, -1))

        Xg, it_g = matfn.polar(gauss, method="prism", cfg=acfg, key=key,
                               return_iters=True)
        Xi, it_i = matfn.polar(ill, method="prism", cfg=acfg, key=key,
                               return_iters=True)
        it_g, it_i = np.asarray(it_g), np.asarray(it_i)
        row = {"n": n, "B": ADAPTIVE_B, "tol": ADAPTIVE_TOL,
               "iters_budget": ADAPTIVE_BUDGET,
               "iters_mean": round(float(it_g.mean()), 2),
               "iters_max": int(it_g.max()),
               "iters_mean_ill": round(float(it_i.mean()), 2),
               "iters_max_ill": int(it_i.max()),
               "resid_max": round(float(jnp.max(resid(gauss, Xg))), 4),
               "resid_max_ill": round(float(jnp.max(resid(ill, Xi))), 4)}
        rows.append(row)
        emit(f"batched_matfn_adaptive_n{n}", row["iters_mean"],
             iters_mean=row["iters_mean"], iters_max=row["iters_max"],
             iters_mean_ill=row["iters_mean_ill"],
             iters_max_ill=row["iters_max_ill"],
             iters_budget=ADAPTIVE_BUDGET)
    return rows


def run(write_json: bool = True):
    key = jax.random.PRNGKey(0)
    results = []
    # CI smoke runs pinned to REPRO_KERNEL_MODE=ref: skip the interpret-
    # mode launch-count pass there so the benchmark never touches the
    # Pallas interpreter on runners where its Python cost dominates (the
    # count is a dispatch-structure invariant, asserted by
    # tests/test_bucketing.py on every CI run anyway)
    count_launches = os.environ.get("REPRO_KERNEL_MODE") != "ref"
    write_json = write_json and not smoke()
    for n in pick(SIZES, SMOKE_SIZES):
        for B in pick(BATCHES, SMOKE_BATCHES):
            views = [jax.random.normal(jax.random.fold_in(key, 100 + i),
                                       (n, n)) for i in range(B)]
            cell = {"n": n, "B": B,
                    "iterations": _prism_cfg(n).iterations}
            # --- launch counts (kernel dispatch structure, trace only;
            # the interpret-size cutoff is disabled because counting
            # never executes a kernel body — see kernels/ops.py)
            if count_launches:
                prev = os.environ.get("REPRO_KERNEL_MODE")
                prev_cut = os.environ.get("REPRO_INTERPRET_MAX_ELEMS")
                os.environ["REPRO_KERNEL_MODE"] = "interpret"
                os.environ["REPRO_INTERPRET_MAX_ELEMS"] = "0"
                try:
                    pl_k, bu_k = _engines(n, use_kernels=True)
                    cell["launches_per_leaf"] = _count_launches(pl_k, views,
                                                                key)
                    cell["launches_bucketed"] = _count_launches(bu_k, views,
                                                                key)
                    # dtype-independence of the §7 contract: the bf16
                    # engine must trace the SAME launch structure
                    _, bu16 = _engines(n, use_kernels=True,
                                       dtype="bfloat16")
                    cell["launches_bucketed_bf16"] = _count_launches(
                        bu16, views, key)
                    # §10 fused tier: warm tail 1 launch + 2 per fitted
                    # iteration, independent of B, d, max_power and dtype
                    _, fu_k = _engines(n, use_kernels=True, fuse="on")
                    cell["launches_fused"] = _count_launches(fu_k, views,
                                                             key)
                    _, fu16 = _engines(n, use_kernels=True,
                                       dtype="bfloat16", fuse="on")
                    cell["launches_fused_bf16"] = _count_launches(
                        fu16, views, key)
                finally:
                    for var, old in [("REPRO_KERNEL_MODE", prev),
                                     ("REPRO_INTERPRET_MAX_ELEMS",
                                      prev_cut)]:
                        if old is None:
                            os.environ.pop(var, None)
                        else:
                            os.environ[var] = old
            # --- wall clock + compile (ref mode jnp); the dtype axis
            # adds the bf16-policy bucketed engine
            per_leaf, bucketed = _engines(n)
            _, bucketed16 = _engines(n, dtype="bfloat16")
            for name, fn in [("per_leaf", per_leaf),
                             ("bucketed", bucketed),
                             ("bucketed_bf16", bucketed16)]:
                jfn = jax.jit(lambda vs, fn=fn: fn(vs, key))
                t0 = time.perf_counter()
                jax.block_until_ready(jfn(views))
                cell[f"{name}_compile_s"] = round(
                    time.perf_counter() - t0, 3)
                # min over repeats: robust to scheduler noise on a small
                # shared host (median still jitters at the 100ms scale)
                reps = 7 if n <= 256 else 2
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(jfn(views))
                    ts.append(time.perf_counter() - t0)
                cell[f"{name}_ms"] = round(1e3 * min(ts), 2)
            cell["speedup"] = round(
                cell["per_leaf_ms"] / max(cell["bucketed_ms"], 1e-9), 3)
            cell["bf16_speedup"] = round(
                cell["bucketed_ms"] / max(cell["bucketed_bf16_ms"], 1e-9),
                3)
            cell["hbm_bytes_fp32"] = hbm_bytes_per_iter(n, B, "float32")
            cell["hbm_bytes_bf16"] = hbm_bytes_per_iter(n, B, "bfloat16")
            # §10 fused-tier modeled HBM: per fitted iteration and per
            # whole warm tail, plus the auto tier's budget decision for
            # this shape at the default REPRO_VMEM_BUDGET
            from repro.kernels import ops as kops

            cell["hbm_bytes_fused_fp32"] = hbm_bytes_per_iter_fused(
                n, B, "float32")
            cell["hbm_bytes_fused_bf16"] = hbm_bytes_per_iter_fused(
                n, B, "bfloat16")
            cell["hbm_bytes_warm_tail_fp32"] = hbm_bytes_warm_tail(
                n, B, "float32")
            cell["hbm_bytes_warm_tail_bf16"] = hbm_bytes_warm_tail(
                n, B, "bfloat16")
            # the auto tier's decision is dtype-dependent (bf16 halves
            # the working set), so record both
            cell["fused_fits_fp32"] = bool(kops.fused_fits((n, n),
                                                           "float32"))
            cell["fused_fits_bf16"] = bool(kops.fused_fits((n, n),
                                                           "bfloat16"))
            results.append(cell)
            extra = ({"launches_per_leaf": cell["launches_per_leaf"],
                      "launches_bucketed": cell["launches_bucketed"],
                      "launches_bucketed_bf16":
                          cell["launches_bucketed_bf16"],
                      "launches_fused": cell["launches_fused"],
                      "launches_fused_bf16": cell["launches_fused_bf16"]}
                     if count_launches else {})
            emit(f"batched_matfn_n{n}_B{B}", 1e3 * cell["bucketed_ms"],
                 per_leaf_ms=cell["per_leaf_ms"],
                 bucketed_ms=cell["bucketed_ms"],
                 bucketed_bf16_ms=cell["bucketed_bf16_ms"],
                 speedup=cell["speedup"],
                 bf16_speedup=cell["bf16_speedup"], **extra)
    adaptive = run_adaptive(key)
    out = {"benchmark": "bucketed batched PRISM polar vs per-leaf loop",
           "backend": jax.default_backend(),
           "prism": {"degree": 2, "warm_alpha_iters": 1, "sketch_dim": 8},
           "dtypes": ["float32", "bfloat16"],
           "adaptive": adaptive,
           "notes": [
               "wall clock is the CPU ref-mode (pure-jnp) number; the "
               "bucketed win is in the dispatch-bound regime (many small "
               "leaves) and in compile time (one chain vs B).",
               "large-n CPU cells are flop-bound and XLA-CPU schedules a "
               "batched dot_general slightly worse than a loop of 2-D "
               "GEMMs, so speedup < 1 there is a host artifact; on the "
               "TPU kernel path the same cells collapse B*(2+d) Pallas "
               "launches to 2+d (see launches_per_leaf/launches_bucketed).",
               "dtype axis (DESIGN.md §9): bucketed_bf16_ms runs the "
               "bf16-compute/fp32-accumulate policy.  XLA-CPU emulates "
               "bf16 through fp32 upcasts, so CPU bf16 wall clock is "
               "neutral-to-slower BY DESIGN (bf16_speedup ~<= 1 here is "
               "expected, not a regression); the accelerator claim is "
               "hbm_bytes_bf16 = hbm_bytes_fp32 / 2 at identical launch "
               "counts (launches_bucketed_bf16 == launches_bucketed).",
               "fused axis (DESIGN.md §10): launches_fused traces the "
               "fused-iteration tier (fuse='on'): 1 launch for the warm "
               "tail + 2 per fitted iteration, vs warm*(1+d) + "
               "fitted*(2+d) on the §7 tier — and dtype-blind "
               "(launches_fused_bf16 == launches_fused).  "
               "hbm_bytes_fused_* model one fitted iteration (5 matrices "
               "+ the sketch vs 2 + (4d+2) + 4d on §7 — degree- and "
               "max_power-independent because R and the Horner "
               "accumulator never leave VMEM); hbm_bytes_warm_tail_* "
               "model an ENTIRE warm tail (one read + one write of X).  "
               "fused_fits_{fp32,bf16} record the auto tier's "
               "trace-time decision for this n per compute dtype at the "
               "default REPRO_VMEM_BUDGET (bf16 halves the working set, "
               "so it can fuse where fp32 cannot); the launch counts "
               "force fuse='on' so every cell documents the contract.",
               "adaptive axis (DESIGN.md §11): at one residual target "
               "tol, the Gaussian bucket's iters_mean must sit strictly "
               "below iters_max_ill — the fixed iteration count a "
               "certificate-free engine provisions for the same target "
               "(set by the near-rank-deficient straggler).  resid_max* "
               "prove both buckets met the target; launch contracts are "
               "tol-blind (tests/test_adaptive_tol.py).",
           ],
           "results": results}
    if write_json:
        with open(OUT, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {OUT}", flush=True)
    return out


if __name__ == "__main__":
    run()
