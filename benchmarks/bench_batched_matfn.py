"""Per-leaf loop vs shape-bucketed batched PRISM polar (DESIGN.md §7).

The workload models Muon over a stack of B same-shape layer weight
matrices (the transformer hot path): the per-leaf engine calls
``matfn.polar`` once per matrix inside one jit (B unrolled chains), the
bucketed engine stacks the leaves and runs ONE batched chain.

Reported per (n, B) cell:
  * wall clock per optimizer-step-equivalent call (ref-mode jnp GEMMs —
    the honest CPU number; on TPU the same dispatch structure holds),
  * compile time of the first call (B unrolled chains vs one),
  * Pallas launches per step for the kernel path (counted by tracing with
    REPRO_KERNEL_MODE=interpret): per-leaf scales as B * (2 + d),
    bucketed stays constant at 2 + d (gram + fused chain + d Horner GEMMs).

Writes the committed baseline BENCH_batched_matfn.json so later PRs have
a perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick, smoke
from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn
from repro.optim import bucketing

SIZES = [256, 1024]
BATCHES = [1, 8, 32]
# smoke sweeps are SUBSETS of the full grids, so every smoke CSV row has
# a same-named full-run/baseline counterpart
SMOKE_SIZES = [256]
SMOKE_BATCHES = [1, 8]
OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_batched_matfn.json")


def _prism_cfg(n: int, use_kernels: bool = False) -> PrismConfig:
    return PrismConfig(degree=2, iterations=3 if n <= 256 else 2,
                       warm_alpha_iters=1, sketch_dim=8,
                       use_kernels=use_kernels)


def _engines(n: int, use_kernels: bool = False):
    cfg = _prism_cfg(n, use_kernels)

    def per_leaf(views, key):
        return [matfn.polar(v, method="prism", cfg=cfg,
                            key=jax.random.fold_in(key, i))
                for i, v in enumerate(views)]

    def bucketed(views, key):
        ocfg = OptimizerConfig(prism=cfg)
        return bucketing.polar_bucketed(views, ocfg, key)

    return per_leaf, bucketed


def _count_launches(fn, views, key) -> int:
    from repro.kernels import ops

    return ops.count_launches(lambda vs: fn(vs, key), views)


def run(write_json: bool = True):
    key = jax.random.PRNGKey(0)
    results = []
    # CI smoke runs pinned to REPRO_KERNEL_MODE=ref: skip the interpret-
    # mode launch-count pass there so the benchmark never touches the
    # Pallas interpreter on runners where its Python cost dominates (the
    # count is a dispatch-structure invariant, asserted by
    # tests/test_bucketing.py on every CI run anyway)
    count_launches = os.environ.get("REPRO_KERNEL_MODE") != "ref"
    write_json = write_json and not smoke()
    for n in pick(SIZES, SMOKE_SIZES):
        for B in pick(BATCHES, SMOKE_BATCHES):
            views = [jax.random.normal(jax.random.fold_in(key, 100 + i),
                                       (n, n)) for i in range(B)]
            cell = {"n": n, "B": B,
                    "iterations": _prism_cfg(n).iterations}
            # --- launch counts (kernel dispatch structure, trace only)
            if count_launches:
                prev = os.environ.get("REPRO_KERNEL_MODE")
                os.environ["REPRO_KERNEL_MODE"] = "interpret"
                try:
                    pl_k, bu_k = _engines(n, use_kernels=True)
                    cell["launches_per_leaf"] = _count_launches(pl_k, views,
                                                                key)
                    cell["launches_bucketed"] = _count_launches(bu_k, views,
                                                                key)
                finally:
                    if prev is None:
                        os.environ.pop("REPRO_KERNEL_MODE", None)
                    else:
                        os.environ["REPRO_KERNEL_MODE"] = prev
            # --- wall clock + compile (ref mode jnp)
            per_leaf, bucketed = _engines(n)
            for name, fn in [("per_leaf", per_leaf),
                             ("bucketed", bucketed)]:
                jfn = jax.jit(lambda vs, fn=fn: fn(vs, key))
                t0 = time.perf_counter()
                jax.block_until_ready(jfn(views))
                cell[f"{name}_compile_s"] = round(
                    time.perf_counter() - t0, 3)
                # min over repeats: robust to scheduler noise on a small
                # shared host (median still jitters at the 100ms scale)
                reps = 7 if n <= 256 else 2
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(jfn(views))
                    ts.append(time.perf_counter() - t0)
                cell[f"{name}_ms"] = round(1e3 * min(ts), 2)
            cell["speedup"] = round(
                cell["per_leaf_ms"] / max(cell["bucketed_ms"], 1e-9), 3)
            results.append(cell)
            extra = ({"launches_per_leaf": cell["launches_per_leaf"],
                      "launches_bucketed": cell["launches_bucketed"]}
                     if count_launches else {})
            emit(f"batched_matfn_n{n}_B{B}", 1e3 * cell["bucketed_ms"],
                 per_leaf_ms=cell["per_leaf_ms"],
                 bucketed_ms=cell["bucketed_ms"],
                 speedup=cell["speedup"], **extra)
    out = {"benchmark": "bucketed batched PRISM polar vs per-leaf loop",
           "backend": jax.default_backend(),
           "prism": {"degree": 2, "warm_alpha_iters": 1, "sketch_dim": 8},
           "notes": [
               "wall clock is the CPU ref-mode (pure-jnp) number; the "
               "bucketed win is in the dispatch-bound regime (many small "
               "leaves) and in compile time (one chain vs B).",
               "large-n CPU cells are flop-bound and XLA-CPU schedules a "
               "batched dot_general slightly worse than a loop of 2-D "
               "GEMMs, so speedup < 1 there is a host artifact; on the "
               "TPU kernel path the same cells collapse B*(2+d) Pallas "
               "launches to 2+d (see launches_per_leaf/launches_bucketed).",
           ],
           "results": results}
    if write_json:
        with open(OUT, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {OUT}", flush=True)
    return out


if __name__ == "__main__":
    run()
