"""Per-leaf loop vs shape-bucketed batched PRISM polar (DESIGN.md §7),
with a dtype axis for the mixed-precision engine (DESIGN.md §9).

The workload models Muon over a stack of B same-shape layer weight
matrices (the transformer hot path): the per-leaf engine calls
``matfn.polar`` once per matrix inside one jit (B unrolled chains), the
bucketed engine stacks the leaves and runs ONE batched chain.

Reported per (n, B) cell:
  * wall clock per optimizer-step-equivalent call (ref-mode jnp GEMMs —
    the honest CPU number; on TPU the same dispatch structure holds),
    for BOTH matfn dtypes: ``bucketed_ms`` (fp32) and
    ``bucketed_bf16_ms`` (bf16 compute / fp32 accumulate),
  * the modeled HBM bytes one fitted PRISM-NS iteration streams over the
    bucket per dtype (``hbm_bytes_fp32`` / ``hbm_bytes_bf16``) — the
    accelerator-transferable number: bf16 operands halve chain traffic
    while accumulators/traces stay fp32.  On CPU, XLA emulates bf16 via
    fp32 upcasts, so bf16 wall clock is expected NEUTRAL-to-slower here
    (``bf16_speedup`` documents it); the HBM model is the TPU claim,
  * compile time of the first call (B unrolled chains vs one),
  * Pallas launches per step for the kernel path (counted by tracing with
    REPRO_KERNEL_MODE=interpret and the interpret-size cutoff disabled —
    counting only traces): per-leaf scales as B * (2 + d), bucketed
    stays constant at 2 + d, and the count is dtype-independent.

Writes the committed baseline BENCH_batched_matfn.json so later PRs have
a perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick, smoke
from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn
from repro.optim import bucketing

SIZES = [256, 1024]
BATCHES = [1, 8, 32]
# smoke sweeps are SUBSETS of the full grids, so every smoke CSV row has
# a same-named full-run/baseline counterpart
SMOKE_SIZES = [256]
SMOKE_BATCHES = [1, 8]
OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_batched_matfn.json")


def _prism_cfg(n: int, use_kernels: bool = False,
               dtype: str = "float32") -> PrismConfig:
    return PrismConfig(degree=2, iterations=3 if n <= 256 else 2,
                       warm_alpha_iters=1, sketch_dim=8,
                       use_kernels=use_kernels, dtype=dtype)


def _engines(n: int, use_kernels: bool = False, dtype: str = "float32"):
    cfg = _prism_cfg(n, use_kernels, dtype)

    def per_leaf(views, key):
        return [matfn.polar(v, method="prism", cfg=cfg,
                            key=jax.random.fold_in(key, i))
                for i, v in enumerate(views)]

    def bucketed(views, key):
        ocfg = OptimizerConfig(prism=cfg, matfn_dtype=dtype)
        return bucketing.polar_bucketed(views, ocfg, key)

    return per_leaf, bucketed


def hbm_bytes_per_iter(n: int, B: int, dtype: str, degree: int = 2,
                       sketch_pad: int = 128) -> int:
    """Modeled HBM bytes one fitted PRISM-NS iteration streams for a
    [B, n, n] bucket in the given compute dtype (DESIGN.md §9).

    gram reads X once and writes R; the fused sketch chain re-reads R
    once per power (V stays in VMEM); each of the d Horner GEMMs reads
    (acc, R, X) and writes acc.  Traces/alphas are O(p) fp32 scalars —
    negligible and dtype-pinned, so they are omitted: operand bytes are
    the whole story, which is exactly why bf16 halves the number.
    """
    item = 2 if dtype == "bfloat16" else 4
    mats = B * n * n
    max_power = 4 * degree + 2
    gram = 2 * mats                      # read X, write R
    chain = max_power * mats + B * n * sketch_pad  # R per power + St once
    horner = degree * 4 * mats           # read acc, R, X; write acc
    return item * (gram + chain + horner)


def _count_launches(fn, views, key) -> int:
    from repro.kernels import ops

    return ops.count_launches(lambda vs: fn(vs, key), views)


def run(write_json: bool = True):
    key = jax.random.PRNGKey(0)
    results = []
    # CI smoke runs pinned to REPRO_KERNEL_MODE=ref: skip the interpret-
    # mode launch-count pass there so the benchmark never touches the
    # Pallas interpreter on runners where its Python cost dominates (the
    # count is a dispatch-structure invariant, asserted by
    # tests/test_bucketing.py on every CI run anyway)
    count_launches = os.environ.get("REPRO_KERNEL_MODE") != "ref"
    write_json = write_json and not smoke()
    for n in pick(SIZES, SMOKE_SIZES):
        for B in pick(BATCHES, SMOKE_BATCHES):
            views = [jax.random.normal(jax.random.fold_in(key, 100 + i),
                                       (n, n)) for i in range(B)]
            cell = {"n": n, "B": B,
                    "iterations": _prism_cfg(n).iterations}
            # --- launch counts (kernel dispatch structure, trace only;
            # the interpret-size cutoff is disabled because counting
            # never executes a kernel body — see kernels/ops.py)
            if count_launches:
                prev = os.environ.get("REPRO_KERNEL_MODE")
                prev_cut = os.environ.get("REPRO_INTERPRET_MAX_ELEMS")
                os.environ["REPRO_KERNEL_MODE"] = "interpret"
                os.environ["REPRO_INTERPRET_MAX_ELEMS"] = "0"
                try:
                    pl_k, bu_k = _engines(n, use_kernels=True)
                    cell["launches_per_leaf"] = _count_launches(pl_k, views,
                                                                key)
                    cell["launches_bucketed"] = _count_launches(bu_k, views,
                                                                key)
                    # dtype-independence of the §7 contract: the bf16
                    # engine must trace the SAME launch structure
                    _, bu16 = _engines(n, use_kernels=True,
                                       dtype="bfloat16")
                    cell["launches_bucketed_bf16"] = _count_launches(
                        bu16, views, key)
                finally:
                    for var, old in [("REPRO_KERNEL_MODE", prev),
                                     ("REPRO_INTERPRET_MAX_ELEMS",
                                      prev_cut)]:
                        if old is None:
                            os.environ.pop(var, None)
                        else:
                            os.environ[var] = old
            # --- wall clock + compile (ref mode jnp); the dtype axis
            # adds the bf16-policy bucketed engine
            per_leaf, bucketed = _engines(n)
            _, bucketed16 = _engines(n, dtype="bfloat16")
            for name, fn in [("per_leaf", per_leaf),
                             ("bucketed", bucketed),
                             ("bucketed_bf16", bucketed16)]:
                jfn = jax.jit(lambda vs, fn=fn: fn(vs, key))
                t0 = time.perf_counter()
                jax.block_until_ready(jfn(views))
                cell[f"{name}_compile_s"] = round(
                    time.perf_counter() - t0, 3)
                # min over repeats: robust to scheduler noise on a small
                # shared host (median still jitters at the 100ms scale)
                reps = 7 if n <= 256 else 2
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(jfn(views))
                    ts.append(time.perf_counter() - t0)
                cell[f"{name}_ms"] = round(1e3 * min(ts), 2)
            cell["speedup"] = round(
                cell["per_leaf_ms"] / max(cell["bucketed_ms"], 1e-9), 3)
            cell["bf16_speedup"] = round(
                cell["bucketed_ms"] / max(cell["bucketed_bf16_ms"], 1e-9),
                3)
            cell["hbm_bytes_fp32"] = hbm_bytes_per_iter(n, B, "float32")
            cell["hbm_bytes_bf16"] = hbm_bytes_per_iter(n, B, "bfloat16")
            results.append(cell)
            extra = ({"launches_per_leaf": cell["launches_per_leaf"],
                      "launches_bucketed": cell["launches_bucketed"],
                      "launches_bucketed_bf16":
                          cell["launches_bucketed_bf16"]}
                     if count_launches else {})
            emit(f"batched_matfn_n{n}_B{B}", 1e3 * cell["bucketed_ms"],
                 per_leaf_ms=cell["per_leaf_ms"],
                 bucketed_ms=cell["bucketed_ms"],
                 bucketed_bf16_ms=cell["bucketed_bf16_ms"],
                 speedup=cell["speedup"],
                 bf16_speedup=cell["bf16_speedup"], **extra)
    out = {"benchmark": "bucketed batched PRISM polar vs per-leaf loop",
           "backend": jax.default_backend(),
           "prism": {"degree": 2, "warm_alpha_iters": 1, "sketch_dim": 8},
           "dtypes": ["float32", "bfloat16"],
           "notes": [
               "wall clock is the CPU ref-mode (pure-jnp) number; the "
               "bucketed win is in the dispatch-bound regime (many small "
               "leaves) and in compile time (one chain vs B).",
               "large-n CPU cells are flop-bound and XLA-CPU schedules a "
               "batched dot_general slightly worse than a loop of 2-D "
               "GEMMs, so speedup < 1 there is a host artifact; on the "
               "TPU kernel path the same cells collapse B*(2+d) Pallas "
               "launches to 2+d (see launches_per_leaf/launches_bucketed).",
               "dtype axis (DESIGN.md §9): bucketed_bf16_ms runs the "
               "bf16-compute/fp32-accumulate policy.  XLA-CPU emulates "
               "bf16 through fp32 upcasts, so CPU bf16 wall clock is "
               "neutral-to-slower BY DESIGN (bf16_speedup ~<= 1 here is "
               "expected, not a regression); the accelerator claim is "
               "hbm_bytes_bf16 = hbm_bytes_fp32 / 2 at identical launch "
               "counts (launches_bucketed_bf16 == launches_bucketed).",
           ],
           "results": results}
    if write_json:
        with open(OUT, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {OUT}", flush=True)
    return out


if __name__ == "__main__":
    run()
