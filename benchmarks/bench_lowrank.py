"""Lowrank sketched orthogonalization tier (DESIGN.md §14).

Sweeps aspect ratios m/n of the momentum view and reports, per cell:

* **accuracy** — orthonormality of the rangefinder basis
  (max |Q^T Q - I|) and the relative top-k subspace error of the lifted
  product against the exact SVD top-l oracle (``lowrank.svd_topk``) on a
  decaying-spectrum matrix;
* **modeled cost** — the kernels/ops.py GEMM-FLOPs and HBM-traffic
  models of the sketched path (rangefinder + two small NS chains + lift)
  vs the cubic full-view polar, the numbers the bucketing planner's win
  guard compares (``resolve_lowrank_tier``);
* **wall clock** — jit-warmed CPU ms for both paths (honest CPU number;
  the FLOPs ratio is the accelerator-transferable metric).

Writes the committed baseline BENCH_lowrank.json; its schema
(validate_bench.py) enforces the §14 headline — strictly fewer modeled
FLOPs than cubic at m >= 4n, orthogonality/oracle error within tol — so
a regression in either the models or the numerics fails CI.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pick, smoke, time_call
from repro.config import PrismConfig
from repro.core import lowrank, matfn
from repro.kernels import ops as kops

OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_lowrank.json")

# (n, aspect): view is [aspect * n, n]
CELLS = [(256, 1), (256, 2), (256, 4), (256, 8), (512, 4), (512, 8)]
SMOKE_CELLS = [(128, 4)]
RANK, OVERSAMPLE, POWER_ITERS = 16, 8, 1
TOL = 5e-3      # accuracy budget for both error metrics (fp32 engine)


def _decay_matrix(key, m: int, n: int, k: int) -> jax.Array:
    """Top-k spectrum well above a flat tail: the regime the tier
    targets (momentum with a dominant subspace)."""
    U, _ = jnp.linalg.qr(jax.random.normal(key, (m, n)))
    V, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                           (n, n)))
    s = jnp.concatenate([jnp.linspace(10.0, 5.0, k),
                         0.05 * jnp.ones(n - k)])
    return (U * s) @ V.T


def _cell(n: int, aspect: int) -> dict:
    m = aspect * n
    l = RANK + OVERSAMPLE
    key = jax.random.PRNGKey(n + aspect)
    # 30-iteration budget with a 1e-6 certificate: the cubic path
    # early-stops (§11), while the rangefinder chain needs the deep tail
    # — the power iteration cubes the spectrum, so the sketch's smallest
    # genuine direction sits ~(0.05/10)^3 ~ 1e-7 below the top and takes
    # ~25 doublings to orthonormalize
    pcfg = PrismConfig(degree=2, iterations=30, warm_alpha_iters=2,
                       sketch_dim=8, tol=1e-6)
    A = _decay_matrix(key, m, n, RANK)

    Q = lowrank.rangefinder(A, l, key, cfg=pcfg,
                            power_iters=POWER_ITERS)
    ortho_err = float(jnp.max(jnp.abs(
        jnp.swapaxes(Q, -1, -2) @ Q - jnp.eye(l))))

    low = jax.jit(lambda x: lowrank.polar_lowrank(
        x, RANK, OVERSAMPLE, cfg=pcfg, key=key,
        power_iters=POWER_ITERS))
    cubic = jax.jit(lambda x: matfn.polar(x, method="prism", cfg=pcfg,
                                          key=key))
    O = low(A)
    oracle = lowrank.svd_topk(A, l)
    # error where the tier makes its claim: the dominant-subspace block
    U, _, _ = np.linalg.svd(np.asarray(A), full_matrices=False)
    Pk = U[:, :RANK] @ U[:, :RANK].T
    topk_err = float(np.linalg.norm(Pk @ np.asarray(O - oracle))
                     / np.linalg.norm(Pk @ np.asarray(oracle)))

    ms_lowrank = 1e3 * time_call(low, A)
    ms_cubic = 1e3 * time_call(cubic, A)

    it = pcfg.iterations + pcfg.warm_alpha_iters
    flops_lowrank = kops.lowrank_polar_flops(
        (m, n), l, iters=it, degree=pcfg.degree,
        power_iters=POWER_ITERS)
    flops_cubic = kops.polar_flops((m, n), iters=it, degree=pcfg.degree)
    bf16 = jnp.dtype(jnp.bfloat16)
    hbm_lowrank = kops.lowrank_polar_hbm_bytes(
        (m, n), l, bf16, iters=it, power_iters=POWER_ITERS)
    hbm_cubic = kops.polar_hbm_bytes((m, n), bf16, iters=it)

    cell = {
        "m": m, "n": n, "aspect": aspect, "l": l, "rank": RANK,
        "oversample": OVERSAMPLE, "power_iters": POWER_ITERS,
        "iters": it, "tol": TOL,
        "ortho_err": ortho_err, "topk_err": topk_err,
        "flops_lowrank": flops_lowrank, "flops_cubic": flops_cubic,
        "flops_ratio": flops_cubic / flops_lowrank,
        "hbm_lowrank": hbm_lowrank, "hbm_cubic": hbm_cubic,
        "ms_lowrank": ms_lowrank, "ms_cubic": ms_cubic,
    }
    emit(f"lowrank_m{m}_n{n}", ms_lowrank * 1000,
         ms_cubic=round(ms_cubic, 3),
         flops_ratio=round(cell["flops_ratio"], 2),
         ortho_err=f"{ortho_err:.2e}", topk_err=f"{topk_err:.2e}")
    return cell


def run(write_json: bool = True) -> None:
    cells = [_cell(n, a) for n, a in pick(CELLS, SMOKE_CELLS)]
    if not (write_json and not smoke()):
        return
    out = {
        "benchmark": "lowrank",
        "backend": jax.default_backend(),
        "rank": RANK, "oversample": OVERSAMPLE,
        "notes": [
            "sketched rangefinder + subspace NS polar + lift "
            "(core/lowrank.py) vs the cubic full-view polar",
            "flops/hbm: the kernels/ops.py models the planner's win "
            "guard compares (resolve_lowrank_tier); bf16 bytes",
            "ortho_err: max |Q^T Q - I| of the rangefinder basis; "
            "topk_err: relative dominant-subspace error vs the SVD "
            "top-l oracle on a decaying spectrum",
            "CPU wall clock understates the win at large m: the cubic "
            "path is HBM-bound on accelerators, the sketched path "
            "streams the [m, n] view a constant number of times",
        ],
        "results": cells,
    }
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {OUT}", flush=True)
