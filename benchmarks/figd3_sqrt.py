"""Paper Fig. D.3 / D.4: degree-5 square-root methods on Wishart and HTMP
matrices; coupled (X, Y) iterations, error vs eigendecomposition."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, iters_to_tol, pick, time_call
from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

CFG = PrismConfig(degree=2, sketch_dim=8)
MAX_ITERS = 40
N = 256


def _bench(tag, A, key):
    sq_ref, isq_ref = matfn.sqrtm(A, method="eigh")
    out = {}
    for meth, kw in [("prism", dict(cfg=CFG, key=key)),
                     ("newton_schulz", dict(cfg=CFG)),
                     ("polar_express", dict())]:
        (sq, isq), info = matfn.sqrtm(A, method=meth, iters=MAX_ITERS,
                                      return_info=True, **kw)
        res = info.residual_fro if hasattr(info, "residual_fro") else info
        out[meth] = (iters_to_tol(res, N),
                     float(jnp.linalg.norm(sq - sq_ref)
                           / jnp.linalg.norm(sq_ref)))
    wall = time_call(
        jax.jit(lambda A: matfn.sqrtm(A, method="prism", cfg=CFG, key=key,
                                      iters=10)[0]), A)
    emit(tag, wall * 1e6 / 10,
         iters_prism=out["prism"][0], iters_ns=out["newton_schulz"][0],
         iters_pe=out["polar_express"][0],
         err_prism=f"{out['prism'][1]:.1e}",
         err_ns=f"{out['newton_schulz'][1]:.1e}",
         err_pe=f"{out['polar_express'][1]:.1e}")


def run():
    key = jax.random.PRNGKey(13)
    for gamma in pick([1, 4, 50], [1]):
        G = rm.gaussian(key, N * gamma, N) / np.sqrt(N * gamma)
        _bench(f"figd3_wishart_gamma{gamma}", G.T @ G, key)
    for kappa in pick([0.1, 0.5, 100.0], [0.1]):
        H = rm.htmp(key, 2 * N, N, kappa)
        _bench(f"figd4_htmp_sqrt_kappa{kappa:g}", H.T @ H, key)


if __name__ == "__main__":
    run()
