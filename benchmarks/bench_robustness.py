"""Robustness guardians: guard overhead + recovery accounting (§15).

Two experiments on the Muon hot path:

1. **Guard overhead.**  The §15 guards are selects riding existing
   chains, so they must be launch-neutral: the divergence detector adds
   ZERO launches to the adaptive matfn plan (status is read from the
   certificate the loop already computes), and the skip-step wrapper
   adds ZERO matrix-function launches to the async steady-state step
   (which stays at the §12 contract's zero).  Wall-clock overhead of the
   wrapped steady step is reported alongside (a few fused reductions +
   one select per buffer).

2. **Recovery accounting.**  A gradient stream with NaN bursts injected
   every few steps: ``bad_steps`` must count EXACTLY the injected steps
   (no false positives on the healthy steps, none missed) with the final
   params/state finite.  A poisoned refresh stream drives the validated
   async install through its discard -> backoff retry -> degrade -> clean
   recovery ladder; the counters land in the baseline so a telemetry
   regression is visible in review.

Writes the committed baseline BENCH_robustness.json
(benchmarks/validate_bench.py enforces the invariants above on every
PR).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick, smoke, time_call
from repro.config import OptimizerConfig, PrismConfig
from repro.optim import base, make_optimizer

OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_robustness.json")

CELLS = [(256, 4), (512, 2)]
SMOKE_CELLS = [(128, 2)]
PERIOD = 4


def _make(n: int, layers: int, use_kernels: bool = False, **kw):
    prism = PrismConfig(degree=2, iterations=3, warm_alpha_iters=1,
                        sketch_dim=8, tol=1e-2,
                        use_kernels=use_kernels)
    kw.setdefault("precond_every", PERIOD)
    kw.setdefault("matfn_tol", 1e-2)
    cfg = OptimizerConfig(name="muon", learning_rate=0.02, prism=prism,
                          **kw)
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (layers, n, n)),
              "o": jax.random.normal(jax.random.fold_in(key, 1),
                                     (n, 2 * n)),
              "b": jnp.zeros((n,))}
    axes = {"w": ("layers", "embed", "mlp"), "o": ("embed", "mlp"),
            "b": ("embed",)}
    return cfg, make_optimizer(cfg, axes), params


def _grads(params, key, poison: bool = False):
    g = jax.tree.map(
        lambda p: 0.1 * jax.random.normal(
            jax.random.fold_in(key, p.size), p.shape), params)
    if poison:
        g = jax.tree.map(lambda x: x * jnp.nan, g)
    return g


def _guard_overhead(n: int, layers: int) -> dict:
    """Steady-state step cost + launch counts, guards off vs on."""
    key = jax.random.PRNGKey(1)
    cells = {}
    for skip in (False, True):
        cfg, opt, params = _make(n, layers, precond_async=True,
                                 skip_nonfinite=skip)
        state = opt.init(params)
        g = _grads(params, key)
        step = jax.jit(opt.update, static_argnums=(5,))
        cells[skip] = 1e3 * time_call(
            lambda: step(g, state, params, 0, key, False))
    cell = {
        "n": n, "layers": layers, "period": PERIOD,
        "steady_ms_bare": cells[False], "steady_ms_guarded": cells[True],
        "overhead_pct": 100.0 * (cells[True] / max(cells[False], 1e-9)
                                 - 1.0),
    }
    if os.environ.get("REPRO_KERNEL_MODE") != "ref":
        prev = os.environ.get("REPRO_KERNEL_MODE")
        prev_cut = os.environ.get("REPRO_INTERPRET_MAX_ELEMS")
        os.environ["REPRO_KERNEL_MODE"] = "interpret"
        os.environ["REPRO_INTERPRET_MAX_ELEMS"] = "0"
        try:
            from repro.core import matfn
            from repro.kernels import ops

            params_k = None
            for skip in (False, True):
                kcfg, kopt, params_k = _make(n, layers,
                                             precond_async=True,
                                             use_kernels=True,
                                             skip_nonfinite=skip)
                kstate = kopt.init(params_k)
                gk = _grads(params_k, key)
                tag = "guarded" if skip else "bare"
                cell[f"steady_matfn_launches_{tag}"] = ops.count_launches(
                    lambda gg, s: kopt.update(gg, s, params_k, 0, key,
                                              refresh=False), gk, kstate)
            # matfn-level: the status read is launch-free too
            mcfg = PrismConfig(degree=2, iterations=3, warm_alpha_iters=1,
                               sketch_dim=8, tol=1e-2, use_kernels=True,
                               fuse="on")
            A = jnp.zeros((4, n, n))
            cell["matfn_launches_plain"] = ops.count_launches(
                lambda a: matfn.polar(a, method="prism", cfg=mcfg,
                                      key=key), A)
            cell["matfn_launches_status"] = ops.count_launches(
                lambda a: matfn.polar(a, method="prism", cfg=mcfg,
                                      key=key, return_iters=True,
                                      return_status=True), A)
        finally:
            for var, old in [("REPRO_KERNEL_MODE", prev),
                             ("REPRO_INTERPRET_MAX_ELEMS", prev_cut)]:
                if old is None:
                    os.environ.pop(var, None)
                else:
                    os.environ[var] = old
    emit(f"robustness_steady_n{n}_L{layers}",
         cell["steady_ms_guarded"] * 1000,
         overhead_pct=round(cell["overhead_pct"], 2),
         launches=cell.get("steady_matfn_launches_guarded", "skipped"))
    return cell


def _recovery_experiment() -> dict:
    """NaN bursts through the skip-step guard + a poisoned refresh
    stream through the validated async install."""
    n, layers = pick((128, 2), (64, 2))
    steps = pick(24, 12)
    inject_every = 4
    key = jax.random.PRNGKey(2)
    cfg, opt, params = _make(n, layers, skip_nonfinite=True)
    state = opt.init(params)
    step = jax.jit(opt.update, static_argnums=(5,))
    p = params
    injected = 0
    for t in range(steps):
        poison = t % inject_every == 2
        injected += int(poison)
        g = _grads(p, jax.random.fold_in(key, t), poison=poison)
        p, state = step(g, state, p, t, jax.random.fold_in(key, t),
                        None)
    finite = all(
        bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
        for l in jax.tree.leaves(p) + jax.tree.leaves(state))
    out = {
        "steps": steps, "injected": injected,
        "bad_steps": int(state["bad_steps"]),
        "final_finite": bool(finite),
    }

    # validated async install: fail -> retry -> degrade -> recover
    acfg, aopt, aparams = _make(n, layers, precond_async=True,
                                precond_swap_delay=1,
                                precond_max_retries=2,
                                precond_drift_slack=2.0)
    svc = base.AsyncPrecondService(aopt, acfg)
    astep = jax.jit(aopt.update, static_argnums=(5,))
    real = svc._refresh
    poison_box = {"on": False}
    svc._refresh = lambda s, k: (
        jax.tree.map(lambda x: x * jnp.nan
                     if jnp.issubdtype(x.dtype, jnp.floating) else x,
                     real(s, k)) if poison_box["on"] else real(s, k))
    astate = aopt.init(aparams)
    ap = aparams
    recovered_at = None
    for t in range(20):
        poison_box["on"] = 1 <= t <= 8 and svc.counters["degraded"] == 0
        astate = svc.step_begin(astate, t, jax.random.fold_in(key, t),
                                drift=1e9)
        if not poison_box["on"] and t > 1 and recovered_at is None \
                and int(astate["pending_at"]) != base.NO_PENDING:
            recovered_at = t
        g = _grads(ap, jax.random.fold_in(key, 100 + t))
        ap, astate = astep(g, astate, ap, t,
                           jax.random.fold_in(key, t), False)
    out.update({
        "discarded": svc.counters["discarded"],
        "retries": svc.counters["retries"],
        "degraded": svc.counters["degraded"],
        "recovered_install": recovered_at is not None,
    })
    emit("robustness_recovery", 0.0,
         bad_steps=out["bad_steps"], injected=out["injected"],
         discarded=out["discarded"], degraded=out["degraded"])
    return out


def run(write_json: bool = True) -> None:
    cells = [_guard_overhead(n, L)
             for n, L in pick(CELLS, SMOKE_CELLS)]
    recovery = _recovery_experiment()
    if not (write_json and not smoke()):
        return
    doc = {
        "benchmark": "robustness",
        "backend": jax.default_backend(),
        "period": PERIOD,
        "notes": [
            "guards are selects riding existing chains: launch-neutral "
            "by construction (schema-enforced)",
            "steady_ms_* are jit-warmed medians on this container's "
            "CPU; overhead_pct is the skip-step wrapper's cost",
            "recovery: bad_steps must equal injected NaN bursts; the "
            "async ladder is discard -> retry -> degrade -> recover",
        ],
        "results": cells,
        "recovery": recovery,
    }
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()
