"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).

``--smoke`` (or REPRO_SMOKE=1) shrinks every benchmark to CI-smoke scale
— same modules, same CSV names, reduced sweeps/steps — so CI can assert
that every registered benchmark at least executes.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# make `python benchmarks/run.py` work from anywhere: the repo root (the
# `benchmarks` namespace package's parent) must be importable
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk sizes: every benchmark executes quickly")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_SMOKE"] = "1"

    from benchmarks import (bench_async_precond, bench_batched_matfn,
                            bench_lowrank, bench_pipeline_train,
                            bench_robustness, bench_serving,
                            bench_sharded_precond, fig1_sigma_sweep,
                            fig3_gaussian, fig4_htmp, fig5_shampoo,
                            fig6_muon_lm, figd3_sqrt, figd5_newton,
                            roofline_table)

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in [fig1_sigma_sweep, fig3_gaussian, fig4_htmp, figd3_sqrt,
                figd5_newton, fig5_shampoo, fig6_muon_lm, roofline_table,
                bench_batched_matfn, bench_sharded_precond,
                bench_async_precond, bench_pipeline_train,
                bench_lowrank, bench_robustness, bench_serving]:
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---", flush=True)
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,status=ERROR;err={type(e).__name__}:{e}",
                  flush=True)
    print(f"# total {time.time() - t0:.1f}s")

    if args.smoke:
        # smoke also gates the COMMITTED baselines on their schema, so a
        # benchmark/baseline drift fails CI instead of rotting silently
        from benchmarks import validate_bench

        rc = validate_bench.main()
        if rc:
            sys.exit(rc)


if __name__ == "__main__":
    main()
