"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (bench_batched_matfn, fig1_sigma_sweep,
                            fig3_gaussian, fig4_htmp, fig5_shampoo,
                            fig6_muon_lm, figd3_sqrt, figd5_newton,
                            roofline_table)

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in [fig1_sigma_sweep, fig3_gaussian, fig4_htmp, figd3_sqrt,
                figd5_newton, fig5_shampoo, fig6_muon_lm, roofline_table,
                bench_batched_matfn]:
        name = mod.__name__.split(".")[-1]
        print(f"# --- {name} ---", flush=True)
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,status=ERROR;err={type(e).__name__}:{e}",
                  flush=True)
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
