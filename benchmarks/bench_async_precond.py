"""Async preconditioner service: steady-state cost + schedule quality
(DESIGN.md §12).

Two experiments on a Muon workload (a stack of layer weight matrices —
the transformer hot path):

1. **Step-cost decomposition.**  Times the async steady-state step (the
   ONLY compiled step variant under ``precond_async`` — swap cond
   included, zero matfn work), the standalone refresh program, and the
   legacy blocking refresh step (in-step chains).  The async service
   hides the whole refresh cost behind forward/backward, so the modeled
   async step time is the steady time; the blocking baseline pays
   ``steady + refresh`` every ``precond_every``-th step.  Launch counts
   (traced with the kernel path, skipped under REPRO_KERNEL_MODE=ref)
   document the §12 contract: ``blocking_launches_steady == 0`` — all
   matfn launches live in the refresh program.

2. **Drift-triggered vs fixed-clock schedule at an equal residual
   target.**  A piecewise-stationary gradient stream (segments of a
   fixed base gradient + small noise, spectrum shift at each boundary)
   drives two async services: a fixed clock with period K, and the drift
   trigger with the SAME certificate target (threshold set to the clock
   schedule's realized max drift) under a 10x-looser ceiling.  The drift
   schedule concentrates refreshes right after the shifts and skips the
   stationary stretches — fewer refreshes at the same max staleness
   residual (schema-enforced in BENCH_async_precond.json).

Writes the committed baseline BENCH_async_precond.json so later PRs
have a perf trajectory.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick, smoke, time_call
from repro.config import OptimizerConfig, PrismConfig
from repro.optim import base, make_optimizer

OUT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                   "BENCH_async_precond.json")

CELLS = [(256, 4), (512, 2)]       # (n, stacked layers)
SMOKE_CELLS = [(128, 2)]           # subset-scale: same row names
PERIOD = 4


def _make(n: int, layers: int, use_kernels: bool = False,
          **kw) -> tuple:
    prism = PrismConfig(degree=2, iterations=3, warm_alpha_iters=1,
                        sketch_dim=8, use_kernels=use_kernels)
    kw.setdefault("precond_every", PERIOD)
    cfg = OptimizerConfig(name="muon", learning_rate=0.02, prism=prism,
                          **kw)
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (layers, n, n)),
              "o": jax.random.normal(jax.random.fold_in(key, 1),
                                     (n, 2 * n)),
              "b": jnp.zeros((n,))}
    axes = {"w": ("layers", "embed", "mlp"), "o": ("embed", "mlp"),
            "b": ("embed",)}
    return cfg, make_optimizer(cfg, axes), params


def _grads(params, key):
    return jax.tree.map(
        lambda p: 0.1 * jax.random.normal(
            jax.random.fold_in(key, p.size), p.shape), params)


def _step_costs(n: int, layers: int) -> dict:
    key = jax.random.PRNGKey(1)
    # async: the steady step (refresh=False static) and the refresh plane
    acfg, aopt, params = _make(n, layers, precond_async=True)
    astate = aopt.init(params)
    g = _grads(params, key)
    step = jax.jit(aopt.update, static_argnums=(5,))
    steady_ms = 1e3 * time_call(
        lambda: step(g, astate, params, 0, key, False))
    refresh = jax.jit(aopt.refresh)
    refresh_ms = 1e3 * time_call(lambda: refresh(astate, key))
    # blocking baseline: the in-step refresh variant (refresh=True)
    scfg, sopt, _ = _make(n, layers)
    sstate = sopt.init(params)
    sstep = jax.jit(sopt.update, static_argnums=(5,))
    blocking_ms = 1e3 * time_call(
        lambda: sstep(g, sstate, params, 0, key, True))
    cell = {
        "n": n, "layers": layers, "period": PERIOD,
        "steady_ms": steady_ms, "refresh_ms": refresh_ms,
        "blocking_step_ms": blocking_ms,
        # the async service hides the refresh behind fwd/bwd: modeled
        # refresh-step speedup and the K-amortized mean-step speedup
        "speedup_refresh_step": blocking_ms / max(steady_ms, 1e-9),
        "speedup_amortized": (steady_ms + (blocking_ms - steady_ms)
                              / PERIOD) / max(steady_ms, 1e-9),
    }
    if os.environ.get("REPRO_KERNEL_MODE") != "ref":
        # counting only traces the kernel wrappers (never executes a
        # body), so pin interpret mode with the size cutoff disabled —
        # under the default "auto"/"ref" CPU mode no kernel is ever
        # dispatched and every count would be a vacuous 0
        prev = os.environ.get("REPRO_KERNEL_MODE")
        prev_cut = os.environ.get("REPRO_INTERPRET_MAX_ELEMS")
        os.environ["REPRO_KERNEL_MODE"] = "interpret"
        os.environ["REPRO_INTERPRET_MAX_ELEMS"] = "0"
        try:
            from repro.kernels import ops

            kacfg, kaopt, _ = _make(n, layers, precond_async=True,
                                    use_kernels=True)
            kastate = kaopt.init(params)
            pending = base.install_pending(
                kastate, kaopt.refresh(kastate, key), at_step=0)
            # §12 contract: zero matfn launches in the steady step, with
            # AND without a pending swap in flight
            cell["blocking_launches_steady"] = max(
                ops.count_launches(
                    lambda gg, s: kaopt.update(gg, s, params, 0, key,
                                               refresh=False), g, kastate),
                ops.count_launches(
                    lambda gg, s: kaopt.update(gg, s, params, 0, key,
                                               refresh=False), g, pending))
            cell["launches_refresh"] = ops.count_launches(
                lambda s: kaopt.refresh(s, key), kastate)
            kscfg, ksopt, _ = _make(n, layers, use_kernels=True)
            cell["launches_blocking_step"] = ops.count_launches(
                lambda gg, s: ksopt.update(gg, s, params, 0, key,
                                           refresh=True),
                g, ksopt.init(params))
        finally:
            for var, old in [("REPRO_KERNEL_MODE", prev),
                             ("REPRO_INTERPRET_MAX_ELEMS", prev_cut)]:
                if old is None:
                    os.environ.pop(var, None)
                else:
                    os.environ[var] = old
    emit(f"async_steady_n{n}_L{layers}", steady_ms * 1000,
         refresh_ms=round(refresh_ms, 3),
         blocking_ms=round(blocking_ms, 3),
         speedup_refresh_step=round(cell["speedup_refresh_step"], 2),
         launches_steady=cell.get("blocking_launches_steady", "skipped"))
    return cell


def _run_schedule(cfg, opt, params, steps: int, segment: int):
    """Drive one async service over the piecewise-stationary stream;
    returns (refreshes, max consumed drift)."""
    key = jax.random.PRNGKey(2)
    svc = base.AsyncPrecondService(opt, cfg)
    step = jax.jit(opt.update, static_argnums=(5,))
    p, s = params, opt.init(params)
    drift_max = 0.0
    for t in range(steps):
        drift = float(base.precond_drift(s))
        if t >= 2 * segment:
            # measure steady-state staleness only: skip the warmup
            # segments where rnorm is still settling from zero
            drift_max = max(drift_max, drift)
        s = svc.step_begin(s, t, jax.random.fold_in(key, t), drift=drift)
        base_key = jax.random.fold_in(key, 10_000 + t // segment)
        g = jax.tree.map(
            lambda q: 0.1 * jax.random.normal(
                jax.random.fold_in(base_key, q.size), q.shape)
            + 0.005 * jax.random.normal(
                jax.random.fold_in(jax.random.fold_in(key, t), q.size),
                q.shape), params)
        p, s = step(g, s, p, t, jax.random.PRNGKey(7), False)
    return svc.counters, drift_max


def _schedule_experiment() -> dict:
    n, layers = pick((128, 2), (64, 2))
    steps = pick(120, 40)
    segment = pick(30, 10)
    K = 6
    tol = 1e-3
    # fixed clock at period K (trigger disabled)
    ccfg, copt, params = _make(n, layers, precond_async=True,
                               precond_every=K, matfn_tol=tol,
                               momentum=0.5)
    clock, drift_max_clock = _run_schedule(ccfg, copt, params, steps,
                                           segment)
    # drift trigger at the SAME certificate target (threshold = the
    # clock schedule's realized max drift) under a 10x-looser ceiling
    slack = 1.0 + drift_max_clock / tol
    dcfg, dopt, _ = _make(n, layers, precond_async=True,
                          precond_every=10 * K, matfn_tol=tol,
                          precond_drift_slack=slack, momentum=0.5)
    drift, drift_max_drift = _run_schedule(dcfg, dopt, params, steps,
                                           segment)
    out = {
        "n": n, "layers": layers, "steps": steps, "segment": segment,
        "period_clock": K, "ceiling_drift": 10 * K,
        "drift_threshold": dcfg.drift_threshold,
        "refreshes_clock": clock["refreshes"],
        "refreshes_drift": drift["refreshes"],
        "drift_triggered": drift["drift_triggered"],
        "clock_triggered_in_drift_run": drift["clock_triggered"],
        "drift_max_clock": drift_max_clock,
        "drift_max_drift": drift_max_drift,
    }
    emit("async_schedule", 0.0,
         refreshes_clock=clock["refreshes"],
         refreshes_drift=drift["refreshes"],
         drift_max_clock=round(drift_max_clock, 5),
         drift_max_drift=round(drift_max_drift, 5))
    return out


def run(write_json: bool = True) -> None:
    cells = [_step_costs(n, L) for n, L in pick(CELLS, SMOKE_CELLS)]
    sched = _schedule_experiment()
    if not (write_json and not smoke()):
        return
    out = {
        "benchmark": "async_precond",
        "backend": jax.default_backend(),
        "period": PERIOD,
        "notes": [
            "steady_ms: the async steady-state step (the only compiled "
            "variant under precond_async) — zero matfn launches, swap "
            "cond included",
            "refresh_ms: the standalone jitted refresh program the "
            "service overlaps with fwd/bwd",
            "blocking_step_ms: the legacy in-step refresh variant "
            "(refresh=True) the async plane replaces",
            "CPU wall clock understates the async win: on an "
            "accelerator the refresh overlaps compute instead of "
            "timesharing host cores",
            "schedule: drift trigger vs fixed clock on a piecewise-"
            "stationary stream at an equal max-staleness target",
        ],
        "results": cells,
        "schedule": sched,
    }
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {OUT}", flush=True)
