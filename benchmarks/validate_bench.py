"""Schema validation for committed BENCH_*.json baselines.

Run by CI's smoke step (and by ``benchmarks/run.py --smoke``) so a
benchmark edit that drifts from its committed baseline's shape — a
renamed field, a dropped dtype axis, a non-numeric cell — fails the PR
instead of silently rotting the perf trajectory.  Hand-rolled checks
(no jsonschema dependency in the container): a schema here is a dict of
``field -> predicate`` for the top level and for each results row, plus
cross-field invariants.
"""
from __future__ import annotations

import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _nonneg(x) -> bool:
    return _is_num(x) and x >= 0


def _pos_int(x) -> bool:
    return isinstance(x, int) and not isinstance(x, bool) and x > 0


def _str_list(x) -> bool:
    return isinstance(x, list) and x and all(isinstance(s, str) for s in x)


BATCHED_MATFN_TOP = {
    "benchmark": lambda x: isinstance(x, str) and x,
    "backend": lambda x: isinstance(x, str) and x,
    "prism": lambda x: isinstance(x, dict),
    "dtypes": lambda x: _str_list(x) and "float32" in x and "bfloat16" in x,
    "notes": _str_list,
    "results": lambda x: isinstance(x, list) and x,
    # §11 adaptive early stopping: instance-adaptive iteration counts
    "adaptive": lambda x: isinstance(x, list) and x,
}

ADAPTIVE_ROW = {
    "n": _pos_int,
    "B": _pos_int,
    "tol": lambda x: _is_num(x) and x > 0,
    "iters_budget": _pos_int,
    "iters_mean": lambda x: _is_num(x) and x >= 1,
    "iters_max": _pos_int,
    "iters_mean_ill": lambda x: _is_num(x) and x >= 1,
    "iters_max_ill": _pos_int,
    "resid_max": _nonneg,
    "resid_max_ill": _nonneg,
}


def _check_adaptive_row(row: dict, where: str):
    errs = []
    for field, ok in ADAPTIVE_ROW.items():
        if field not in row:
            errs.append(f"{where}: missing field {field!r}")
        elif not ok(row[field]):
            errs.append(f"{where}: bad value {field}={row[field]!r}")
    if errs:
        return errs
    # §11 invariants.  The headline: at an equal residual target, the
    # well-conditioned bucket's MEAN certified count must sit strictly
    # below the fixed-iters baseline — the count a certificate-free
    # engine provisions, i.e. what the ill-conditioned straggler needed.
    if not row["iters_mean"] < row["iters_max_ill"]:
        errs.append(f"{where}: iters_mean must be strictly below the "
                    f"fixed-iters baseline iters_max_ill "
                    f"({row['iters_mean']} vs {row['iters_max_ill']})")
    if row["iters_mean"] > row["iters_max"]:
        errs.append(f"{where}: iters_mean > iters_max")
    if row["iters_mean_ill"] > row["iters_max_ill"]:
        errs.append(f"{where}: iters_mean_ill > iters_max_ill")
    for f in ("iters_max", "iters_max_ill"):
        if row[f] > row["iters_budget"]:
            errs.append(f"{where}: {f} exceeds the iteration budget")
    # "equal residual targets": both buckets actually met tol (modest
    # slack for the p=8 sketch certificate's variance)
    for f in ("resid_max", "resid_max_ill"):
        if row[f] > 1.5 * row["tol"]:
            errs.append(f"{where}: {f}={row[f]} above the tol target "
                        f"{row['tol']}")
    return errs

BATCHED_MATFN_ROW = {
    "n": _pos_int,
    "B": _pos_int,
    "iterations": _pos_int,
    "per_leaf_ms": _nonneg,
    "bucketed_ms": _nonneg,
    "bucketed_bf16_ms": _nonneg,
    "per_leaf_compile_s": _nonneg,
    "bucketed_compile_s": _nonneg,
    "bucketed_bf16_compile_s": _nonneg,
    "speedup": _nonneg,
    "bf16_speedup": _nonneg,
    "hbm_bytes_fp32": _pos_int,
    "hbm_bytes_bf16": _pos_int,
    # the committed baseline must carry the §7/§9 dispatch contract:
    # regenerating under REPRO_KERNEL_MODE=ref skips launch counting and
    # is rejected here — rerun without it
    "launches_per_leaf": _pos_int,
    "launches_bucketed": _pos_int,
    "launches_bucketed_bf16": _pos_int,
    # §10 fused-iteration tier axis
    "launches_fused": _pos_int,
    "launches_fused_bf16": _pos_int,
    "hbm_bytes_fused_fp32": _pos_int,
    "hbm_bytes_fused_bf16": _pos_int,
    "hbm_bytes_warm_tail_fp32": _pos_int,
    "hbm_bytes_warm_tail_bf16": _pos_int,
    "fused_fits_fp32": lambda x: isinstance(x, bool),
    "fused_fits_bf16": lambda x: isinstance(x, bool),
}


def _check_batched_matfn_row(row: dict, where: str):
    errs = []
    for field, ok in BATCHED_MATFN_ROW.items():
        if field not in row:
            errs.append(f"{where}: missing field {field!r}")
        elif not ok(row[field]):
            errs.append(f"{where}: bad value {field}={row[field]!r}")
    # §9 invariants: bf16 halves HBM bytes, launch counts dtype-blind
    if _is_num(row.get("hbm_bytes_fp32")) and \
            _is_num(row.get("hbm_bytes_bf16")) and \
            row["hbm_bytes_bf16"] * 2 != row["hbm_bytes_fp32"]:
        errs.append(f"{where}: hbm_bytes_bf16 must be half of fp32 "
                    f"({row['hbm_bytes_bf16']} vs {row['hbm_bytes_fp32']})")
    if "launches_bucketed" in row and \
            row.get("launches_bucketed_bf16") != row["launches_bucketed"]:
        errs.append(f"{where}: launch counts are dtype-dependent: "
                    f"{row.get('launches_bucketed_bf16')} != "
                    f"{row['launches_bucketed']}")
    # §10 invariants: the fused tier halves nothing by dtype games — bf16
    # still exactly halves bytes, counts stay dtype-blind, and the fused
    # tier strictly beats the §7 tier on both launches and modeled HBM
    if _is_num(row.get("hbm_bytes_fused_fp32")) and \
            _is_num(row.get("hbm_bytes_fused_bf16")) and \
            row["hbm_bytes_fused_bf16"] * 2 != row["hbm_bytes_fused_fp32"]:
        errs.append(f"{where}: hbm_bytes_fused_bf16 must be half of fp32")
    if "launches_fused" in row and \
            row.get("launches_fused_bf16") != row["launches_fused"]:
        errs.append(f"{where}: fused launch counts are dtype-dependent")
    if _is_num(row.get("launches_fused")) and \
            _is_num(row.get("launches_bucketed")) and \
            not row["launches_fused"] < row["launches_bucketed"]:
        errs.append(f"{where}: launches_fused must beat launches_bucketed "
                    f"({row['launches_fused']} vs "
                    f"{row['launches_bucketed']})")
    if _is_num(row.get("hbm_bytes_fused_fp32")) and \
            _is_num(row.get("hbm_bytes_fp32")) and \
            not row["hbm_bytes_fused_fp32"] < row["hbm_bytes_fp32"]:
        errs.append(f"{where}: hbm_bytes_fused_fp32 must beat the §7 "
                    f"model")
    return errs


def validate_batched_matfn(doc: dict, name: str):
    errs = []
    for field, ok in BATCHED_MATFN_TOP.items():
        if field not in doc:
            errs.append(f"{name}: missing top-level field {field!r}")
        elif not ok(doc[field]):
            errs.append(f"{name}: bad top-level {field}={doc[field]!r}")
    for i, row in enumerate(doc.get("results") or []):
        if not isinstance(row, dict):
            errs.append(f"{name}: results[{i}] is not an object")
            continue
        errs.extend(_check_batched_matfn_row(row, f"{name}: results[{i}]"))
    for i, row in enumerate(doc.get("adaptive") or []):
        if not isinstance(row, dict):
            errs.append(f"{name}: adaptive[{i}] is not an object")
            continue
        errs.extend(_check_adaptive_row(row, f"{name}: adaptive[{i}]"))
    return errs


ASYNC_PRECOND_TOP = {
    "benchmark": lambda x: x == "async_precond",
    "backend": lambda x: isinstance(x, str) and x,
    "period": _pos_int,
    "notes": _str_list,
    "results": lambda x: isinstance(x, list) and x,
    "schedule": lambda x: isinstance(x, dict),
}

ASYNC_PRECOND_ROW = {
    "n": _pos_int,
    "layers": _pos_int,
    "period": _pos_int,
    "steady_ms": _nonneg,
    "refresh_ms": _nonneg,
    "blocking_step_ms": _nonneg,
    "speedup_refresh_step": _nonneg,
    "speedup_amortized": _nonneg,
    # the §12 contract: the async steady step compiles with ZERO matfn
    # launches — all of them live in the refresh program.  Regenerating
    # under REPRO_KERNEL_MODE=ref skips counting and is rejected here.
    "blocking_launches_steady": lambda x: x == 0,
    "launches_refresh": _pos_int,
    "launches_blocking_step": _pos_int,
}

ASYNC_PRECOND_SCHEDULE = {
    "steps": _pos_int,
    "segment": _pos_int,
    "period_clock": _pos_int,
    "ceiling_drift": _pos_int,
    "drift_threshold": lambda x: _is_num(x) and x > 0,
    "refreshes_clock": _pos_int,
    "refreshes_drift": _pos_int,
    "drift_triggered": lambda x: isinstance(x, int) and x >= 0,
    "drift_max_clock": _nonneg,
    "drift_max_drift": _nonneg,
}


def validate_async_precond(doc: dict, name: str):
    errs = []
    for field, ok in ASYNC_PRECOND_TOP.items():
        if field not in doc:
            errs.append(f"{name}: missing top-level field {field!r}")
        elif not ok(doc[field]):
            errs.append(f"{name}: bad top-level {field}={doc[field]!r}")
    for i, row in enumerate(doc.get("results") or []):
        where = f"{name}: results[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} is not an object")
            continue
        for field, ok in ASYNC_PRECOND_ROW.items():
            if field not in row:
                errs.append(f"{where}: missing field {field!r}")
            elif not ok(row[field]):
                errs.append(f"{where}: bad value {field}={row[field]!r}")
    sched = doc.get("schedule")
    if isinstance(sched, dict):
        where = f"{name}: schedule"
        for field, ok in ASYNC_PRECOND_SCHEDULE.items():
            if field not in sched:
                errs.append(f"{where}: missing field {field!r}")
            elif not ok(sched[field]):
                errs.append(f"{where}: bad value "
                            f"{field}={sched[field]!r}")
        if errs:
            return errs
        # the headline claim: at an equal max-staleness residual target
        # (threshold = the clock schedule's realized max drift), the
        # drift trigger must refresh NO MORE often than the fixed clock
        if not sched["refreshes_drift"] <= sched["refreshes_clock"]:
            errs.append(f"{where}: drift schedule refreshed more than "
                        f"the fixed clock ({sched['refreshes_drift']} vs "
                        f"{sched['refreshes_clock']})")
        # "equal residual": the drift run's realized staleness stays
        # within 2x the clock's — the trigger fires the refresh the step
        # drift crosses the threshold, but the swap lands one dispatch-
        # to-swap delay later, so a shift-boundary spike can overshoot
        if sched["drift_max_drift"] > 2.0 * sched["drift_max_clock"]:
            errs.append(f"{where}: drift run's max staleness "
                        f"{sched['drift_max_drift']} above 2x the clock "
                        f"schedule's {sched['drift_max_clock']}")
        if sched["drift_threshold"] > 1.01 * sched["drift_max_clock"]:
            errs.append(f"{where}: threshold not anchored to the clock "
                        f"run's realized max drift")
    return errs


PIPELINE_TRAIN_TOP = {
    "benchmark": lambda x: x == "pipeline_train",
    "backend": lambda x: isinstance(x, str) and x,
    "seq_len": _pos_int,
    "global_batch": _pos_int,
    "notes": _str_list,
    "results": lambda x: isinstance(x, list) and x,
    "launches": lambda x: isinstance(x, list) and x,
}

PIPELINE_TRAIN_ROW = {
    "model": lambda x: isinstance(x, str) and x,
    "stages": lambda x: _pos_int(x) and x > 1,
    "n_micro": _pos_int,
    "seq_len": _pos_int,
    "global_batch": _pos_int,
    "steps": _pos_int,
    "ticks": _pos_int,
    "bubble_fraction": lambda x: _is_num(x) and 0.0 <= x < 1.0,
    "step_s": lambda x: isinstance(x, list) and x and all(
        _is_num(s) and s > 0 for s in x),
    "tokens_per_sec": lambda x: _is_num(x) and x > 0,
    "total_s": lambda x: _is_num(x) and x > 0,
    "losses": lambda x: isinstance(x, list) and x and all(
        _is_num(s) for s in x),
}

PIPELINE_TRAIN_LAUNCH_ROW = {
    "model": lambda x: isinstance(x, str) and x,
    "stages": lambda x: _pos_int(x) and x > 1,
    "n_micro": _pos_int,
    # the §12/§13 composition contract: the steady async pipeline step
    # compiles with ZERO matfn launches — every chain lives in the
    # refresh program dispatched into the 1F1B bubbles.  Regenerating
    # under REPRO_KERNEL_MODE=ref skips counting and is rejected here.
    "steady_matfn_launches": lambda x: x == 0 and not isinstance(x, bool),
    "refresh_matfn_launches": _pos_int,
}


def validate_pipeline_train(doc: dict, name: str):
    errs = []
    for field, ok in PIPELINE_TRAIN_TOP.items():
        if field not in doc:
            errs.append(f"{name}: missing top-level field {field!r}")
        elif not ok(doc[field]):
            errs.append(f"{name}: bad top-level {field}={doc[field]!r}")
    rows = [r for r in (doc.get("results") or []) if isinstance(r, dict)]
    for i, row in enumerate(doc.get("results") or []):
        where = f"{name}: results[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} is not an object")
            continue
        for field, ok in PIPELINE_TRAIN_ROW.items():
            if field not in row:
                errs.append(f"{where}: missing field {field!r}")
            elif not ok(row[field]):
                errs.append(f"{where}: bad value {field}={row[field]!r}")
        # the analytic 1F1B schedule model (DESIGN.md §13)
        if all(_is_num(row.get(k)) for k in ("ticks", "stages", "n_micro",
                                             "bubble_fraction")):
            T = row["n_micro"] + 2 * (row["stages"] - 1)
            if row["ticks"] != T:
                errs.append(f"{where}: ticks != n_micro + 2*(stages-1) "
                            f"({row['ticks']} vs {T})")
            b = 2.0 * (row["stages"] - 1) / T
            if abs(row["bubble_fraction"] - b) > 1e-9:
                errs.append(f"{where}: bubble_fraction off the model "
                            f"({row['bubble_fraction']} vs {b})")
    # the trajectory must cover >= 2 models, and at fixed (model, depth)
    # the bubble fraction must strictly DECREASE in n_micro
    if len({r.get("model") for r in rows}) < 2:
        errs.append(f"{name}: needs >= 2 models in results")
    groups: dict = {}
    for r in rows:
        groups.setdefault((r.get("model"), r.get("stages")), []).append(r)
    for (m, s), rs in groups.items():
        rs = sorted(rs, key=lambda r: r.get("n_micro", 0))
        for a, b in zip(rs, rs[1:]):
            if not (_is_num(a.get("bubble_fraction"))
                    and _is_num(b.get("bubble_fraction"))):
                continue
            if not b["bubble_fraction"] < a["bubble_fraction"]:
                errs.append(f"{name}: bubble_fraction must decrease in "
                            f"n_micro for {m} S={s}")
    lrows = [r for r in (doc.get("launches") or [])
             if isinstance(r, dict)]
    for i, row in enumerate(doc.get("launches") or []):
        where = f"{name}: launches[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} is not an object")
            continue
        for field, ok in PIPELINE_TRAIN_LAUNCH_ROW.items():
            if field not in row:
                errs.append(f"{where}: missing field {field!r}")
            elif not ok(row[field]):
                errs.append(f"{where}: bad value {field}={row[field]!r}")
    if len({r.get("model") for r in lrows}) < 2:
        errs.append(f"{name}: needs the launch contract for >= 2 models")
    return errs


LOWRANK_TOP = {
    "benchmark": lambda x: x == "lowrank",
    "backend": lambda x: isinstance(x, str) and x,
    "rank": _pos_int,
    "oversample": lambda x: isinstance(x, int) and x >= 0,
    "notes": _str_list,
    "results": lambda x: isinstance(x, list) and x,
}

LOWRANK_ROW = {
    "m": _pos_int,
    "n": _pos_int,
    "aspect": _pos_int,
    "l": _pos_int,
    "rank": _pos_int,
    "oversample": lambda x: isinstance(x, int) and x >= 0,
    "power_iters": lambda x: isinstance(x, int) and x >= 0,
    "iters": _pos_int,
    "tol": lambda x: _is_num(x) and x > 0,
    "ortho_err": _nonneg,
    "topk_err": _nonneg,
    "flops_lowrank": _pos_int,
    "flops_cubic": _pos_int,
    "flops_ratio": lambda x: _is_num(x) and x > 0,
    "hbm_lowrank": _pos_int,
    "hbm_cubic": _pos_int,
    "ms_lowrank": _nonneg,
    "ms_cubic": _nonneg,
}


def validate_lowrank(doc: dict, name: str):
    errs = []
    for field, ok in LOWRANK_TOP.items():
        if field not in doc:
            errs.append(f"{name}: missing top-level field {field!r}")
        elif not ok(doc[field]):
            errs.append(f"{name}: bad top-level {field}={doc[field]!r}")
    rows = [r for r in (doc.get("results") or []) if isinstance(r, dict)]
    for i, row in enumerate(doc.get("results") or []):
        where = f"{name}: results[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} is not an object")
            continue
        row_errs = []
        for field, ok in LOWRANK_ROW.items():
            if field not in row:
                row_errs.append(f"{where}: missing field {field!r}")
            elif not ok(row[field]):
                row_errs.append(f"{where}: bad value "
                                f"{field}={row[field]!r}")
        errs.extend(row_errs)
        if row_errs:
            continue
        # §14 accuracy contract: rangefinder orthonormality AND the
        # dominant-subspace oracle error within the stated tol
        for f in ("ortho_err", "topk_err"):
            if row[f] > row["tol"]:
                errs.append(f"{where}: {f}={row[f]} above tol "
                            f"{row['tol']}")
        # the subspace must be strict and the cell geometry consistent
        if row["l"] != row["rank"] + row["oversample"]:
            errs.append(f"{where}: l != rank + oversample")
        if not row["l"] < min(row["m"], row["n"]):
            errs.append(f"{where}: l must be a strict subspace of "
                        f"min(m, n)")
        if row["m"] != row["aspect"] * row["n"]:
            errs.append(f"{where}: m != aspect * n")
        # §14 cost contract: wherever the planner's size/aspect
        # threshold fires (m >= 4n), the modeled FLOPs AND HBM traffic
        # of the sketched path must STRICTLY beat the cubic polar
        if row["m"] >= 4 * row["n"]:
            if not row["flops_lowrank"] < row["flops_cubic"]:
                errs.append(f"{where}: lowrank FLOPs must beat cubic at "
                            f"m >= 4n ({row['flops_lowrank']} vs "
                            f"{row['flops_cubic']})")
            if not row["hbm_lowrank"] < row["hbm_cubic"]:
                errs.append(f"{where}: lowrank HBM must beat cubic at "
                            f"m >= 4n")
    # the sweep must actually cover the claimed regime
    if rows and not any(r.get("m", 0) >= 4 * r.get("n", 1)
                        for r in rows):
        errs.append(f"{name}: sweep has no m >= 4n cell")
    return errs


ROBUSTNESS_TOP = {
    "benchmark": lambda x: x == "robustness",
    "backend": lambda x: isinstance(x, str) and x,
    "period": _pos_int,
    "notes": _str_list,
    "results": lambda x: isinstance(x, list) and x,
    "recovery": lambda x: isinstance(x, dict),
}

ROBUSTNESS_ROW = {
    "n": _pos_int,
    "layers": _pos_int,
    "period": _pos_int,
    "steady_ms_bare": _nonneg,
    "steady_ms_guarded": _nonneg,
    "overhead_pct": _is_num,
    # the committed baseline must carry the §15 launch contract:
    # regenerating under REPRO_KERNEL_MODE=ref skips launch counting and
    # is rejected here — rerun without it
    "steady_matfn_launches_bare": lambda x: isinstance(x, int)
    and not isinstance(x, bool),
    "steady_matfn_launches_guarded": lambda x: isinstance(x, int)
    and not isinstance(x, bool),
    "matfn_launches_plain": _pos_int,
    "matfn_launches_status": _pos_int,
}

ROBUSTNESS_RECOVERY = {
    "steps": _pos_int,
    "injected": _pos_int,
    "bad_steps": lambda x: isinstance(x, int) and not isinstance(x, bool)
    and x >= 0,
    "final_finite": lambda x: isinstance(x, bool),
    "discarded": lambda x: isinstance(x, int) and not isinstance(x, bool)
    and x >= 0,
    "retries": lambda x: isinstance(x, int) and not isinstance(x, bool)
    and x >= 0,
    "degraded": lambda x: isinstance(x, int) and not isinstance(x, bool)
    and x >= 0,
    "recovered_install": lambda x: isinstance(x, bool),
}


def validate_robustness(doc: dict, name: str):
    errs = []
    for field, ok in ROBUSTNESS_TOP.items():
        if field not in doc:
            errs.append(f"{name}: missing top-level field {field!r}")
        elif not ok(doc[field]):
            errs.append(f"{name}: bad top-level {field}={doc[field]!r}")
    for i, row in enumerate(doc.get("results") or []):
        where = f"{name}: results[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} is not an object")
            continue
        row_errs = []
        for field, ok in ROBUSTNESS_ROW.items():
            if field not in row:
                row_errs.append(f"{where}: missing field {field!r}")
            elif not ok(row[field]):
                row_errs.append(f"{where}: bad value "
                                f"{field}={row[field]!r}")
        errs.extend(row_errs)
        if row_errs:
            continue
        # §15 launch contracts.  The guards are selects riding existing
        # chains: the skip-step wrapper must keep the async steady step
        # at the §12 contract's ZERO matfn launches, and the divergence
        # detector's status read must add zero launches to the matfn
        # plan (it is decoded from the certificate the loop computes).
        for f in ("steady_matfn_launches_bare",
                  "steady_matfn_launches_guarded"):
            if row[f] != 0:
                errs.append(f"{where}: {f}={row[f]} — the steady step "
                            f"must stay at zero matfn launches")
        if row["matfn_launches_status"] != row["matfn_launches_plain"]:
            errs.append(f"{where}: status telemetry changed the launch "
                        f"count ({row['matfn_launches_status']} vs "
                        f"{row['matfn_launches_plain']})")
    rec = doc.get("recovery")
    if isinstance(rec, dict):
        where = f"{name}: recovery"
        rec_errs = []
        for field, ok in ROBUSTNESS_RECOVERY.items():
            if field not in rec:
                rec_errs.append(f"{where}: missing field {field!r}")
            elif not ok(rec[field]):
                rec_errs.append(f"{where}: bad value "
                                f"{field}={rec[field]!r}")
        errs.extend(rec_errs)
        if not rec_errs:
            # exact accounting: every injected NaN burst is one skipped
            # step — no false positives, none missed — and the run ends
            # finite; the poisoned refresh stream must walk the full
            # discard -> retry -> degrade ladder and then recover
            if rec["bad_steps"] != rec["injected"]:
                errs.append(f"{where}: bad_steps={rec['bad_steps']} != "
                            f"injected={rec['injected']}")
            if not rec["final_finite"]:
                errs.append(f"{where}: run ended non-finite")
            if not rec["discarded"] >= 1:
                errs.append(f"{where}: poisoned refresh was never "
                            f"discarded")
            if not rec["degraded"] >= 1:
                errs.append(f"{where}: retry ladder never degraded")
            if not rec["recovered_install"]:
                errs.append(f"{where}: no clean install after recovery")
    return errs


SERVING_TOP = {
    "benchmark": lambda x: x == "serving",
    "backend": lambda x: isinstance(x, str) and x,
    "step_dt_ms": lambda x: _is_num(x) and x > 0,
    "notes": _str_list,
    # ≥2 archs: the engine must be proven beyond one attention flavor
    "results": lambda x: isinstance(x, list) and len(x) >= 2,
}

SERVING_ROW = {
    "arch": lambda x: isinstance(x, str) and x,
    "family": lambda x: x in ("dense", "moe"),
    "slots": _pos_int,
    "cache_len": _pos_int,
    "n_requests": _pos_int,
    "step_dt_ms": lambda x: _is_num(x) and x > 0,
    "decode_step_shapes": _pos_int,
    "prefill_launches": _pos_int,
    "qps_points": lambda x: isinstance(x, list) and len(x) >= 3,
    "sat_qps": lambda x: _is_num(x) and x > 0,
    "continuous_tokens_per_s": lambda x: _is_num(x) and x > 0,
    "static_tokens_per_s": lambda x: _is_num(x) and x > 0,
    "decode_ms_per_step_wall": _nonneg,
}

SERVING_POINT = {
    "qps": lambda x: _is_num(x) and x > 0,
    "completed": _pos_int,
    "p50_s": _nonneg,
    "p99_s": _nonneg,
    "tokens_per_s": lambda x: _is_num(x) and x > 0,
    "decode_steps": _pos_int,
    "occupancy_mean": lambda x: _is_num(x) and x >= 1,
    "occupancy_max": _pos_int,
    "occupancy_traj": lambda x: isinstance(x, list) and x
    and all(isinstance(o, int) and o >= 1 for o in x),
}


def validate_serving(doc: dict, name: str):
    errs = []
    for field, ok in SERVING_TOP.items():
        if field not in doc:
            errs.append(f"{name}: missing top-level field {field!r}")
        elif not ok(doc[field]):
            errs.append(f"{name}: bad top-level {field}={doc[field]!r}")
    for i, row in enumerate(doc.get("results") or []):
        where = f"{name}: results[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} is not an object")
            continue
        row_errs = []
        for field, ok in SERVING_ROW.items():
            if field not in row:
                row_errs.append(f"{where}: missing field {field!r}")
            elif not ok(row[field]):
                row_errs.append(f"{where}: bad value "
                                f"{field}={row[field]!r}")
        errs.extend(row_errs)
        if row_errs:
            continue
        # §16 jit-cache contract: the slot table never changes shape, so
        # the decode step compiles at most 2 shapes across a whole run
        if row["decode_step_shapes"] > 2:
            errs.append(f"{where}: decode step compiled "
                        f"{row['decode_step_shapes']} shapes (> 2)")
        # §16 engine contract: on a mixed-length seeded trace at
        # saturating load, continuous admission strictly out-runs
        # static (admit-only-when-drained) batching
        if not row["continuous_tokens_per_s"] > row["static_tokens_per_s"]:
            errs.append(
                f"{where}: continuous batching does not beat static "
                f"({row['continuous_tokens_per_s']} vs "
                f"{row['static_tokens_per_s']} tok/s)")
        for j, pt in enumerate(row["qps_points"]):
            pw = f"{where}: qps_points[{j}]"
            pt_errs = []
            for field, ok in SERVING_POINT.items():
                if field not in pt:
                    pt_errs.append(f"{pw}: missing field {field!r}")
                elif not ok(pt[field]):
                    pt_errs.append(f"{pw}: bad value "
                                   f"{field}={pt[field]!r}")
            errs.extend(pt_errs)
            if pt_errs:
                continue
            if pt["p50_s"] > pt["p99_s"]:
                errs.append(f"{pw}: p50 {pt['p50_s']} > p99 "
                            f"{pt['p99_s']}")
            if pt["completed"] != row["n_requests"]:
                errs.append(f"{pw}: completed {pt['completed']} != "
                            f"offered {row['n_requests']}")
            if pt["occupancy_max"] > row["slots"]:
                errs.append(f"{pw}: occupancy {pt['occupancy_max']} "
                            f"exceeds the slot table ({row['slots']})")
    return errs


VALIDATORS = {
    "BENCH_batched_matfn.json": validate_batched_matfn,
    "BENCH_async_precond.json": validate_async_precond,
    "BENCH_pipeline_train.json": validate_pipeline_train,
    "BENCH_lowrank.json": validate_lowrank,
    "BENCH_robustness.json": validate_robustness,
    "BENCH_serving.json": validate_serving,
}


def main() -> int:
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        print("validate_bench: no BENCH_*.json baselines found", flush=True)
        return 1
    errs = []
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            errs.append(f"{name}: unreadable JSON: {e}")
            continue
        validator = VALIDATORS.get(name)
        if validator is None:
            # unknown baselines must at least be well-formed objects
            if not isinstance(doc, dict) or "results" not in doc:
                errs.append(f"{name}: no schema registered and not a "
                            "results document")
            else:
                print(f"validate_bench: {name} OK (generic)", flush=True)
            continue
        file_errs = validator(doc, name)
        if file_errs:
            errs.extend(file_errs)
        else:
            print(f"validate_bench: {name} OK", flush=True)
    for e in errs:
        print(f"validate_bench: ERROR {e}", flush=True)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
