"""Shared benchmark utilities: wall-clock timing + GEMM/FLOP accounting.

This container is CPU-only, so each benchmark reports BOTH:
  * wall-clock per call (honest CPU number, jit-warmed, blocked), and
  * algorithmic quantities that transfer to accelerators — iteration
    counts to tolerance and GEMM-FLOPs to tolerance (the paper's own
    speed metric is GPU time, which is GEMM-dominated; FLOPs-to-converge
    is the hardware-independent version of the same comparison).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict

import jax
import numpy as np

RESULTS = []


def smoke() -> bool:
    """True under ``benchmarks/run.py --smoke`` (REPRO_SMOKE=1): every
    registered benchmark must still EXECUTE, emitting a SUBSET of its
    full-run CSV rows (same names, shrunk sweeps/step counts) so the
    whole suite fits a CI smoke budget and rows stay comparable to
    committed baselines."""
    return os.environ.get("REPRO_SMOKE", "") == "1"


def pick(full, small):
    """``full`` normally, ``small`` under smoke (sweep lists, steps)."""
    return small if smoke() else full


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, **derived):
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    line = f"{name},{us_per_call:.1f},{kv}"
    RESULTS.append(line)
    print(line, flush=True)


def iters_to_tol(residuals, n: int, tol: float = 1e-3) -> int:
    r = np.asarray(residuals, dtype=np.float64) / np.sqrt(n)
    hit = np.nonzero(r < tol)[0]
    return int(hit[0]) + 1 if hit.size else len(r)


# GEMM-FLOP models per iteration (m x n input, n <= m), fp accounting
def flops_per_iter(method: str, m: int, n: int, sketch_dim: int = 8,
                   degree: int = 2) -> float:
    """Polar-factor iteration cost: all methods are 3 GEMMs of ~2mn^2;
    PRISM adds the sketched trace chain (4d+2 products of n x n @ n x p)
    and PolarExpress is identical to classical NS-5 in structure."""
    base = 3 * 2.0 * m * n * n
    if method == "prism":
        base += (4 * degree + 2) * 2.0 * n * n * sketch_dim
    return base
