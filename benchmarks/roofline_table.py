"""§Roofline table: read results/dryrun/*.json and print one CSV row per
(arch x shape x mesh) cell with the three roofline terms."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(out_dir: str = "results/dryrun"):
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    if not files:
        print(f"(no dry-run records in {out_dir}; run "
              f"`python -m repro.launch.dryrun --all --both_meshes` first)")
        return
    for f in files:
        rec = json.load(open(f))
        tag = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if "error" in rec:
            emit(tag, 0.0, status="FAIL", error=rec["error"][:80])
            continue
        r = rec["roofline"]
        emit(tag, rec["compile_s"] * 1e6,
             compute_s=f"{r['compute_s']:.3f}",
             memory_s=f"{r['memory_s']:.3f}",
             collective_s=f"{r['collective_s']:.3f}",
             dominant=r["dominant"],
             useful_flops=f"{r['useful_flops_fraction']:.3f}",
             roofline=f"{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    run()
