"""Trainer integration: loss goes down, checkpoints resume exactly,
straggler accounting works."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import OptimizerConfig, PrismConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.models import build
from repro.train import Trainer

OCFG = OptimizerConfig(name="muon", learning_rate=0.02,
                       prism=PrismConfig(degree=2, iterations=3,
                                         warm_alpha_iters=3, sketch_dim=8))


def _mk(tmp_path, steps=8, every=4):
    cfg = get_smoke_config("gpt2-paper")
    model = build(cfg)
    tcfg = TrainConfig(steps=steps, checkpoint_dir=str(tmp_path),
                       checkpoint_every=every, log_every=100,
                       async_checkpoint=False)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                      markov_rank=8)
    return Trainer(model, OCFG, tcfg, dcfg)


def test_train_reduces_loss_and_checkpoints(tmp_path):
    tr = _mk(tmp_path, steps=10, every=5)
    _, _, losses = tr.run()
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert os.path.exists(tmp_path / "step_00000010")
    assert os.path.exists(tmp_path / "HEARTBEAT")


def test_resume_is_exact(tmp_path):
    # run 1: steps 0..5 with a checkpoint at 4
    tr1 = _mk(tmp_path / "a", steps=6, every=4)
    p1, o1, l1 = tr1.run()
    # run 2: same config, interrupted at step 4, then resumed to 6
    tr2 = _mk(tmp_path / "b", steps=4, every=4)
    tr2.run()
    tr3 = _mk(tmp_path / "b", steps=6, every=4)
    p3, o3, l3 = tr3.run()
    # identical final params: deterministic data + exact state restore
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_elastic_restore_to_sharded(tmp_path):
    """Checkpoint restores onto explicit shardings (device_put path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import checkpoint as ckpt

    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 3, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, restored = ckpt.restore(str(tmp_path), tree, shardings=sh)
    assert step == 3
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
