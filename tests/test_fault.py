"""Watchdog + int8-psum shard_map collective tests."""
import os
import subprocess
import sys
import textwrap
import time

from repro.train.fault import Watchdog, WatchdogConfig, latest_restart_point


def test_watchdog_states(tmp_path):
    hb = tmp_path / "HEARTBEAT"
    wd = Watchdog(str(hb), WatchdogConfig(stale_after_s=10))
    assert wd.check() == "missing"
    hb.write_text(f"5 {time.time()}")
    assert wd.check() == "ok"
    assert not wd.should_restart()
    # stale heartbeat
    hb.write_text(f"6 {time.time() - 100}")
    assert wd.check() == "stale"
    assert wd.should_restart()
    # regression (restarted host reports older step while we expect newer)
    hb.write_text(f"2 {time.time()}")
    wd2 = Watchdog(str(hb), WatchdogConfig(stale_after_s=1000))
    wd2.last_step = 6
    assert wd2.check() == "regressed"


def test_latest_restart_point(tmp_path):
    import jax.numpy as jnp

    from repro import checkpoint as ckpt

    assert latest_restart_point(str(tmp_path / "nope")) is None
    ckpt.save(str(tmp_path), 7, {"x": jnp.ones(2)})
    os.makedirs(tmp_path / "step_00000009.tmp")  # crashed write
    assert latest_restart_point(str(tmp_path)) == 7


INT8_PSUM_SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import int8_psum

    from repro.launch.mesh import compat_make_mesh
    from repro.sharding_ctx import compat_shard_map

    mesh = compat_make_mesh((4,), ("pod",))
    x = jnp.asarray(np.random.RandomState(0).randn(4, 1000), jnp.float32)

    f = compat_shard_map(lambda a: int8_psum(a[0], "pod"), mesh=mesh,
                         in_specs=P("pod"), out_specs=P())
    with mesh:
        got = f(x)
    want = np.sum(np.asarray(x), axis=0)
    err = np.abs(np.asarray(got) - want)
    # blockwise int8: error bounded by sum of per-shard quant steps
    bound = 4 * np.abs(x).max() / 127 + 1e-5
    assert err.max() <= bound, (err.max(), bound)
    print("INT8_PSUM_OK", err.max())
""")


def test_int8_psum_shard_map():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", INT8_PSUM_SCRIPT],
                         cwd="/root/repo", env=env, capture_output=True,
                         text=True, timeout=300)
    assert "INT8_PSUM_OK" in out.stdout, out.stdout + out.stderr[-2000:]
