"""Watchdog, int8-psum collective, and the kill/resume pipeline drill."""
import os
import signal
import subprocess
import sys
import textwrap
import time

from repro.train.fault import Watchdog, WatchdogConfig, latest_restart_point


def test_watchdog_states(tmp_path):
    hb = tmp_path / "HEARTBEAT"
    wd = Watchdog(str(hb), WatchdogConfig(stale_after_s=10))
    assert wd.check() == "missing"
    hb.write_text(f"5 {time.time()}")
    assert wd.check() == "ok"
    assert not wd.should_restart()
    # stale heartbeat
    hb.write_text(f"6 {time.time() - 100}")
    assert wd.check() == "stale"
    assert wd.should_restart()
    # regression (restarted host reports older step while we expect newer)
    hb.write_text(f"2 {time.time()}")
    wd2 = Watchdog(str(hb), WatchdogConfig(stale_after_s=1000))
    wd2.last_step = 6
    assert wd2.check() == "regressed"


def test_watchdog_config_not_shared(tmp_path):
    """Regression: the default WatchdogConfig must be per-instance — a
    dataclass default instance shared across watchdogs would let one
    watchdog's threshold tweak leak into every other."""
    a = Watchdog(str(tmp_path / "a"))
    b = Watchdog(str(tmp_path / "b"))
    assert a.cfg is not b.cfg
    a.cfg.stale_after_s = 1.0
    assert b.cfg.stale_after_s == WatchdogConfig().stale_after_s


def test_latest_restart_point(tmp_path):
    import jax.numpy as jnp

    from repro import checkpoint as ckpt

    assert latest_restart_point(str(tmp_path / "nope")) is None
    ckpt.save(str(tmp_path), 7, {"x": jnp.ones(2)})
    os.makedirs(tmp_path / "step_00000009.tmp")  # crashed write
    assert latest_restart_point(str(tmp_path)) == 7


INT8_PSUM_SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import int8_psum

    from repro.launch.mesh import compat_make_mesh
    from repro.sharding_ctx import compat_shard_map

    mesh = compat_make_mesh((4,), ("pod",))
    x = jnp.asarray(np.random.RandomState(0).randn(4, 1000), jnp.float32)

    f = compat_shard_map(lambda a: int8_psum(a[0], "pod"), mesh=mesh,
                         in_specs=P("pod"), out_specs=P())
    with mesh:
        got = f(x)
    want = np.sum(np.asarray(x), axis=0)
    err = np.abs(np.asarray(got) - want)
    # blockwise int8: error bounded by sum of per-shard quant steps
    bound = 4 * np.abs(x).max() / 127 + 1e-5
    assert err.max() <= bound, (err.max(), bound)
    print("INT8_PSUM_OK", err.max())
""")


def test_int8_psum_shard_map():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", INT8_PSUM_SCRIPT],
                         cwd="/root/repo", env=env, capture_output=True,
                         text=True, timeout=300)
    assert "INT8_PSUM_OK" in out.stdout, out.stdout + out.stderr[-2000:]


# ------------------------------------------- async refresh plane (§12)


def test_restore_discards_inflight_pending(tmp_path):
    """A checkpoint written mid-interval (refresh dispatched, swap not
    yet due) excludes the pending payloads; the restore keeps the
    init-state zeros for them and discard_inflight clears pending_at —
    so the resumed run never swaps in a buffer it did not dispatch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import checkpoint as ckpt
    from repro.config import OptimizerConfig, PrismConfig
    from repro.optim import base, make_optimizer
    from repro.train.fault import discard_inflight

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (48, 32))}
    axes = {"w": ("embed", "mlp")}
    cfg = OptimizerConfig(name="muon", learning_rate=0.05,
                          precond_every=4, precond_async=True,
                          precond_swap_delay=2,
                          prism=PrismConfig(degree=2, iterations=3,
                                            warm_alpha_iters=1,
                                            sketch_dim=8))
    opt = make_optimizer(cfg, axes)
    p, s = params, opt.init(params)
    # two steps, then a refresh dispatched at t=2 whose swap is due at
    # t=4 — checkpoint lands mid-interval at t=3
    for t in range(3):
        if t == 2:
            s = base.install_pending(s, opt.refresh(s, key), at_step=2)
        g = jax.tree.map(lambda q: 0.1 * jnp.ones_like(q), params)
        p, s = opt.update(g, s, p, t, jax.random.fold_in(key, t),
                          refresh=False)
    assert int(s["pending_at"]) == 2
    ckpt.save(str(tmp_path), 3, {"opt": s}, drop=base.PENDING_STATE_KEYS)
    # on-disk file really excludes every pending payload
    data = np.load(tmp_path / "step_00000003" / "tree.npz")
    assert not any(base.PENDING_STATE_KEYS.intersection(k.split("|"))
                   for k in data.files)
    # restore into a fresh init target; pending keys fall back to zeros
    target = {"opt": opt.init(params)}
    _, restored = ckpt.restore(str(tmp_path), target,
                               allow_missing=base.PENDING_STATE_KEYS)
    rs = discard_inflight(restored["opt"])
    assert int(rs["pending_at"]) == base.NO_PENDING
    slot = base._flat_slots(rs["leaves"])[0][0]
    np.testing.assert_array_equal(np.asarray(slot["ortho_p"], np.float32),
                                  0.0)
    # non-pending state round-trips exactly (mom, active cache, count)
    orig = base._flat_slots(s["leaves"])[0][0]
    np.testing.assert_array_equal(np.asarray(slot["mom"]),
                                  np.asarray(orig["mom"]))
    np.testing.assert_array_equal(np.asarray(slot["ortho"]),
                                  np.asarray(orig["ortho"]))
    # the resumed run never consumes the zeroed pending buffer: the swap
    # cond stays untaken on the very step the old swap was due
    p2, s2 = opt.update(jax.tree.map(lambda q: 0.1 * jnp.ones_like(q),
                                     params),
                        rs, params, 4, jax.random.fold_in(key, 4),
                        refresh=False)
    assert int(s2["pending_at"]) == base.NO_PENDING
    slot2 = base._flat_slots(s2["leaves"])[0][0]
    np.testing.assert_array_equal(np.asarray(slot2["ortho"]),
                                  np.asarray(orig["ortho"]))


# --------------------------------------- kill/resume pipeline drill (§13)


def _drill_env():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["REPRO_KERNEL_MODE"] = "ref"
    env["PYTHONPATH"] = "src"
    return env


def _drill_cmd(ckpt_dir, steps, extra=()):
    return [sys.executable, "-m", "repro.train.fault",
            "--ckpt_dir", str(ckpt_dir), "--steps", str(steps),
            "--ckpt_every", "2", *extra]


def _losses(stdout):
    """{step: hex_loss} from DRILL_LOSS lines."""
    out = {}
    for line in stdout.splitlines():
        if line.startswith("DRILL_LOSS "):
            _, t, h = line.split()
            out[int(t)] = h
    return out


def _kill_after_checkpoint(ckpt_dir, proc, min_step=2, timeout_s=420):
    """Poll the drill's heartbeat until a complete checkpoint >= min_step
    exists AND the run has moved past it, then SIGKILL mid-step."""
    wd = Watchdog(os.path.join(str(ckpt_dir), "HEARTBEAT"))
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        assert proc.poll() is None, \
            "drill exited before the kill: " + proc.stdout.read()
        hb = wd.read()
        if hb is not None and hb[0] >= min_step and \
                (latest_restart_point(str(ckpt_dir)) or 0) >= min_step:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=30)
            return
        time.sleep(0.2)
    proc.kill()
    raise AssertionError("drill never reached a killable checkpoint: "
                         + proc.stdout.read())


def test_kill_resume_bitwise(tmp_path):
    """The tentpole drill: a pipeline training subprocess on the 8-device
    (pod=2, data=2, model=2) host mesh is SIGKILLed mid-run; the relaunch
    resumes from the newest complete checkpoint and its per-step losses
    continue BITWISE (hex-compared) against an uninterrupted run — sync
    preconditioners make resume exactly deterministic."""
    steps = 5
    ref_dir, kill_dir = tmp_path / "ref", tmp_path / "kill"
    ref = subprocess.run(_drill_cmd(ref_dir, steps), env=_drill_env(),
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=560)
    ref_losses = _losses(ref.stdout)
    assert sorted(ref_losses) == list(range(steps)), \
        ref.stdout + ref.stderr[-4000:]

    proc = subprocess.Popen(_drill_cmd(kill_dir, steps), env=_drill_env(),
                            cwd="/root/repo", stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    _kill_after_checkpoint(kill_dir, proc)
    pre = _losses(proc.stdout.read())
    # the pre-kill prefix already matches the reference bitwise
    for t, h in pre.items():
        assert h == ref_losses[t], (t, h, ref_losses[t])

    resumed = subprocess.run(_drill_cmd(kill_dir, steps),
                             env=_drill_env(), cwd="/root/repo",
                             capture_output=True, text=True, timeout=560)
    assert "resumed from step" in resumed.stdout, \
        resumed.stdout + resumed.stderr[-4000:]
    post = _losses(resumed.stdout)
    assert post, resumed.stdout
    assert min(post) >= 2  # restarted from a checkpoint, not from scratch
    for t, h in post.items():
        assert h == ref_losses[t], (t, h, ref_losses[t])
    assert max(post) == steps - 1
    # per-stage heartbeats carry the same Watchdog-parseable contract
    for s in range(2):
        hb = Watchdog(str(kill_dir / f"HEARTBEAT.stage{s}")).read()
        assert hb is not None and hb[0] == steps - 1, (s, hb)


def test_kill_resume_async_staleness_reset(tmp_path):
    """Async-precond variant: resume is NOT bitwise (the documented
    staleness reset, DESIGN.md §12/§13) — instead the relaunch must
    resume from a checkpoint, re-bootstrap the refresh plane via
    discard_inflight, and finish with finite losses."""
    import json
    import math

    steps = 4
    d = tmp_path / "drill"
    proc = subprocess.Popen(
        _drill_cmd(d, steps, extra=("--async_precond",)),
        env=_drill_env(), cwd="/root/repo", stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    _kill_after_checkpoint(d, proc)
    resumed = subprocess.run(
        _drill_cmd(d, steps, extra=("--async_precond",)),
        env=_drill_env(), cwd="/root/repo", capture_output=True,
        text=True, timeout=560)
    assert "resumed from step" in resumed.stdout, \
        resumed.stdout + resumed.stderr[-4000:]
    post = _losses(resumed.stdout)
    assert post and max(post) == steps - 1, resumed.stdout
    assert all(math.isfinite(float.fromhex(h)) for h in post.values())
    done = [line for line in resumed.stdout.splitlines()
            if line.startswith("DRILL_DONE ")]
    telemetry = json.loads(done[0][len("DRILL_DONE "):])
    # the resumed service re-bootstrapped (never consumed stale pendings)
    assert telemetry["bootstrap"] >= 1, telemetry
