"""Watchdog + int8-psum shard_map collective tests."""
import os
import subprocess
import sys
import textwrap
import time

from repro.train.fault import Watchdog, WatchdogConfig, latest_restart_point


def test_watchdog_states(tmp_path):
    hb = tmp_path / "HEARTBEAT"
    wd = Watchdog(str(hb), WatchdogConfig(stale_after_s=10))
    assert wd.check() == "missing"
    hb.write_text(f"5 {time.time()}")
    assert wd.check() == "ok"
    assert not wd.should_restart()
    # stale heartbeat
    hb.write_text(f"6 {time.time() - 100}")
    assert wd.check() == "stale"
    assert wd.should_restart()
    # regression (restarted host reports older step while we expect newer)
    hb.write_text(f"2 {time.time()}")
    wd2 = Watchdog(str(hb), WatchdogConfig(stale_after_s=1000))
    wd2.last_step = 6
    assert wd2.check() == "regressed"


def test_latest_restart_point(tmp_path):
    import jax.numpy as jnp

    from repro import checkpoint as ckpt

    assert latest_restart_point(str(tmp_path / "nope")) is None
    ckpt.save(str(tmp_path), 7, {"x": jnp.ones(2)})
    os.makedirs(tmp_path / "step_00000009.tmp")  # crashed write
    assert latest_restart_point(str(tmp_path)) == 7


INT8_PSUM_SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import int8_psum

    from repro.launch.mesh import compat_make_mesh
    from repro.sharding_ctx import compat_shard_map

    mesh = compat_make_mesh((4,), ("pod",))
    x = jnp.asarray(np.random.RandomState(0).randn(4, 1000), jnp.float32)

    f = compat_shard_map(lambda a: int8_psum(a[0], "pod"), mesh=mesh,
                         in_specs=P("pod"), out_specs=P())
    with mesh:
        got = f(x)
    want = np.sum(np.asarray(x), axis=0)
    err = np.abs(np.asarray(got) - want)
    # blockwise int8: error bounded by sum of per-shard quant steps
    bound = 4 * np.abs(x).max() / 127 + 1e-5
    assert err.max() <= bound, (err.max(), bound)
    print("INT8_PSUM_OK", err.max())
""")


def test_int8_psum_shard_map():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", INT8_PSUM_SCRIPT],
                         cwd="/root/repo", env=env, capture_output=True,
                         text=True, timeout=300)
    assert "INT8_PSUM_OK" in out.stdout, out.stdout + out.stderr[-2000:]


# ------------------------------------------- async refresh plane (§12)


def test_restore_discards_inflight_pending(tmp_path):
    """A checkpoint written mid-interval (refresh dispatched, swap not
    yet due) excludes the pending payloads; the restore keeps the
    init-state zeros for them and discard_inflight clears pending_at —
    so the resumed run never swaps in a buffer it did not dispatch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import checkpoint as ckpt
    from repro.config import OptimizerConfig, PrismConfig
    from repro.optim import base, make_optimizer
    from repro.train.fault import discard_inflight

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (48, 32))}
    axes = {"w": ("embed", "mlp")}
    cfg = OptimizerConfig(name="muon", learning_rate=0.05,
                          precond_every=4, precond_async=True,
                          precond_swap_delay=2,
                          prism=PrismConfig(degree=2, iterations=3,
                                            warm_alpha_iters=1,
                                            sketch_dim=8))
    opt = make_optimizer(cfg, axes)
    p, s = params, opt.init(params)
    # two steps, then a refresh dispatched at t=2 whose swap is due at
    # t=4 — checkpoint lands mid-interval at t=3
    for t in range(3):
        if t == 2:
            s = base.install_pending(s, opt.refresh(s, key), at_step=2)
        g = jax.tree.map(lambda q: 0.1 * jnp.ones_like(q), params)
        p, s = opt.update(g, s, p, t, jax.random.fold_in(key, t),
                          refresh=False)
    assert int(s["pending_at"]) == 2
    ckpt.save(str(tmp_path), 3, {"opt": s}, drop=base.PENDING_STATE_KEYS)
    # on-disk file really excludes every pending payload
    data = np.load(tmp_path / "step_00000003" / "tree.npz")
    assert not any(base.PENDING_STATE_KEYS.intersection(k.split("|"))
                   for k in data.files)
    # restore into a fresh init target; pending keys fall back to zeros
    target = {"opt": opt.init(params)}
    _, restored = ckpt.restore(str(tmp_path), target,
                               allow_missing=base.PENDING_STATE_KEYS)
    rs = discard_inflight(restored["opt"])
    assert int(rs["pending_at"]) == base.NO_PENDING
    slot = base._flat_slots(rs["leaves"])[0][0]
    np.testing.assert_array_equal(np.asarray(slot["ortho_p"], np.float32),
                                  0.0)
    # non-pending state round-trips exactly (mom, active cache, count)
    orig = base._flat_slots(s["leaves"])[0][0]
    np.testing.assert_array_equal(np.asarray(slot["mom"]),
                                  np.asarray(orig["mom"]))
    np.testing.assert_array_equal(np.asarray(slot["ortho"]),
                                  np.asarray(orig["ortho"]))
    # the resumed run never consumes the zeroed pending buffer: the swap
    # cond stays untaken on the very step the old swap was due
    p2, s2 = opt.update(jax.tree.map(lambda q: 0.1 * jnp.ones_like(q),
                                     params),
                        rs, params, 4, jax.random.fold_in(key, 4),
                        refresh=False)
    assert int(s2["pending_at"]) == base.NO_PENDING
    slot2 = base._flat_slots(s2["leaves"])[0][0]
    np.testing.assert_array_equal(np.asarray(slot2["ortho"]),
                                  np.asarray(orig["ortho"]))
