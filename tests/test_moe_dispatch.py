"""Per-sample MoE dispatch must match global dispatch when drop-free."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import moe as moe_lib


def test_per_sample_matches_global_dropfree(key):
    cfg = get_smoke_config("mixtral-8x7b").replace(dtype="float32",
                                                   emb_dtype="float32")
    # drop-free capacities on both paths
    m_global = dataclasses.replace(cfg.moe, dispatch="global",
                                   capacity_factor=float(cfg.moe.num_experts))
    m_local = dataclasses.replace(cfg.moe, dispatch="per_sample",
                                  capacity_factor=float(cfg.moe.num_experts))
    params = moe_lib.init_moe(key, cfg.replace(moe=m_global))
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, cfg.d_model))
    yg, auxg = moe_lib.moe_ffn(params, x, cfg.replace(moe=m_global))
    yl, auxl = moe_lib.moe_ffn(params, x, cfg.replace(moe=m_local))
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yl),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(auxg), float(auxl), rtol=1e-5)


def test_per_sample_capacity_drops_are_per_sample(key):
    """With tiny capacity, drops happen independently per sample."""
    cfg = get_smoke_config("granite-moe-1b-a400m").replace(
        dtype="float32", emb_dtype="float32")
    m_local = dataclasses.replace(cfg.moe, dispatch="per_sample",
                                  capacity_factor=0.5)
    params = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 32, cfg.d_model))
    y, aux = moe_lib.moe_ffn(params, x, cfg.replace(moe=m_local))
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
