"""Multi-device integration: the sharded train step EXECUTES on an 8-way
CPU mesh (both sharding strategies), and checkpoints restore elastically
onto a different mesh shape.  Subprocess keeps the main test world
single-device."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.config import OptimizerConfig, PrismConfig
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, make_batch_fn
    from repro.launch import sharding as sh
    from repro.models import build
    from repro.optim import make_optimizer
    from repro.sharding_ctx import activation_sharding
    from repro.train.state import make_train_step, master_params, \\
        opt_state_shardings
    from repro import checkpoint as ckpt

    def run_steps(mesh_shape, strategy, ckpt_dir, resume, grads_dtype):
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh(mesh_shape, ("data", "model"))
        cfg = get_smoke_config("qwen3-14b").replace(
            d_model=64, num_heads=4, num_kv_heads=2, head_dim=16)
        model = build(cfg)
        ocfg = OptimizerConfig(name="muon", learning_rate=0.02,
                               grads_dtype=grads_dtype,
                               muon_local_reshard=(strategy == "zero"),
                               prism=PrismConfig(degree=2, iterations=2,
                                                 warm_alpha_iters=1,
                                                 sketch_dim=4))
        opt = make_optimizer(ocfg, model.logical_axes())
        rules = sh.param_rules(cfg, mesh, strategy)
        pshapes = model.param_shapes()
        pshard = sh.tree_shardings(mesh, model.logical_axes(), rules,
                                   pshapes)
        master_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
        sshard = opt_state_shardings(mesh, opt, master_shapes, pshard)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, markov_rank=8)
        batch_fn = make_batch_fn(cfg, dcfg)
        with mesh, activation_sharding(
                mesh, sh.activation_rules(cfg, mesh, strategy)):
            step = jax.jit(make_train_step(model, opt, ocfg),
                           in_shardings=(pshard, sshard, None, None),
                           out_shardings=(pshard, sshard, None))
            params = master_params(model.init(jax.random.PRNGKey(0)))
            params = jax.device_put(params, pshard)
            state = opt.init(params)
            start = 0
            if resume:
                s0, restored = ckpt.restore(
                    ckpt_dir, {"params": params, "opt": state},
                    shardings={"params": pshard, "opt": sshard})
                params, state, start = (restored["params"],
                                        restored["opt"], s0)
            losses = []
            for t in range(start, start + 3):
                params, state, metrics = step(params, state,
                                              batch_fn(jnp.asarray(t)),
                                              jnp.asarray(t, jnp.int32))
                losses.append(float(metrics["loss"]))
            if ckpt_dir and not resume:
                ckpt.save(ckpt_dir, start + 3,
                          {"params": params, "opt": state})
            return losses

    l1 = run_steps((2, 4), "tp", "/tmp/elastic_ck", False, "float32")
    assert all(np.isfinite(l1)), l1
    l2 = run_steps((4, 2), "zero", "/tmp/elastic_ck", True, "bfloat16")
    assert all(np.isfinite(l2)), l2
    assert l2[-1] < l1[0], (l1, l2)  # resumed training keeps improving
    print("SHARDED_TRAIN_OK", l1, l2)
""")


def test_sharded_train_and_elastic_resume():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd="/root/repo",
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert "SHARDED_TRAIN_OK" in out.stdout, out.stdout[-2000:] \
        + out.stderr[-3000:]
