"""Convergence-behavior tests: the paper's Theorems 1-2 and headline claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import PrismConfig
from repro.core import matfn
from repro.core import newton_schulz as ns
from repro.core import random_matrices as rm


def _iters_to_tol(residuals, n, tol=1e-3):
    r = np.asarray(residuals) / np.sqrt(n)
    hit = np.nonzero(r < tol)[0]
    return int(hit[0]) if hit.size else len(r)


def test_theorem1_rate_d1_exact_fit(key):
    """Thm 1: d=1, exact fit, ||R_k||_2 <= ||R_0||_2^(2^(k-2))."""
    A = rm.spd_with_eigs(key, 48, jnp.linspace(0.3, 0.999, 48))
    A = A / jnp.linalg.norm(A, 2)  # ||A||_2 <= 1, A symmetric => A^2 symmetric
    cfg = PrismConfig(degree=1, sketch_dim=0)
    X, info = matfn.signm(A, method="prism", cfg=cfg, iters=10,
                          return_info=True)
    # spectral norms of residuals
    r0 = float(jnp.linalg.norm(jnp.eye(48) - A @ A, 2))
    Xk = A
    # recompute residual spectral norms along the trajectory via the info's
    # Frobenius proxy: Frobenius upper-bounds spectral, so the bound in
    # Frobenius/sqrt(n)-form is implied if we allow the sqrt(n) slack.
    rF = np.asarray(info.residual_fro)
    for k in range(2, len(rF)):
        bound = r0 ** (2 ** (k - 2))
        assert rF[k] / np.sqrt(48) <= max(bound, 5e-5) * 1.5, (k, rF[k], bound)


def test_alphas_stay_in_bounds(key):
    for d, (lo, hi) in [(1, (0.5, 1.0)), (2, (3 / 8, 29 / 20))]:
        cfg = PrismConfig(degree=d, sketch_dim=8)
        A = rm.log_uniform_spectrum(key, 64, 64, 1e-6)
        _, info = matfn.polar(A, method="prism", cfg=cfg, key=key, iters=15,
                              return_info=True)
        al = np.asarray(info.alphas)
        assert np.all(al >= lo - 1e-5) and np.all(al <= hi + 1e-5)


@pytest.mark.parametrize("smin", [1e-2, 1e-4, 1e-6, 1e-8])
def test_prism_at_least_as_fast_as_classical(key, smin):
    """The paper's headline: PRISM never slower than classical NS
    (iteration count to fixed tolerance), across spectral ranges."""
    A = rm.log_uniform_spectrum(key, 128, 128, smin)
    cfg = PrismConfig(degree=2, sketch_dim=8)
    _, info_p = matfn.polar(A, method="prism", cfg=cfg, key=key, iters=40,
                            return_info=True)
    _, info_c = matfn.polar(A, method="newton_schulz", cfg=cfg, iters=40,
                            return_info=True)
    it_p = _iters_to_tol(info_p.residual_fro, 128)
    it_c = _iters_to_tol(info_c.residual_fro, 128)
    assert it_p <= it_c, (it_p, it_c)


def test_prism_robust_to_sigma_min_mismatch(key):
    """Fig. 1: PolarExpress (tuned for 1e-3) degrades for much smaller
    sigma_min; PRISM keeps converging fast without knowing sigma_min."""
    A = rm.log_uniform_spectrum(key, 128, 128, 1e-9)
    cfg = PrismConfig(degree=2, sketch_dim=8)
    _, info_p = matfn.polar(A, method="prism", cfg=cfg, key=key, iters=40,
                            return_info=True)
    _, fros_pe = matfn.polar(A, method="polar_express", iters=40,
                             return_info=True)
    it_p = _iters_to_tol(info_p.residual_fro, 128)
    it_pe = _iters_to_tol(fros_pe, 128)
    assert it_p <= it_pe, (it_p, it_pe)


def test_sketched_matches_exact_fit_rate(key):
    """Thm 2 in practice: p=8 sketch converges ~as fast as the exact fit."""
    A = rm.log_uniform_spectrum(key, 256, 256, 1e-6)
    cfg_s = PrismConfig(degree=2, sketch_dim=8)
    cfg_e = PrismConfig(degree=2, sketch_dim=0)
    _, info_s = matfn.polar(A, method="prism", cfg=cfg_s, key=key, iters=30,
                            return_info=True)
    _, info_e = matfn.polar(A, method="prism", cfg=cfg_e, iters=30,
                            return_info=True)
    it_s = _iters_to_tol(info_s.residual_fro, 256)
    it_e = _iters_to_tol(info_e.residual_fro, 256)
    assert abs(it_s - it_e) <= 2, (it_s, it_e)


def test_htmp_heavy_tail_convergence(key):
    """Fig. 4 regime: heavy-tailed spectra; PRISM stays fast."""
    for kappa in [0.1, 0.5, 100.0]:
        A = rm.htmp(key, 128, 64, kappa)
        cfg = PrismConfig(degree=2, sketch_dim=8)
        _, info = matfn.polar(A, method="prism", cfg=cfg, key=key, iters=40,
                              return_info=True)
        assert np.asarray(info.residual_fro)[-1] < 1e-2


def test_warm_alpha_schedule(key):
    """Paper Sec. C trick: alpha pinned to u for the first iterations."""
    cfg = PrismConfig(degree=2, sketch_dim=8, warm_alpha_iters=3)
    A = rm.gaussian(key, 96, 48)
    _, info = matfn.polar(A, method="prism", cfg=cfg, key=key, iters=8,
                          return_info=True)
    al = np.asarray(info.alphas)
    np.testing.assert_allclose(al[:3], 29 / 20, atol=1e-6)
    # and convergence still happens
    assert np.asarray(info.residual_fro)[-1] < 1e-1
