"""Serving layer tests (DESIGN.md §16): sampling properties, engine
invariants (slot accounting, bitwise batching-invariance, deterministic
eviction, the jit-shape contract), one-launch prefill parity, and the
train -> serve checkpoint handoff."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro.configs import get_smoke_config
from repro.models import build
from repro.serving import (Engine, EngineConfig, make_trace, pow2_pad,
                           sample_logits)
from repro.serving.decode import make_serve_step

given, settings, st = hypothesis_or_stub()

ARCH = "qwen3-14b"
CLEN = 64


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config(ARCH)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _trace(cfg, seed=7, n=10, qps=50.0):
    return make_trace(seed, n_requests=n, qps=qps,
                      vocab_size=cfg.vocab_size,
                      prompt_lens=(3, 5, 8, 12), gen_lens=(2, 4, 6))


# ---------------------------------------------------------------- sampling


@given(b=st.integers(1, 4), extra=st.integers(0, 1), v=st.integers(2, 37),
       temp=st.floats(0.05, 4.0), k=st.integers(1, 40), seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_sample_logits_in_vocab(b, extra, v, temp, k, seed):
    """Property: samples are int32 and inside the vocab for any leading
    batch layout, temperature, and top_k (including top_k > vocab)."""
    rng = np.random.default_rng(seed)
    shape = (b, 2, v) if extra else (b, v)
    logits = jnp.asarray(rng.standard_normal(shape) * 3, jnp.float32)
    out = sample_logits(logits, jax.random.PRNGKey(seed),
                        temperature=temp, top_k=k)
    assert out.dtype == jnp.int32
    assert out.shape == shape[:-1]
    assert bool(jnp.all((out >= 0) & (out < v)))


@given(v=st.integers(2, 50), temp=st.floats(0.05, 9.0),
       seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_sample_topk1_is_argmax(v, temp, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((3, v)) * 5, jnp.float32)
    out = sample_logits(logits, jax.random.PRNGKey(seed),
                        temperature=temp, top_k=1)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


@given(seed=st.integers(0, 99), temp=st.floats(0.1, 2.0))
@settings(max_examples=25, deadline=None)
def test_sample_replay_deterministic(seed, temp):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((4, 33)), jnp.float32)
    key = jax.random.PRNGKey(seed)
    a = sample_logits(logits, key, temperature=temp, top_k=8)
    b = sample_logits(logits, key, temperature=temp, top_k=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_temperature_domain_single_source(served):
    """The dedup'd check raises identically from both entry points."""
    _, model, _ = served
    with pytest.raises(ValueError, match="temperature must be > 0"):
        sample_logits(jnp.zeros((2, 8)), jax.random.PRNGKey(0),
                      temperature=0.0)
    with pytest.raises(ValueError, match="temperature must be > 0"):
        make_serve_step(model, greedy=False, temperature=-1.0)


def test_serve_step_rid_fold_separates_streams(served):
    """Two requests decoding at the SAME position must not share a
    sample stream: same logits + same pos, different rids -> (with
    overwhelming probability over 64 positions) different samples, and
    replaying the same (rid, pos) resamples identically."""
    cfg, model, params = served
    step = make_serve_step(model, greedy=False, temperature=1.0)
    cache_a = model.init_cache(2, 16)
    cache_b = model.init_cache(2, 16)
    key = jax.random.PRNGKey(3)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2, 1), jnp.int32)
    seq_a, seq_b = [], []
    for t in range(8):
        pos = jnp.full((2, 1), t, jnp.int32)
        _, na, cache_a = step(params, cache_a, tok, pos, key,
                              rids=jnp.asarray([0, 0], jnp.int32))
        _, nb, cache_b = step(params, cache_b, tok, pos, key,
                              rids=jnp.asarray([0, 7], jnp.int32))
        seq_a.append(np.asarray(na))
        seq_b.append(np.asarray(nb))
    a, b = np.stack(seq_a), np.stack(seq_b)
    # lane 0 has rid 0 in both runs: identical history -> identical samples
    np.testing.assert_array_equal(a[:, 0], b[:, 0])
    # lane 1 differs only by rid -> streams must diverge
    assert not np.array_equal(a[:, 1], b[:, 1])


# ------------------------------------------------------------------ engine


def test_engine_slot_accounting_never_leaks(served):
    cfg, model, params = served
    trace = _trace(cfg)
    eng = Engine(model, params, EngineConfig(slots=4, cache_len=CLEN,
                                             eos_id=0))
    res = eng.run(trace, step_dt=0.01)
    # every request completes exactly once, table fully drains
    assert sorted(c.rid for c in res.completions) == \
        sorted(r.rid for r in trace)
    assert eng.n_active == 0
    assert bool(np.all(eng._rid == -1))
    assert all(o >= 1 and o <= 4 for o in res.occupancy)
    assert len(res.occupancy) == res.n_decode_steps
    for c in res.completions:
        req = trace[c.rid]
        assert 1 <= len(c.tokens) <= req.max_new
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)
        if len(c.tokens) < req.max_new:   # early exit must be EOS
            assert c.tokens[-1] == 0


def test_engine_bitwise_matches_single_request_loop(served):
    """The §16 batching-invariance claim, bitwise: every request decoded
    through the shared slot table (other lanes active, padded admission
    lanes, retired neighbors) produces the IDENTICAL token sequence as a
    batch-1 loop over the same model."""
    cfg, model, params = served
    trace = _trace(cfg)
    eng = Engine(model, params, EngineConfig(slots=4, cache_len=CLEN,
                                             eos_id=0))
    res = eng.run(trace, step_dt=0.01)

    pf = jax.jit(lambda p, t, ln: model.prefill_cache(
        p, {"tokens": t}, CLEN, ln))
    step = jax.jit(lambda p, c, t, pos, a: model.decode_step(
        p, c, t, pos, active=a))
    for r in trace:
        P = pow2_pad(r.prompt_len)
        toks = np.pad(r.prompt, (0, P - r.prompt_len)).reshape(1, P)
        logits, cache = pf(params, jnp.asarray(toks),
                           jnp.asarray([r.prompt_len], np.int32))
        out = [int(jnp.argmax(logits[:, 0], axis=-1)[0])]
        pos = r.prompt_len
        while len(out) < r.max_new and out[-1] != 0:
            lg, cache = step(params, cache,
                             jnp.asarray([[out[-1]]], jnp.int32),
                             jnp.asarray([[pos]], jnp.int32),
                             jnp.asarray([True]))
            out.append(int(jnp.argmax(lg[:, 0, :], axis=-1)[0]))
            pos += 1
        got = next(c for c in res.completions if c.rid == r.rid).tokens
        assert tuple(out) == got, f"rid {r.rid}: {out} != {got}"


def test_engine_inactive_slots_bitwise_frozen(served):
    """Retired/free lanes' cache rows survive decode steps bitwise —
    the active-mask plumbing, checked leaf-for-leaf."""
    cfg, model, params = served
    cache = model.init_cache(3, 16)
    step = jax.jit(lambda p, c, t, pos, a: model.decode_step(
        p, c, t, pos, active=a))
    tok = jnp.asarray([[5], [6], [7]], jnp.int32)
    for t in range(3):   # warm the caches with an all-active phase
        pos = jnp.full((3, 1), t, jnp.int32)
        _, cache = step(params, cache, tok, pos,
                        jnp.asarray([True, True, True]))
    before = jax.tree.map(np.asarray, cache)
    _, cache = step(params, cache, tok, jnp.full((3, 1), 3, jnp.int32),
                    jnp.asarray([True, False, True]))
    after = jax.tree.map(np.asarray, cache)
    for leaf_b, leaf_a in zip(jax.tree.leaves(before),
                              jax.tree.leaves(after)):
        ax = 0 if leaf_b.ndim == 2 else 1   # kpos [B, clen] vs k/v [L,B,..]
        np.testing.assert_array_equal(np.take(leaf_b, 1, axis=ax),
                                      np.take(leaf_a, 1, axis=ax))
    # the active lanes did write
    assert not np.array_equal(before["kpos"][0], after["kpos"][0])


def test_engine_deterministic_eviction_and_replay(served):
    cfg, model, params = served
    trace = _trace(cfg, seed=11, n=12, qps=80.0)
    mk = lambda: Engine(model, params, EngineConfig(
        slots=4, cache_len=CLEN, eos_id=0))
    r1 = mk().run(trace, step_dt=0.01)
    r2 = mk().run(trace, step_dt=0.01)
    # identical completion ORDER (eviction order) and timings, bitwise
    order1 = sorted(r1.completions, key=lambda c: (c.finished, c.rid))
    order2 = sorted(r2.completions, key=lambda c: (c.finished, c.rid))
    assert [c.rid for c in order1] == [c.rid for c in order2]
    for a, b in zip(r1.completions, r2.completions):
        assert a == b
    assert r1.occupancy == r2.occupancy


def test_engine_jit_shape_contract(served):
    """The decode step compiles at most 2 distinct shapes across a whole
    mixed-length run — in practice exactly 1 ([slots, 1] never varies)."""
    cfg, model, params = served
    eng = Engine(model, params, EngineConfig(slots=4, cache_len=CLEN,
                                             eos_id=0))
    res = eng.run(_trace(cfg, seed=3, n=12, qps=60.0), step_dt=0.01)
    assert res.decode_step_shapes <= 2
    assert res.decode_step_shapes == 1


def test_engine_continuous_beats_static(served):
    """The BENCH_serving.json throughput invariant, at test scale: on a
    mixed-length seeded trace, continuous admission strictly out-runs
    static (admit-only-when-drained) batching on the virtual clock."""
    cfg, model, params = served
    trace = _trace(cfg, seed=5, n=12, qps=100.0)
    run = lambda adm: Engine(model, params, EngineConfig(
        slots=4, cache_len=CLEN, eos_id=0, admission=adm)).run(
            trace, step_dt=0.01)
    cont, stat = run("continuous"), run("static")
    assert cont.generated_tokens == stat.generated_tokens
    assert cont.tokens_per_s > stat.tokens_per_s
    assert cont.n_decode_steps < stat.n_decode_steps


def test_engine_rejects_unservable_families(served):
    cfg, model, params = served
    hy = build(get_smoke_config("recurrentgemma-2b"))
    with pytest.raises(NotImplementedError, match="slot-installable"):
        Engine(hy, None, EngineConfig(slots=2, cache_len=16))
    with pytest.raises(ValueError, match="admission"):
        EngineConfig(slots=2, cache_len=16, admission="magic")


# ----------------------------------------------------------- prefill parity


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x7b"])
def test_prefill_cache_matches_streamed_decode(arch):
    """One-launch ragged prefill == streamed active-masked decode loop,
    within bf16 flash-vs-direct softmax noise; kpos bitwise."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 3, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    lengths = jnp.asarray([12, 7, 3], jnp.int32)
    lp, cp = jax.jit(model.prefill_cache, static_argnums=(2,))(
        params, {"tokens": toks}, 24, lengths)
    cache = model.init_cache(B, 24)
    step = jax.jit(lambda p, c, t, pos, a: model.decode_step(
        p, c, t, pos, active=a))
    last = jnp.zeros_like(lp)
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1],
                         jnp.full((B, 1), t, jnp.int32), t < lengths)
        last = jnp.where((t == lengths - 1).reshape(B, 1, 1), lg, last)
    np.testing.assert_array_equal(np.asarray(cp["kpos"]),
                                  np.asarray(cache["kpos"]))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(last),
                               rtol=5e-2, atol=5e-2)


def test_prefill_cache_scan_fallback_bitwise():
    """ssm prefill (scan of decode_step) is bitwise-identical to the
    streamed loop it replaces."""
    cfg = get_smoke_config("falcon-mamba-7b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 10
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    lengths = jnp.asarray([10, 4], jnp.int32)
    lp, cp = jax.jit(model.prefill_cache, static_argnums=(2,))(
        params, {"tokens": toks}, 16, lengths)
    cache = model.init_cache(B, 16)
    step = jax.jit(lambda p, c, t, pos, a: model.decode_step(
        p, c, t, pos, active=a))
    last = jnp.zeros_like(lp)
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1],
                         jnp.full((B, 1), t, jnp.int32), t < lengths)
        last = jnp.where((t == lengths - 1).reshape(B, 1, 1), lg, last)
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(last))
    for a, b in zip(jax.tree.leaves(cp), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- checkpoint handoff


def test_checkpoint_to_serve_handoff(tmp_path):
    """Train 3 steps, checkpoint, restore params into the engine, serve
    a trace to completion; a corrupted newest MANIFEST falls back to the
    older verifying step per §15."""
    from repro.checkpoint import restore_params
    from repro.config import (OptimizerConfig, PrismConfig, TrainConfig)
    from repro.data import DataConfig
    from repro.train import Trainer

    cfg = get_smoke_config("gpt2-paper")
    model = build(cfg)
    ocfg = OptimizerConfig(name="muon", learning_rate=0.02,
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=3,
                                             sketch_dim=8))
    tcfg = TrainConfig(steps=3, checkpoint_dir=str(tmp_path),
                       checkpoint_every=1, log_every=100,
                       async_checkpoint=False)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=4, markov_rank=8)
    Trainer(model, ocfg, tcfg, dcfg).run()

    step, params = restore_params(str(tmp_path), model.param_shapes())
    assert step == 3
    eng = Engine(model, params, EngineConfig(slots=2, cache_len=32,
                                             eos_id=None))
    trace = make_trace(1, n_requests=4, qps=100.0,
                       vocab_size=cfg.vocab_size,
                       prompt_lens=(3, 6), gen_lens=(2, 4))
    res = eng.run(trace, step_dt=0.01)
    assert len(res.completions) == 4
    assert all(0 <= t < cfg.vocab_size
               for c in res.completions for t in c.tokens)

    # §15: corrupt the newest step's payload -> handoff falls back
    npz = os.path.join(str(tmp_path), "step_00000003", "tree.npz")
    raw = bytearray(open(npz, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(raw))
    step2, params2 = restore_params(str(tmp_path), model.param_shapes())
    assert step2 < 3
    lg, _ = jax.jit(model.prefill_cache, static_argnums=(2,))(
        params2, {"tokens": jnp.zeros((1, 4), jnp.int32)}, 8, None)
    assert bool(jnp.all(jnp.isfinite(lg[..., :cfg.vocab_size])))
