"""The loop-aware HLO analyzer must agree with unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo as H


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_matches_unroll_flops():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    def unrolled(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a_scan = H.analyze_module(_compile_text(scanned, x, w))
    a_unroll = H.analyze_module(_compile_text(unrolled, x, w))
    want = 8 * 2 * 256 ** 3
    assert a_scan["flops"] == want, a_scan["flops"]
    assert a_unroll["flops"] == want
    # xla's own analysis undercounts the scan by 8x (the bug we fix)
    ca = jax.jit(scanned).lower(x, w).compile().cost_analysis()
    if isinstance(ca, list):  # jax 0.4.x: one dict per device
        ca = ca[0]
    assert float(ca["flops"]) == want / 8


def test_nested_scan_multiplicity():
    def fn(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = H.analyze_module(_compile_text(fn, x, w))
    assert a["flops"] == 15 * 2 * 128 ** 3, a["flops"]


def test_batched_dot_flops():
    def fn(a, b):
        return jnp.einsum("bmk,bkn->bmn", a, b)

    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    out = H.analyze_module(_compile_text(fn, a, b))
    assert out["flops"] == 2 * 4 * 64 * 32 * 16


def test_bytes_scale_with_trip_count():
    def scanned(x):
        def body(c, _):
            return jnp.tanh(c) * 1.5, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    one = H.analyze_module(_compile_text(lambda x: jnp.tanh(x) * 1.5, x))
    ten = H.analyze_module(_compile_text(scanned, x))
    assert ten["hbm_bytes"] >= 8 * one["hbm_bytes"]


def test_collective_wire_estimates():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[16,16]{1,0} all-gather(%p), replica_groups=[4,4], dimensions={0}
  ROOT %ar = f32[16,16]{1,0} all-reduce(%ag), replica_groups=[1,16], to_apply=%add
}
"""
    out = H.collective_stats(hlo)
    b = 16 * 16 * 4
    np.testing.assert_allclose(out["bytes_by_kind"]["all-gather"],
                               (4 - 1) / 4 * b)
    np.testing.assert_allclose(out["bytes_by_kind"]["all-reduce"],
                               2 * (16 - 1) / 16 * b)
