"""Scalar illustrations from the paper (Sec. 4 / Fig. 2), asserted."""
import numpy as np
import pytest

pytestmark = pytest.mark.tier1

from repro.core import polynomials as poly


def test_fig2_scalar_acceleration():
    """x0 = 1e-6: g_1(xi; 1) converges exponentially faster than f_1."""

    def run(alpha, iters=40):
        x = 1e-6
        xs = []
        for _ in range(iters):
            xi = 1 - x * x
            x = x * (1 + alpha * xi)
            xs.append(1 - x * x)
        return np.asarray(xs)

    std = run(0.5)   # classical Newton-Schulz f_1
    acc = run(1.0)   # g_1(xi; 1)
    # residual 1 - x^2 decays ~(9/4)^{-k} vs ~4^{-k} near x=0 (paper Sec. 4)
    k = 20
    assert acc[k] < std[k]
    # exponential gap: accelerated reaches 0.5 much earlier
    it_std = int(np.argmax(std < 0.5))
    it_acc = int(np.argmax(acc < 0.5))
    assert it_acc < it_std * 0.75


def test_sec4_linear_rate_constants():
    """Near x=0: 1 - x_{k+1}^2 ~ 1 - 2.25 x_k^2 (std) vs 1 - 4 x_k^2 (acc)."""
    x = 1e-4
    std = 1 - (x * (1 + 0.5 * (1 - x * x))) ** 2
    acc = 1 - (x * (1 + 1.0 * (1 - x * x))) ** 2
    np.testing.assert_allclose(1 - std, 2.25 * x * x, rtol=1e-3)
    np.testing.assert_allclose(1 - acc, 4.0 * x * x, rtol=1e-3)


def test_lemma_b1_claim1_claim2():
    """h(x, a) ranges from Lemma B.1 (claims 1-2), on a dense grid."""
    h = lambda x, a: 1 - (1 - x) * (1 + a * x) ** 2
    xs1 = np.linspace(0.5, 1.0, 201)
    xs2 = np.linspace(-0.2, 0.5, 201)
    als = np.linspace(0.5, 1.0, 101)
    X1, A1 = np.meshgrid(xs1, als)
    v1 = h(X1, A1)
    assert v1.min() >= -0.2 - 1e-9 and np.all(v1 <= X1 ** 2 + 1e-9)
    X2, A2 = np.meshgrid(xs2, als)
    v2 = h(X2, A2)
    assert v2.min() >= -0.2 - 1e-9 and v2.max() <= 0.25 + 1e-9
