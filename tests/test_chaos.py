"""Chaos drill matrix (DESIGN.md §15): every injection must recover to
a finite-loss continuation.

Each test runs one deterministic fault drill from train/chaos.py and
asserts its report says ``recovered``.  CI runs this file in the
dedicated ``chaos`` leg (the subprocess drills spawn their own 8-device
children via the env train/chaos.py pins); the fast unit-level guardian
tests live in test_guardian.py.
"""
import pytest

from repro.train import chaos


def test_nan_grad_drill(tmp_path):
    report = chaos.drill_nan_grad(str(tmp_path))
    assert report["recovered"], report
    assert report["bad_steps"] == 1


def test_spectrum_spike_drill(tmp_path):
    report = chaos.drill_spectrum_spike(str(tmp_path))
    assert report["recovered"], report
    # the spike is visible in the drift proxy and answered by a refresh
    assert report["drift_post"] > 5 * max(report["drift_pre"], 1e-12)
    assert report["refresh_after_spike"]


def test_ckpt_corrupt_drill(tmp_path):
    report = chaos.drill_ckpt_corrupt(str(tmp_path))
    assert report["recovered"], report
    assert report["manifest_rejected"]
    assert report["resumed_from"] < report["corrupted_step"]


def test_sigkill_drill(tmp_path):
    report = chaos.drill_sigkill(str(tmp_path))
    assert report["recovered"], report
    assert report["bitwise"]


def test_hang_drill(tmp_path):
    report = chaos.drill_hang(str(tmp_path))
    assert report["recovered"], report
    assert report["watchdog"] == "stale"
    # the per-stage diagnostic names every stalled stage
    assert all(v is not None for v in report["stages"].values())
