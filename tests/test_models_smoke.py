"""Per-architecture smoke tests: reduced configs, one forward + one grad
step + one decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_smoke_config
from repro.models import build
from repro.models.inputs import make_decode_inputs, make_train_batch

B, S = 2, 32


@pytest.mark.parametrize("arch", arch_ids() + ["gpt2-paper"])
def test_forward_and_loss(key, arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(key)
    batch = make_train_batch(key, cfg, B, S)
    logits, aux = model.forward(params, batch)
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.num_codebooks, S, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    # a random model should sit near uniform cross-entropy
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 2.0


@pytest.mark.parametrize("arch", arch_ids())
def test_grad_step_no_nans(key, arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(key)
    batch = make_train_batch(key, cfg, B, S)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat, _ = jax.tree.flatten(grads)
    for g in flat:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), arch
    # gradients actually flow to the embedding
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", arch_ids())
def test_decode_step(key, arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(key)
    cache = model.init_cache(B, 64)
    logits = None
    for t in range(3):
        inp = make_decode_inputs(jax.random.fold_in(key, t), cfg, B, t)
        logits, cache = model.decode_step(params, cache, inp["tokens"],
                                          inp["pos"])
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.num_codebooks, 1, cfg.vocab_size)
    else:
        assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-14b", "musicgen-medium"])
def test_sampled_decode_step(key, arch):
    """Sampled serving path: temperature/top-k tokens are int32, in
    vocab, PRNG-reproducible, and top_k=1 degenerates to greedy."""
    from repro.serving.decode import make_serve_step

    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(key)
    step = jax.jit(make_serve_step(model, greedy=False, temperature=0.8,
                                   top_k=16))
    greedy_step = jax.jit(make_serve_step(model, greedy=True))
    cache = model.init_cache(B, 64)
    skey = jax.random.fold_in(key, 99)
    nxt = None
    for t in range(3):
        inp = make_decode_inputs(jax.random.fold_in(key, t), cfg, B, t)
        tok = inp["tokens"] if nxt is None else \
            nxt.reshape(inp["tokens"].shape)
        logits, nxt, cache = step(params, cache, tok, inp["pos"], skey)
        assert nxt.dtype == jnp.int32
        assert nxt.shape == logits.shape[:-1]
        assert bool(jnp.all((nxt >= 0) & (nxt < cfg.vocab_size)))
    # reproducible: same key + same inputs -> same sample
    _, nxt2, _ = step(params, cache, tok, inp["pos"], skey)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(nxt2))
    # top_k=1 == argmax for any temperature
    one = jax.jit(make_serve_step(model, greedy=False, temperature=3.0,
                                  top_k=1))
    cache_a = model.init_cache(B, 64)
    cache_b = model.init_cache(B, 64)
    inp = make_decode_inputs(key, cfg, B, 0)
    _, n_a, _ = one(params, cache_a, inp["tokens"], inp["pos"], skey)
    _, n_g, _ = greedy_step(params, cache_b, inp["tokens"], inp["pos"])
    np.testing.assert_array_equal(np.asarray(n_a), np.asarray(n_g))
    with pytest.raises(ValueError):
        make_serve_step(model, greedy=False, temperature=0.0)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x7b",
                                  "recurrentgemma-2b", "falcon-mamba-7b"])
def test_decode_matches_forward(key, arch):
    """Greedy decode logits == forward logits at the same positions."""
    import dataclasses

    cfg = get_smoke_config(arch).replace(dtype="float32", emb_dtype="float32")
    if cfg.moe is not None:
        # drop-free capacity: capacity dropping differs between a full
        # forward (T=B*S tokens compete) and decode (T=B), by design
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build(cfg)
    params = model.init(key)
    T = 8
    batch = make_train_batch(key, cfg, B, T)
    full_logits, _ = model.forward(params, batch)
    cache = model.init_cache(B, 16)
    for t in range(T):
        tok = batch["tokens"][:, t:t + 1]
        pos = jnp.full((B, 1), t, jnp.int32)
        step_logits, cache = model.decode_step(params, cache, tok, pos)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2)


def test_logical_axes_match_params(key):
    for arch in arch_ids():
        cfg = get_smoke_config(arch)
        model = build(cfg)
        shapes = model.param_shapes()
        axes = model.logical_axes()
        st = jax.tree.structure(shapes)
        at = jax.tree.structure(
            axes, is_leaf=lambda t: isinstance(t, tuple) and
            all(isinstance(x, (str, type(None))) for x in t))
        assert st == at, f"{arch}: {st} vs {at}"
        # every axes tuple must have one name per array dim
        flat_s = jax.tree.leaves(shapes)
        flat_a = jax.tree.leaves(
            axes, is_leaf=lambda t: isinstance(t, tuple) and
            all(isinstance(x, (str, type(None))) for x in t))
        for s, a in zip(flat_s, flat_a):
            assert len(a) == s.ndim, (arch, s.shape, a)


def test_full_configs_instantiate_abstractly():
    """Full (non-smoke) configs build abstract param trees w/o allocation."""
    from repro.configs import get_config

    for arch in arch_ids():
        cfg = get_config(arch)
        model = build(cfg)
        shapes = model.param_shapes()
        n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert n_params > 1e8, (arch, n_params)  # all assigned archs > 100M
