"""Pipeline-parallel schedules: GPipe forward + the 1F1B training engine.

The heavy checks run in subprocesses with forced host devices (the test
process itself must keep the default single-device world):

  * GPipe forward == sequential reference;
  * 1F1B toy grads == explicit per-microbatch VJP accumulation, bitwise,
    for BOTH handover implementations (ppermute and the scatter+psum
    fallback), plus the scan-length/tick contract read off the jaxpr;
  * 1F1B on the real smoke transformer: a 2-stage run reproduces the
    single-stage run bitwise at fp32 and within bf16 tolerance at bf16.
"""
import os
import subprocess
import sys
import textwrap

from repro.launch.pipeline import bubble_fraction, n_ticks_1f1b

PIN = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    os.environ.setdefault("REPRO_KERNEL_MODE", "ref")
    import sys
    sys.path.insert(0, "src")
""")


def _run(script: str, devices: int, timeout: int = 420):
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", PIN.format(n=devices) + script],
        cwd="/root/repo", env=env, capture_output=True, text=True,
        timeout=timeout)


def test_schedule_contract():
    """Tick count and bubble model of the 1F1B schedule (DESIGN.md §13)."""
    for S in (1, 2, 4, 8):
        for M in (1, 2, 4, 8, 16):
            T = n_ticks_1f1b(S, M)
            assert T == M + 2 * (S - 1)
            b = bubble_fraction(S, M)
            assert 0.0 <= b < 1.0
            # more microbatches amortize the fixed fill+drain
            assert bubble_fraction(S, 2 * M) <= b
    assert bubble_fraction(1, 4) == 0.0  # no pipeline, no bubble


SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.pipeline import pipeline_apply
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((4,), ("pod",))
    S, L_PER, D = 4, 2, 16

    def stage_fn(params, x):  # params [L_PER, D, D]
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, L_PER, D, D)) / np.sqrt(D)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, D))

    # sequential reference: all 8 layers in order
    ref = x
    for s in range(S):
        ref = stage_fn(Ws[s], ref)

    with mesh:
        got = pipeline_apply(mesh, stage_fn, Ws, x, n_micro=4, axis="pod")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK")
""")


def test_gpipe_matches_sequential():
    out = _run(SCRIPT, devices=4)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr


ONE_F_ONE_B_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.pipeline import n_ticks_1f1b, one_f_one_b
    from repro.launch.mesh import compat_make_mesh
    from repro.sharding_ctx import compat_shard_map

    mesh = compat_make_mesh((4,), ("pod",))
    S, L_PER, D, MB = 4, 2, 8, 4

    def stage_fn(shared, lay, inp, x, is_first, is_last):
        # shared head weight seeds the loss on the last stage (the other
        # stages contribute exact zeros to its psum'd grad, keeping the
        # comparison bitwise); first stage consumes inp instead of the
        # incoming activation
        x = jnp.where(is_first, inp, x)
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, lay)
        loss = jnp.where(is_last, jnp.mean((y @ shared["head"]) ** 2), 0.0)
        return y, jnp.stack([loss.astype(jnp.float32)])

    key = jax.random.PRNGKey(0)
    shared = {"head": 0.3 * jax.random.normal(key, (D, D))}
    Ws = jax.random.normal(jax.random.fold_in(key, 1),
                           (S, L_PER, D, D)) / np.sqrt(D)
    M = 4
    inp = jax.random.normal(jax.random.fold_in(key, 2), (M, MB, D))

    # reference: explicit per-microbatch VJP accumulation of the SAME
    # staged computation (all stages unrolled in one function)
    def full(shared, Ws, x):
        for s in range(S):
            y, l = stage_fn(shared, Ws[s], x, x, s == 0, s == S - 1)
            x = y
        return l[0]

    ref_loss = jnp.float32(0)
    ref_gs = jax.tree.map(jnp.zeros_like, shared)
    ref_gw = jnp.zeros_like(Ws)
    for m in range(M):
        (l, (gs, gw)) = jax.value_and_grad(full, argnums=(0, 1))(
            shared, Ws, inp[m])
        ref_loss += l / M
        ref_gs = jax.tree.map(lambda a, b: a + b / M, ref_gs, gs)
        ref_gw += gw / M

    act = jax.ShapeDtypeStruct((MB, D), jnp.float32)
    for use_ppermute in (True, False):
        run = one_f_one_b(stage_fn, "pod", S, M, act,
                          use_ppermute=use_ppermute)
        mapped = compat_shard_map(
            run, mesh=mesh,
            in_specs=(P(), P("pod"), P(), P("pod")),
            out_specs=(P(), P(), P("pod")),
            axis_names=None)
        with mesh:
            loss, g_sh, g_lay = jax.jit(mapped)(
                shared, Ws, inp, jnp.arange(S, dtype=jnp.int32))
        tag = "ppermute" if use_ppermute else "psum"
        assert np.asarray(loss[0]).tobytes() == \
            np.asarray(ref_loss).tobytes(), (tag, loss, ref_loss)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(g_sh),
                jax.tree_util.tree_leaves_with_path(ref_gs)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
                (tag, pa)
        assert np.asarray(g_lay).tobytes() == \
            np.asarray(ref_gw).tobytes(), tag
        print("HANDOVER_OK", tag)

    # tick contract: the engine's scan really runs
    # n_micro + 2*(n_stages-1) ticks
    for m in (4, 8):
        run = one_f_one_b(stage_fn, "pod", S, m, act)
        mapped = compat_shard_map(
            run, mesh=mesh,
            in_specs=(P(), P("pod"), P(), P("pod")),
            out_specs=(P(), P(), P("pod")), axis_names=None)
        jpr = str(jax.make_jaxpr(mapped)(
            shared, Ws, inp[:1].repeat(m, 0),
            jnp.arange(S, dtype=jnp.int32)))
        T = n_ticks_1f1b(S, m)
        assert f"length={T}" in jpr, (m, T)
        print("TICKS_OK", m, T)
    print("ONE_F_ONE_B_OK")
""")


def test_1f1b_toy_bitwise_and_ticks():
    out = _run(ONE_F_ONE_B_SCRIPT, devices=4)
    assert "ONE_F_ONE_B_OK" in out.stdout, out.stdout + out.stderr[-4000:]
    assert "HANDOVER_OK ppermute" in out.stdout
    assert "HANDOVER_OK psum" in out.stdout


MODEL_PARITY_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, make_batch_fn
    from repro.launch.mesh import compat_make_mesh
    from repro.models import build
    from repro.train.state import master_params, pipeline_loss_and_grads

    def grads_at(stages, dtype):
        cfg = get_smoke_config("qwen3-14b").replace(
            d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
            dtype=dtype)
        model = build(cfg)
        params = master_params(model.init(jax.random.PRNGKey(0)))
        batch = make_batch_fn(cfg, DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
            seed=0, markov_rank=8))(jnp.asarray(0))
        mesh = compat_make_mesh((stages,), ("pod",))
        lag = pipeline_loss_and_grads(model, mesh, n_micro=4)
        with mesh:
            loss, grads, _ = jax.jit(lag)(params, batch)
        return (np.asarray(loss),
                [(p, np.asarray(a)) for p, a in
                 jax.tree_util.tree_leaves_with_path(grads)])

    # fp32: the S=2 pipeline must reproduce the S=1 run of the SAME
    # engine bitwise — identical per-microbatch compute, identical
    # accumulation order, only the stage split differs
    l1, g1 = grads_at(1, "float32")
    l2, g2 = grads_at(2, "float32")
    assert l1.tobytes() == l2.tobytes(), (l1, l2)
    for (p, a), (_, b) in zip(g1, g2):
        assert a.tobytes() == b.tobytes(), p
    print("FP32_BITWISE_OK", float(l1))

    # bf16: reduced-precision handover makes bitwise too strict; the two
    # runs must still agree to bf16 resolution
    l1, g1 = grads_at(1, "bfloat16")
    l2, g2 = grads_at(2, "bfloat16")
    assert abs(float(l1) - float(l2)) <= 0.05 * abs(float(l1)), (l1, l2)
    for (p, a), (_, b) in zip(g1, g2):
        a, b = a.astype(np.float32), b.astype(np.float32)
        tol = 0.05 * max(np.abs(a).max(), 1e-3)
        assert np.abs(a - b).max() <= tol, (p, np.abs(a - b).max(), tol)
    print("BF16_TOL_OK", float(l1))
    print("MODEL_PARITY_OK")
""")


def test_1f1b_model_grad_parity():
    out = _run(MODEL_PARITY_SCRIPT, devices=2, timeout=560)
    assert "MODEL_PARITY_OK" in out.stdout, out.stdout + out.stderr[-4000:]
    assert "FP32_BITWISE_OK" in out.stdout
    assert "BF16_TOL_OK" in out.stdout
