"""Pipeline-parallel stage wrapper: pipelined == sequential reference.

Runs in a subprocess with 4 forced host devices (the test process itself
must keep the default single-device world).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.pipeline import pipeline_apply
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((4,), ("pod",))
    S, L_PER, D = 4, 2, 16

    def stage_fn(params, x):  # params [L_PER, D, D]
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, L_PER, D, D)) / np.sqrt(D)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, D))

    # sequential reference: all 8 layers in order
    ref = x
    for s in range(S):
        ref = stage_fn(Ws[s], ref)

    with mesh:
        got = pipeline_apply(mesh, stage_fn, Ws, x, n_micro=4, axis="pod")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK")
""")


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd="/root/repo",
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
