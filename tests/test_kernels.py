"""Per-kernel allclose sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gram as gram_kernel
from repro.kernels import matmul_add as mma_kernel
from repro.kernels import ref
from repro.kernels import sketch_traces as sk_kernel

pytestmark = pytest.mark.tier1

SHAPES_MM = [
    (8, 8, 8),
    (128, 128, 128),
    (256, 128, 64),
    (100, 70, 130),   # non-divisible => padding path
    (512, 256, 384),
    (33, 257, 129),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,k,n", SHAPES_MM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul_add_sweep(key, m, k, n, dtype):
    ka, kb, kc = jax.random.split(key, 3)
    A = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    B = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    C = jax.random.normal(kc, (m, n), jnp.float32).astype(dtype)
    got = mma_kernel.matmul_add(A, B, C, alpha=0.7, beta=-1.3,
                                bm=128, bn=128, bk=64, interpret=True)
    want = ref.matmul_add(A, B, C, alpha=0.7, beta=-1.3)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_matmul_add_no_c(key):
    A = jax.random.normal(key, (96, 64))
    B = jax.random.normal(jax.random.fold_in(key, 1), (64, 72))
    got = mma_kernel.matmul_add(A, B, alpha=2.0, bm=64, bn=64, bk=32,
                                interpret=True)
    np.testing.assert_allclose(got, 2.0 * (A @ B), rtol=2e-5, atol=2e-5)


GRAM_SHAPES = [(64, 64), (128, 256), (256, 128), (200, 100), (96, 33)]


@pytest.mark.parametrize("m,n", GRAM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_sweep(key, m, n, dtype):
    X = jax.random.normal(key, (m, n), jnp.float32).astype(dtype)
    U = gram_kernel.gram_upper(X, alpha=1.0, beta=-1.0, bn=64, bk=64,
                               interpret=True)
    got = gram_kernel.mirror_upper(U, min(64, n))
    want = ref.gram(X, alpha=1.0, beta=-1.0)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_gram_upper_blocks_correct(key):
    """Upper blocks are exact; lower blocks are undefined (never visited —
    that's the saved MXU work) and masked out by mirror_upper."""
    X = jax.random.normal(key, (64, 256))
    U = np.asarray(gram_kernel.gram_upper(X, alpha=0.0, beta=1.0, bn=64,
                                          bk=64, interpret=True))
    W = np.asarray(ref.gram(X, alpha=0.0, beta=1.0))
    for i in range(4):
        for j in range(i, 4):
            np.testing.assert_allclose(U[i * 64:(i + 1) * 64,
                                         j * 64:(j + 1) * 64],
                                       W[i * 64:(i + 1) * 64,
                                         j * 64:(j + 1) * 64],
                                       rtol=2e-5, atol=2e-5)


def test_unrank_upper_exhaustive():
    """Closed-form triangle unranking is exact for every nb up to 40."""
    import jax.numpy as jnp
    for nb in [1, 2, 3, 7, 16, 40]:
        ts = jnp.arange(nb * (nb + 1) // 2)
        i, j = gram_kernel._unrank_upper(ts, nb)
        want = np.asarray(np.triu_indices(nb))
        np.testing.assert_array_equal(np.asarray(i), want[0])
        np.testing.assert_array_equal(np.asarray(j), want[1])


@pytest.mark.parametrize("n,p,maxp", [(64, 8, 6), (200, 8, 10), (256, 16, 6),
                                      (130, 4, 4)])
def test_sketch_traces_sweep(key, n, p, maxp):
    kr, ks = jax.random.split(key)
    R = jax.random.normal(kr, (n, n)) / np.sqrt(n)
    R = 0.5 * (R + R.T)
    S = jax.random.normal(ks, (p, n)) / np.sqrt(p)
    St = jnp.pad(S.T, ((0, 0), (0, (-p) % 128)))
    V = St
    ts = [float(jnp.sum(St * St))]
    for _ in range(maxp):
        V, t = sk_kernel.sketch_step(R, V, St, bm=64, bk=64, interpret=True)
        ts.append(float(t))
    want = np.asarray(ref.sketch_traces(R, S, maxp))
    np.testing.assert_allclose(np.asarray(ts), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,m,k,n", [(3, 64, 64, 64), (2, 100, 70, 130),
                                     (5, 33, 257, 129)])
def test_matmul_add_batch_grid(key, B, m, k, n):
    """The batch-grid kernel == a loop of 2-D oracle calls."""
    ka, kb, kc = jax.random.split(key, 3)
    A = jax.random.normal(ka, (B, m, k))
    Bm = jax.random.normal(kb, (B, k, n))
    C = jax.random.normal(kc, (B, m, n))
    got = mma_kernel.matmul_add(A, Bm, C, alpha=0.7, beta=-1.3,
                                bm=64, bn=64, bk=64, interpret=True)
    want = np.stack([np.asarray(ref.matmul_add(A[b], Bm[b], C[b],
                                               alpha=0.7, beta=-1.3))
                     for b in range(B)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,m,n", [(3, 96, 130), (2, 64, 64), (4, 200, 100)])
def test_gram_batch_grid(key, B, m, n):
    X = jax.random.normal(key, (B, m, n))
    U = gram_kernel.gram_upper(X, alpha=1.0, beta=-1.0, bn=64, bk=64,
                               interpret=True)
    got = gram_kernel.mirror_upper(U, min(64, n))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gram(X)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,n,p,maxp,bn", [(3, 64, 8, 10, 64),
                                           (2, 130, 8, 5, 64),
                                           (1, 96, 16, 7, 32)])
def test_sketch_chain_single_launch(key, B, n, p, maxp, bn):
    """The fused whole-chain kernel == the per-step oracle chain."""
    kr, ks = jax.random.split(key)
    R = jax.random.normal(kr, (B, n, n)) / np.sqrt(n)
    R = 0.5 * (R + jnp.swapaxes(R, -1, -2))
    S = jax.random.normal(ks, (p, n)) / np.sqrt(p)
    St = jnp.pad(S.T, ((0, 0), (0, (-p) % 128)))
    got = sk_kernel.sketch_chain(R, St, maxp, bn=bn, interpret=True)
    want = np.asarray(ref.sketch_traces(R, S, maxp))[:, 1:]
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_sketch_chain_bf16(key):
    kr, ks = jax.random.split(key)
    R = (jax.random.normal(kr, (2, 64, 64)) / 8).astype(jnp.bfloat16)
    R = 0.5 * (R + jnp.swapaxes(R, -1, -2))
    S = (jax.random.normal(ks, (8, 64)) / np.sqrt(8)).astype(jnp.bfloat16)
    St = jnp.pad(S.T, ((0, 0), (0, 120)))
    got = sk_kernel.sketch_chain(R, St, 6, bn=64, interpret=True)
    want = np.asarray(ref.sketch_traces(R, S, 6))[:, 1:]
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-2, atol=5e-2)


# ----------------------------------------------------- randomized fuzz sweep

# deterministic fuzz corpus: non-divisible (B, m, k, n) drawn once at
# import so every CI run sweeps the same shapes (rerunnable failures)
_FUZZ_RNG = np.random.default_rng(0)
FUZZ_SHAPES = [tuple(int(x) for x in (_FUZZ_RNG.integers(1, 4),
                                      _FUZZ_RNG.integers(1, 100),
                                      _FUZZ_RNG.integers(1, 100),
                                      _FUZZ_RNG.integers(1, 100)))
               for _ in range(6)]


@pytest.mark.parametrize("B,m,k,n", FUZZ_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_matmul_add(key, B, m, k, n, dtype):
    """Interpret-mode kernel == oracle on random non-divisible shapes for
    both fp32 and bf16-in/fp32-accum operands."""
    ka, kb, kc = jax.random.split(key, 3)
    A = jax.random.normal(ka, (B, m, k), jnp.float32).astype(dtype)
    Bm = jax.random.normal(kb, (B, k, n), jnp.float32).astype(dtype)
    C = jax.random.normal(kc, (B, m, n), jnp.float32).astype(dtype)
    got = mma_kernel.matmul_add(A, Bm, C, alpha=0.5, beta=1.25,
                                bm=32, bn=32, bk=32, interpret=True)
    want = ref.matmul_add(A, Bm, C, alpha=0.5, beta=1.25)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("B,m,k,n", FUZZ_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_gram(key, B, m, k, n, dtype):
    del n  # gram consumes (B, m, k) -> [B, k, k]
    X = jax.random.normal(key, (B, m, k), jnp.float32).astype(dtype)
    U = gram_kernel.gram_upper(X, alpha=1.0, beta=-1.0, bn=32, bk=32,
                               interpret=True)
    got = gram_kernel.mirror_upper(U, min(32, k))
    want = ref.gram(X, alpha=1.0, beta=-1.0)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("B,m,k,n", FUZZ_SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_sketch_chain(key, B, m, k, n, dtype):
    del m  # the chain consumes a symmetric [B, k, k] residual
    kr, ks = jax.random.split(key)
    R = jax.random.normal(kr, (B, k, k)) / (2 * np.sqrt(max(k, 1)))
    R = (0.5 * (R + jnp.swapaxes(R, -1, -2))).astype(dtype)
    p = 1 + n % 8
    S = (jax.random.normal(ks, (p, k)) / np.sqrt(p)).astype(dtype)
    St = jnp.pad(S.T, ((0, 0), (0, (-p) % 128)))
    got = sk_kernel.sketch_chain(R, St, 5, bn=32, interpret=True)
    want = np.asarray(ref.sketch_traces(R, S, 5))[:, 1:]
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol)


def test_fuzz_launch_count_dtype_parity(monkeypatch, key):
    """Contract: the bf16 path issues exactly the launches the fp32 path
    does on every fuzz shape — precision never changes dispatch."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.kernels import ops

    for B, m, k, n in FUZZ_SHAPES:
        counts = {}
        for dtype in DTYPES:
            A = jnp.zeros((B, m, k), dtype)
            Bm = jnp.zeros((B, k, n), dtype)
            X = jnp.zeros((B, m, k), dtype)
            counts[dtype] = ops.count_launches(
                lambda A, Bm, X: (ops.matmul_add(A, Bm),
                                  ops.gram(X)), A, Bm, X)
        assert counts[jnp.float32] == counts[jnp.bfloat16] == 2, \
            ((B, m, k, n), counts)


# ------------------------------------------------- interpret-mode size cutoff

def test_interpret_cutoff_falls_back_to_ref(monkeypatch, key):
    """ops._mode honors REPRO_INTERPRET_MAX_ELEMS: oversized operands
    fall back to the jnp oracle (0 launches) so CPU validation runs don't
    crawl; small ones still execute the kernel body; 0 disables."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.kernels import ops

    A = jax.random.normal(key, (64, 64))
    B = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))

    monkeypatch.setenv("REPRO_INTERPRET_MAX_ELEMS", "1000")  # 4096 > 1000
    assert ops.count_launches(lambda a, b: ops.matmul_add(a, b), A, B) == 0
    got = ops.matmul_add(A, B)  # numerics identical through the fallback
    np.testing.assert_allclose(got, ref.matmul_add(A, B), rtol=2e-5,
                               atol=2e-5)

    monkeypatch.setenv("REPRO_INTERPRET_MAX_ELEMS", "100000")
    assert ops.count_launches(lambda a, b: ops.matmul_add(a, b), A, B) == 1

    monkeypatch.setenv("REPRO_INTERPRET_MAX_ELEMS", "0")  # disabled
    assert ops.count_launches(lambda a, b: ops.matmul_add(a, b), A, B) == 1

    monkeypatch.delenv("REPRO_INTERPRET_MAX_ELEMS")
    assert ops._interpret_cutoff() == ops._DEFAULT_INTERPRET_MAX_ELEMS


def test_interpret_cutoff_only_affects_interpret_mode(monkeypatch, key):
    """ref/native dispatch ignores the cutoff (it guards only the Python
    interpreter path)."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    monkeypatch.setenv("REPRO_INTERPRET_MAX_ELEMS", "1")
    from repro.kernels import ops

    A = jax.random.normal(key, (32, 16))
    B = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    np.testing.assert_allclose(ops.matmul_add(A, B), A @ B, rtol=2e-5,
                               atol=2e-5)


def test_ops_dispatch_ref_on_cpu(key):
    """ops.py must fall back to the jnp oracle on CPU by default."""
    from repro.kernels import ops

    A = jax.random.normal(key, (3, 32, 16))  # batched
    B = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 24))
    got = ops.matmul_add(A, B, alpha=1.0)
    np.testing.assert_allclose(got, A @ B, rtol=2e-5, atol=2e-5)
    R = jax.random.normal(key, (2, 48, 48)) / 7
    R = 0.5 * (R + jnp.swapaxes(R, -1, -2))
    S = jax.random.normal(key, (8, 48)) / np.sqrt(8)
    got = ops.sketch_traces(R, S, 4)
    want = ref.sketch_traces(R, S, 4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_prism_with_interpret_kernels_end_to_end(key, monkeypatch):
    """PRISM polar with use_kernels=True (interpret) == pure-jnp result."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.config import PrismConfig
    from repro.core import matfn

    A = jax.random.normal(key, (96, 48))
    Xk = matfn.polar(A, method="prism",
                     cfg=PrismConfig(degree=2, sketch_dim=8, use_kernels=True),
                     key=key, iters=6)
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    Xr = matfn.polar(A, method="prism",
                     cfg=PrismConfig(degree=2, sketch_dim=8, use_kernels=True),
                     key=key, iters=6)
    np.testing.assert_allclose(np.asarray(Xk), np.asarray(Xr),
                               rtol=5e-3, atol=5e-3)
