import os

# Tests run on the single real CPU device; only launch/dryrun spawns the
# 512-placeholder-device world (in a subprocess, per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
