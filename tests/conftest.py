import os

# Tests run on the single real CPU device; only launch/dryrun spawns the
# 512-placeholder-device world (in a subprocess, per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def hypothesis_or_stub():
    """(given, settings, st) — real hypothesis, or decoration-safe stubs
    that skip ONLY the property tests when it isn't installed."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*a, **k):
            return lambda f: pytest.mark.skip(
                reason="hypothesis not installed")(f)

        def settings(*a, **k):
            return lambda f: f

        class _St:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _St()
