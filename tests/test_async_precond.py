"""Async preconditioner service (DESIGN.md §12).

Covers the refresh-plane contract end to end: the shared refresh-period
helper, async-vs-blocking parity at swap boundaries (asyncness changes
scheduling, never values), the zero-matfn-launch steady-state contract,
the drift trigger on an adversarial spectrum shift, sharding rules for
the pending twins, and sharded double-buffer parity on the 8-device CI
mesh (subprocess, same pattern as test_sharded_precond.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, PrismConfig, TrainConfig
from repro.optim import base, make_optimizer


# ------------------------------------------------- resolve_refresh_period

def test_resolve_refresh_period():
    muon = OptimizerConfig(name="muon", precond_every=6)
    assert base.resolve_refresh_period(muon) == 6
    # shampoo honors its legacy knob too: period is the max of the two
    sham = OptimizerConfig(name="shampoo", precond_every=3,
                           precondition_every=10)
    assert base.resolve_refresh_period(sham) == 10
    sham2 = OptimizerConfig(name="shampoo", precond_every=12,
                            precondition_every=5)
    assert base.resolve_refresh_period(sham2) == 12
    # name override for configs reused across optimizers
    assert base.resolve_refresh_period(sham, name="muon") == 3
    # floor at 1
    assert base.resolve_refresh_period(
        OptimizerConfig(name="muon", precond_every=0)) == 1


def test_config_validation():
    with pytest.raises(ValueError, match="precond_every"):
        OptimizerConfig(name="muon", precond_async=True, precond_every=1)
    with pytest.raises(ValueError, match="matfn_tol"):
        OptimizerConfig(name="muon", precond_every=4,
                        precond_drift_slack=2.0)
    cfg = OptimizerConfig(name="muon", precond_every=4, precond_async=True,
                          matfn_tol=1e-2, precond_drift_slack=3.0)
    assert cfg.drift_threshold == pytest.approx(2e-2)
    # slack <= 1 clamps to an always-fire threshold of 0
    cfg0 = OptimizerConfig(name="muon", precond_every=4, precond_async=True,
                           matfn_tol=1e-2, precond_drift_slack=0.5)
    assert cfg0.drift_threshold == 0.0
    # trigger disabled entirely without slack
    assert OptimizerConfig(name="muon", precond_every=4,
                           precond_async=True).drift_threshold is None


# ----------------------------------------------------------- fixtures

def _tree(key):
    params = {"w1": jax.random.normal(key, (64, 32)),
              "w3": jax.random.normal(jax.random.fold_in(key, 2),
                                      (3, 48, 32)),
              "b": jax.random.normal(jax.random.fold_in(key, 4), (64,))}
    axes = {"w1": ("embed", "mlp"), "w3": ("layers", "embed", "mlp"),
            "b": ("embed",)}
    return params, axes


def _grad_stream(key, params, t, scale=0.1):
    k = jax.random.fold_in(key, 1000 + t)
    return jax.tree.map(
        lambda p: scale * jax.random.normal(
            jax.random.fold_in(k, p.size), p.shape), params)


def _async_cfg(name, **kw):
    kw.setdefault("learning_rate", 0.05)
    kw.setdefault("precond_every", 3)
    kw.setdefault("prism", PrismConfig(degree=2, iterations=4,
                                       warm_alpha_iters=1, sketch_dim=8))
    kw.setdefault("precond_swap_delay", 1)
    return OptimizerConfig(name=name, precond_async=True, **kw)


# ------------------------------------------------- async == blocking

@pytest.mark.parametrize("name", ["muon", "shampoo"])
def test_async_matches_blocking_at_swap_boundaries(name):
    """Dispatching the refresh asynchronously changes SCHEDULING, never
    values: a blocking reference that runs the identical refresh program
    synchronously (block_until_ready before the step) and the async
    service produce bit-identical params at every step — including the
    swap-boundary steps where the pending buffer becomes active."""
    key = jax.random.PRNGKey(0)
    params, axes = _tree(key)
    cfg = _async_cfg(name)
    opt = make_optimizer(cfg, axes)
    step = jax.jit(opt.update, static_argnums=(5,))
    refresh = jax.jit(opt.refresh)

    def run(blocking):
        svc = base.AsyncPrecondService(opt, cfg, refresh_jit=refresh)
        p, s = params, opt.init(params)
        swaps = []
        for t in range(8):
            drift = float(base.precond_drift(s))
            s = svc.step_begin(
                s, t, jax.random.fold_in(jax.random.PRNGKey(7), t),
                drift=drift)
            if blocking:
                jax.block_until_ready(s)  # refresh forced to finish
            before = int(s["pending_at"])
            g = _grad_stream(key, params, t)
            p, s = step(g, s, p, t, jax.random.PRNGKey(t), False)
            if before != base.NO_PENDING and \
                    int(s["pending_at"]) == base.NO_PENDING:
                swaps.append(t)
        return p, s, swaps

    p_async, s_async, swaps_a = run(blocking=False)
    p_block, s_block, swaps_b = run(blocking=True)
    assert swaps_a == swaps_b and len(swaps_a) >= 2, (swaps_a, swaps_b)
    for a, b in zip(jax.tree.leaves(p_async), jax.tree.leaves(p_block)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_async), jax.tree.leaves(s_block)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["muon", "shampoo"])
def test_swap_serves_pending_buffer(name):
    """Before the swap the update consumes the ACTIVE buffer untouched;
    on the swap step the pending buffer (and its telemetry twin) becomes
    active and pending_at clears."""
    key = jax.random.PRNGKey(1)
    params, axes = _tree(key)
    cfg = _async_cfg(name, precond_swap_delay=2, precond_every=8)
    opt = make_optimizer(cfg, axes)
    p, s = params, opt.init(params)
    svc = base.AsyncPrecondService(opt, cfg)
    cache_key = "ortho" if name == "muon" else "Linv"

    def caches(state):
        slots, _ = base._flat_slots(state["leaves"])
        return [np.asarray(sl[cache_key]) for sl in slots
                if cache_key in sl]

    # bootstrap dispatch back-dates pending_at, so the first step swaps
    # immediately (it waits on its own preconditioner, like a blocking
    # first step would)
    s = svc.step_begin(s, 0, key, drift=0.0)
    assert int(s["pending_at"]) == -cfg.precond_swap_delay
    g = _grad_stream(key, params, 0)
    p, s = opt.update(g, s, p, 0, jax.random.PRNGKey(0), refresh=False)
    assert int(s["pending_at"]) == base.NO_PENDING
    for t in (1, 2):
        g = _grad_stream(key, params, t)
        p, s = opt.update(g, s, p, t, jax.random.PRNGKey(t), refresh=False)
    active_before = caches(s)
    # dispatch at t=3 (install_pending directly — the raw refresh-plane
    # mechanics, no service scheduling in the way): for the next
    # swap_delay steps the ACTIVE cache must stay bit-identical (no
    # in-step recompute, no early swap)
    s = base.install_pending(s, opt.refresh(s, jax.random.fold_in(key, 1)),
                             at_step=3)
    assert int(s["pending_at"]) == 3
    pend_vals = [np.asarray(sl[cache_key + "_p"]) for sl in
                 base._flat_slots(s["leaves"])[0] if cache_key + "_p" in sl]
    for t in (3, 4):
        g = _grad_stream(key, params, t)
        p, s = opt.update(g, s, p, t, jax.random.PRNGKey(t), refresh=False)
    # t=3: count 3 < 3+2 -> no swap; t=4: count 4 < 5 -> no swap
    assert int(s["pending_at"]) == 3
    for a, b in zip(caches(s), active_before):
        np.testing.assert_array_equal(a, b)
    # t=5: count 5 >= 3+2 -> swap; active now equals the dispatched
    # pending buffer exactly
    g = _grad_stream(key, params, 5)
    p, s = opt.update(g, s, p, 5, jax.random.PRNGKey(5), refresh=False)
    assert int(s["pending_at"]) == base.NO_PENDING
    for a, b in zip(caches(s), pend_vals):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------- zero-launch contract

def test_steady_state_zero_matfn_launches(monkeypatch):
    key = jax.random.PRNGKey(2)
    """The §12 contract: a FULL async trainer step — swap cond included —
    compiles with ZERO matrix-function kernel launches; all matfn work
    lives in the separately jitted refresh program."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, make_batch_fn
    from repro.kernels import ops
    from repro.models import build
    from repro.train.state import make_train_step, master_params

    cfg = get_smoke_config("gpt2-paper").replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128)
    model = build(cfg)
    ocfg = OptimizerConfig(
        name="muon", precond_every=4, precond_async=True,
        prism=PrismConfig(degree=2, iterations=2, warm_alpha_iters=1,
                          sketch_dim=8, use_kernels=True))
    opt = make_optimizer(ocfg, model.logical_axes())
    step_fn = make_train_step(model, opt, ocfg)
    params = master_params(model.init(key))
    state = opt.init(params)
    batch = make_batch_fn(cfg, DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=16, global_batch=2,
                                          markov_rank=8))(jnp.asarray(0))
    step = jnp.asarray(0, jnp.int32)
    # steady-state step: zero launches, even with a swap pending
    state_pending = base.install_pending(
        state, opt.refresh(state, key), at_step=0)
    for s in (state, state_pending):
        n = ops.count_launches(
            lambda p, st, b: step_fn(p, st, b, step, False), params, s,
            batch)
        assert n == 0, n
    # ...while the refresh program itself carries all the matfn launches
    n_refresh = ops.count_launches(lambda s: opt.refresh(s, key), state)
    assert n_refresh > 0, n_refresh


# ------------------------------------------------------- drift trigger

def test_drift_trigger_fires_on_spectrum_shift():
    """Stationary stream: after warmup the drift proxy stays under the
    threshold and the clock (set far out) never fires — no refreshes.
    Then an adversarial spectrum shift (gradients re-drawn 30x larger in
    a rotated basis) drives the momentum — hence the drift proxy — up,
    and the trigger dispatches within a few steps."""
    key = jax.random.PRNGKey(3)
    params, axes = _tree(key)
    # momentum=0.5: the momentum reaches its fixed point within the
    # warmup window (0.5^12 ~ 2e-4), so the stationary phase is a clean
    # no-trigger baseline
    cfg = _async_cfg("muon", precond_every=1000, matfn_tol=1e-2,
                     precond_drift_slack=1.5, momentum=0.5)
    assert cfg.drift_threshold == pytest.approx(5e-3)
    opt = make_optimizer(cfg, axes)
    svc = base.AsyncPrecondService(opt, cfg)
    step = jax.jit(opt.update, static_argnums=(5,))
    p, s = params, opt.init(params)

    def one(t, scale, shift=False):
        nonlocal p, s
        drift = float(base.precond_drift(s))
        s = svc.step_begin(s, t, jax.random.fold_in(key, t), drift=drift)
        g = _grad_stream(key, params, 0 if not shift else t, scale=scale)
        p, s = step(g, s, p, t, jax.random.PRNGKey(42), False)

    # warmup: bootstrap + the early refreshes while rnorm settles
    for t in range(12):
        one(t, 0.1)
    settled = dict(svc.counters)
    # stationary phase: identical gradient every step -> momentum is at
    # its fixed point, dnorm accrues ~0 -> no triggers
    for t in range(12, 24):
        one(t, 0.1)
    assert svc.counters["refreshes"] == settled["refreshes"], \
        (settled, svc.counters)
    assert svc.counters["clock_triggered"] == 0
    # adversarial shift: fresh large gradients every step
    for t in range(24, 30):
        one(t, 3.0, shift=True)
    assert svc.counters["drift_triggered"] > settled["drift_triggered"], \
        (settled, svc.counters)
    assert svc.counters["clock_triggered"] == 0
    assert svc.matfn_telemetry["last_drift"] >= 0.0


# ------------------------------------------------- sharding rules

def test_pending_twin_shardings_match_active():
    """opt_state_shardings gives every pending twin the SAME sharding as
    its active buffer (the swap is then a local per-shard select) and
    replicates the pending_at scalar."""
    from repro.launch.mesh import compat_make_mesh
    from repro.launch.sharding import replicated
    from repro.train.state import opt_state_shardings

    key = jax.random.PRNGKey(4)
    params, axes = _tree(key)
    cfg = _async_cfg("muon", matfn_tol=1e-2, precond_drift_slack=2.0)
    opt = make_optimizer(cfg, axes)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    shapes = jax.eval_shape(lambda: params)
    pshard = jax.tree.map(lambda _: replicated(mesh), params)
    sh = opt_state_shardings(mesh, opt, shapes, pshard)
    assert sh["pending_at"] == replicated(mesh)
    assert sh["count"] == replicated(mesh)
    for slot in base._flat_slots(sh["leaves"])[0]:
        if "ortho" in slot:
            assert slot["ortho_p"] == slot["ortho"]
            assert slot["dnorm"] == replicated(mesh)
            assert slot["rnorm"] == replicated(mesh)


SHARDED_SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.config import OptimizerConfig, PrismConfig
    from repro.launch.mesh import compat_make_mesh
    from repro.optim import base, make_optimizer
    from repro.sharding_ctx import activation_sharding

    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (64, 32)),
              "w3": jax.random.normal(jax.random.fold_in(key, 2),
                                      (3, 48, 32)),
              "b": jax.random.normal(jax.random.fold_in(key, 4), (64,))}
    axes = {"w1": ("embed", "mlp"), "w3": ("layers", "embed", "mlp"),
            "b": ("embed",)}
    cfg = OptimizerConfig(name="muon", learning_rate=0.05,
                          precond_every=3, precond_async=True,
                          precond_swap_delay=1,
                          prism=PrismConfig(degree=2, iterations=4,
                                            warm_alpha_iters=1,
                                            sketch_dim=8))
    opt = make_optimizer(cfg, axes)

    def run(mesh_ctx):
        svc = base.AsyncPrecondService(opt, cfg)
        step = jax.jit(opt.update, static_argnums=(5,))
        p, s = params, opt.init(params)
        with mesh_ctx() if mesh_ctx else _null():
            for t in range(7):
                s = svc.step_begin(
                    s, t, jax.random.fold_in(jax.random.PRNGKey(7), t),
                    drift=float(base.precond_drift(s)))
                g = jax.tree.map(
                    lambda q: 0.1 * jax.random.normal(
                        jax.random.fold_in(jax.random.fold_in(key, t),
                                           q.size), q.shape), params)
                p, s = step(g, s, p, t, jax.random.PRNGKey(t), False)
        return p, svc

    from contextlib import contextmanager
    @contextmanager
    def _null():
        yield

    p_ref, _ = run(None)
    mesh = compat_make_mesh((4, 2), ("data", "model"))

    @contextmanager
    def sharded():
        with mesh, activation_sharding(
                mesh, {"opt_layers": "model", "opt_rows": "data"}):
            yield

    p_sh, svc = run(sharded)
    assert svc.counters["refreshes"] >= 3, svc.counters
    for k in params:
        np.testing.assert_allclose(np.asarray(p_ref[k]),
                                   np.asarray(p_sh[k]),
                                   rtol=2e-5, atol=2e-5)
    print("ASYNC_SHARDED_OK")
""")


def test_sharded_double_buffer_parity_8dev():
    """Async double-buffered Muon under the 8-device (data, model) mesh
    equals the replicated run: pending twins shard like their active
    halves, the swap is a local select, and the sharded refresh program
    produces the same polars (§8 parity through the §12 plane)."""
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert "ASYNC_SHARDED_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-3000:]


# ------------------------------------------------------- trainer smoke

def test_trainer_async_run(tmp_path):
    """End-to-end async training: loss finite and decreasing, the service
    refreshes on its schedule, and precond_drift rides in the metrics."""
    from repro.configs import get_smoke_config
    from repro.data import DataConfig
    from repro.models import build
    from repro.train import Trainer

    cfg = get_smoke_config("gpt2-paper")
    model = build(cfg)
    ocfg = OptimizerConfig(
        name="muon", learning_rate=0.02, precond_every=3,
        precond_async=True, precond_swap_delay=1,
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=3,
                          sketch_dim=8))
    tcfg = TrainConfig(steps=10, checkpoint_dir=str(tmp_path),
                       checkpoint_every=5, log_every=100,
                       async_checkpoint=False)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=4, markov_rank=8)
    seen = {}
    tr = Trainer(model, ocfg, tcfg, dcfg)
    _, opt_state, losses = tr.run(
        on_metrics=lambda t, m: seen.update(m))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert "precond_drift" in seen
    tele = tr.matfn_telemetry
    assert tele["bootstrap"] == 1 and tele["refreshes"] >= 3, tele
    # checkpoints exclude the pending payloads
    from repro import checkpoint as ckpt
    step = ckpt.latest_step(str(tmp_path))
    data = np.load(os.path.join(str(tmp_path), f"step_{step:08d}",
                                "tree.npz"))
    assert not any(base.PENDING_STATE_KEYS.intersection(k.split("|"))
                   for k in data.files)
    # ...but pending_at itself IS saved (it is cleared on restore)
    assert any(k.endswith("pending_at") for k in data.files)
