"""Lowrank sketched orthogonalization tier (DESIGN.md §14): rangefinder
and subspace-polar numerics against the SVD top-k oracle, trace-time tier
planning, Muon routing of embedding/LM-head leaves, and the §12
zero-matfn-launch contract with the tier enabled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, PrismConfig
from repro.core import lowrank
from repro.optim import base, bucketing, make_optimizer

_PCFG = PrismConfig(degree=2, iterations=30, warm_alpha_iters=2,
                    sketch_dim=8, tol=1e-6)


def _rank_l_matrix(key, m, n, l):
    """rank(A) == l with a well-separated spectrum: every sketched
    direction is genuine, so polar_lowrank must match the oracle to
    NS-convergence precision (see the module docstring's caveat)."""
    return jax.random.normal(key, (m, l)) @ \
        jax.random.normal(jax.random.fold_in(key, 1), (l, n))


# --------------------------------------------------------------- numerics

def test_rangefinder_orthonormal_and_captures_range(key):
    A = _rank_l_matrix(key, 96, 24, 4)
    Q = lowrank.rangefinder(A, 8, key, cfg=_PCFG)
    assert Q.shape == (96, 8)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(8), atol=1e-4)
    # range capture: projecting onto span(Q) preserves A
    np.testing.assert_allclose(np.asarray(Q @ (Q.T @ A)), np.asarray(A),
                               atol=1e-3)


def test_polar_lowrank_matches_svd_topk_oracle(key):
    l = 8
    A = _rank_l_matrix(key, 96, 24, l)
    O = lowrank.polar_lowrank(A, rank=4, oversample=4, cfg=_PCFG, key=key)
    oracle = lowrank.svd_topk(A, l)
    np.testing.assert_allclose(np.asarray(O), np.asarray(oracle),
                               atol=1e-4)


def test_polar_lowrank_wide_and_batched(key):
    """Orientation equivariance (wide views transpose through) and
    broadcasting over lead dims, with the §11 iters telemetry."""
    l = 8
    A = jnp.stack([_rank_l_matrix(jax.random.fold_in(key, i), 24, 96, l)
                   for i in range(3)])
    O, iters = lowrank.polar_lowrank(A, rank=4, oversample=4, cfg=_PCFG,
                                     key=key, return_iters=True)
    assert O.shape == (3, 24, 96) and iters.shape == (3,)
    assert int(iters.min()) >= 1
    for i in range(3):
        np.testing.assert_allclose(np.asarray(O[i]),
                                   np.asarray(lowrank.svd_topk(A[i], l)),
                                   atol=1e-4)


def test_power_iters_sharpen_subspace_capture(key):
    """On a decaying spectrum the power-refined basis aligns the top-k
    block with the oracle orders of magnitude tighter than the plain
    sketch."""
    m, n, k = 256, 64, 16
    U, _ = jnp.linalg.qr(jax.random.normal(key, (m, n)))
    V, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 2),
                                           (n, n)))
    s = jnp.concatenate([jnp.linspace(10.0, 5.0, k),
                         0.05 * jnp.ones(n - k)])
    A = (U * s) @ V.T
    Pk = np.asarray(U[:, :k] @ U[:, :k].T)
    oracle = np.asarray(lowrank.svd_topk(A, k + 8))

    def topk_err(power_iters):
        O = lowrank.polar_lowrank(A, rank=k, oversample=8, cfg=_PCFG,
                                  key=key, power_iters=power_iters)
        return np.linalg.norm(Pk @ (np.asarray(O) - oracle)) / \
            np.linalg.norm(Pk @ oracle)

    e0, e1 = topk_err(0), topk_err(1)
    assert e1 < 1e-4, (e0, e1)
    assert e1 < e0 / 10, (e0, e1)


# ---------------------------------------------------------------- planner

def _ocfg(**kw):
    kw.setdefault("lowrank_rank", 16)
    kw.setdefault("lowrank_oversample", 8)
    kw.setdefault("prism", PrismConfig(degree=2, iterations=6,
                                       warm_alpha_iters=1, sketch_dim=8))
    return OptimizerConfig(name="muon", matfn_tol=1e-4, **kw)


def test_planner_tier_selection():
    cfg = _ocfg(lowrank_max_dim=1024)
    # over max_dim -> lowrank, l = rank + oversample
    assert bucketing.resolve_lowrank_tier(cfg, (768, 50257)) == 24
    assert bucketing.resolve_tier(cfg, (768, 50257)) == "lowrank"
    # small square -> cubic tiers
    assert bucketing.resolve_lowrank_tier(cfg, (64, 64)) is None
    assert bucketing.resolve_tier(cfg, (64, 64)) == "grid"
    # aspect-ratio trigger below max_dim (256 == 4.0 * 64)
    assert bucketing.resolve_tier(cfg, (64, 256)) == "lowrank"
    assert bucketing.resolve_tier(cfg, (64, 255)) == "grid"


def test_planner_degrades_to_exact_tiers():
    # disabled by default
    assert bucketing.resolve_lowrank_tier(
        _ocfg(lowrank_rank=0), (768, 50257)) is None
    # l >= min(m, n): no strict subspace -> cubic
    assert bucketing.resolve_lowrank_tier(
        _ocfg(lowrank_rank=60, lowrank_max_dim=128), (64, 512)) is None
    # non-NS matfn family: the subspace chain needs the NS polar
    cfg = OptimizerConfig(name="muon", matfn_method="polar_express")
    assert bucketing.resolve_lowrank_tier(cfg, (768, 50257)) is None
    # modeled-FLOPs win guard: mild aspect + l near min dim loses
    cfg = _ocfg(lowrank_rank=48, lowrank_oversample=8, lowrank_max_dim=64,
                lowrank_aspect=1.5)
    assert bucketing.resolve_lowrank_tier(cfg, (128, 64)) is None


def test_lowrank_flops_model_beats_cubic_at_4x_aspect():
    from repro.kernels import ops as kops

    for n in (64, 256, 1024):
        m = 4 * n
        lo = kops.lowrank_polar_flops((m, n), 24, iters=7)
        cu = kops.polar_flops((m, n), iters=7)
        assert lo < cu, (n, lo, cu)
        assert kops.lowrank_polar_hbm_bytes(
            (m, n), 24, jnp.dtype(jnp.bfloat16), iters=7) < \
            kops.polar_hbm_bytes((m, n), jnp.dtype(jnp.bfloat16), iters=7)


def test_config_validation():
    with pytest.raises(ValueError):
        OptimizerConfig(lowrank_rank=-1)
    with pytest.raises(ValueError):
        OptimizerConfig(lowrank_aspect=0.5)
    with pytest.raises(ValueError):
        OptimizerConfig(lowrank_rank=16, matfn_method="polar_express")


# -------------------------------------------------------- bucketed engine

def test_bucketed_engine_routes_lowrank(key):
    """polar_bucketed dispatches a triggering bucket through the sketched
    path — result matches a direct polar_lowrank call — while the
    non-triggering bucket keeps the exact cubic result."""
    cfg = _ocfg(lowrank_rank=4, lowrank_oversample=4, lowrank_max_dim=64)
    views = [_rank_l_matrix(key, 96, 24, 8),            # aspect 4: lowrank
             jax.random.normal(jax.random.fold_in(key, 9), (24, 24))]
    outs, iters, statuses = bucketing.polar_bucketed(views, cfg, key,
                                           with_iters=True)
    direct = lowrank.polar_lowrank(
        views[0], 4, 4, cfg=cfg.resolved_prism,
        key=jax.random.fold_in(key, 1), method="prism")
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)
    from repro.core import matfn
    exact = matfn.polar(views[1], method="prism", cfg=cfg.resolved_prism,
                        key=jax.random.fold_in(key, 0))
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(exact),
                               rtol=2e-5, atol=2e-5)
    assert iters[0].shape == () and int(iters[0]) >= 1


# --------------------------------------------------------- muon routing

def _muon_setup(ocfg, arch="gpt2-paper"):
    from repro.configs import get_smoke_config
    from repro.models import build

    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(ocfg, model.logical_axes())
    return cfg, model, params, opt


def test_muon_routes_embedding_through_lowrank():
    """With the tier enabled the vocab leaves leave the AdamW fallback:
    their state carries momentum + the planner-resolved tier telemetry
    (lowrank for the smoke model's (64, 256) embedding view), and a step
    produces finite, loss-reducing updates."""
    ocfg = _ocfg(learning_rate=0.02, lowrank_max_dim=1024,
                 prism=PrismConfig(degree=2, iterations=6,
                                   warm_alpha_iters=1, sketch_dim=8))
    cfg, model, params, opt = _muon_setup(ocfg)
    state = opt.init(params)
    emb = state["leaves"]["embed"]
    assert "nu" not in emb and "mom" in emb          # Muon, not AdamW
    assert int(emb["tier"]) == bucketing.TIER_CODES["lowrank"]
    assert int(state["leaves"]["head"]["tier"]) == \
        bucketing.TIER_CODES["lowrank"]
    # square-ish views stay on the cubic tiers
    assert int(state["leaves"]["layers"]["mlp"]["w_up"]["tier"]) == \
        bucketing.TIER_CODES["grid"]

    from repro.data import DataConfig, make_batch_fn
    batch_fn = make_batch_fn(cfg, DataConfig(vocab_size=cfg.vocab_size,
                                             seq_len=32, global_batch=8,
                                             markov_rank=8))

    @jax.jit
    def step_fn(p, s, t):
        batch = batch_fn(t)
        (loss, _), grads = jax.value_and_grad(
            lambda q: model.loss(q, batch), has_aux=True)(p)
        grads, _ = base.clip_by_global_norm(grads, 1.0)
        p, s = opt.update(grads, s, p, t, jax.random.PRNGKey(7))
        return p, s, loss

    losses = []
    for t in range(6):
        params, state, loss = step_fn(params, state, jnp.asarray(t))
        losses.append(float(loss))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0], losses
    # the update applied to the embedding is nonzero (it trains)
    assert float(jnp.abs(state["leaves"]["embed"]["mom"]).max()) > 0


def test_muon_without_lowrank_keeps_adamw_fallback():
    ocfg = OptimizerConfig(name="muon",
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=1,
                                             sketch_dim=8))
    _, _, params, opt = _muon_setup(ocfg)
    state = opt.init(params)
    emb = state["leaves"]["embed"]
    assert "nu" in emb and "tier" not in emb


def test_lowrank_stale_cache_and_async_state():
    """§12/§9 composition: lowrank-routed leaves carry the LIFTED
    full-view ortho caches (cache dtype, pending twin included), so the
    staleness and async planes treat the tier like any other."""
    ocfg = _ocfg(learning_rate=0.02, precond_every=4, precond_async=True,
                 precond_cache_dtype="bfloat16",
                 prism=PrismConfig(degree=2, iterations=3,
                                   warm_alpha_iters=1, sketch_dim=8))
    _, _, params, opt = _muon_setup(ocfg)
    state = opt.init(params)
    emb = state["leaves"]["embed"]
    assert emb["ortho"].shape == (64, 256)            # lifted view shape
    assert emb["ortho"].dtype == jnp.bfloat16
    assert emb["ortho_p"].shape == (64, 256)
    # the refresh plane fills the pending cache through the lowrank tier
    parts = base.install_pending(
        state, opt.refresh(state, jax.random.PRNGKey(1)), at_step=0)
    pend = parts["leaves"]["embed"]["ortho_p"]
    assert bool(jnp.all(jnp.isfinite(pend.astype(jnp.float32))))


def test_steady_state_zero_launches_with_lowrank(monkeypatch):
    """The §12 contract survives the §14 tier: an async trainer step
    with embedding leaves routed lowrank compiles with ZERO matfn kernel
    launches; the refresh program carries them all."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, make_batch_fn
    from repro.kernels import ops
    from repro.models import build
    from repro.train.state import make_train_step, master_params

    key = jax.random.PRNGKey(2)
    cfg = get_smoke_config("gpt2-paper").replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128)
    model = build(cfg)
    ocfg = _ocfg(lowrank_rank=8, lowrank_oversample=4,
                 lowrank_max_dim=128, precond_every=4, precond_async=True,
                 prism=PrismConfig(degree=2, iterations=2,
                                   warm_alpha_iters=1, sketch_dim=8,
                                   use_kernels=True))
    opt = make_optimizer(ocfg, model.logical_axes())
    step_fn = make_train_step(model, opt, ocfg)
    params = master_params(model.init(key))
    state = opt.init(params)
    assert int(state["leaves"]["embed"]["tier"]) == \
        bucketing.TIER_CODES["lowrank"]
    batch = make_batch_fn(cfg, DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=16, global_batch=2,
                                          markov_rank=8))(jnp.asarray(0))
    step = jnp.asarray(0, jnp.int32)
    n = ops.count_launches(
        lambda p, st, b: step_fn(p, st, b, step, False), params, state,
        batch)
    assert n == 0, n
    n_refresh = ops.count_launches(lambda s: opt.refresh(s, key), state)
    assert n_refresh > 0, n_refresh
