"""Mesh-sharded preconditioner engine + staleness-scheduled refresh
(DESIGN.md §8).

Multi-device coverage runs in a subprocess on an 8-CPU-device host mesh
(same pattern as test_sharded_train.py — the main test world stays
single-device); the staleness-cache semantics are single-device and run
in-process.  No hypothesis usage — these are example-based tests, so the
suite collects without it (tests/conftest.py gates the property tests).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro.config import OptimizerConfig, PrismConfig
from repro.optim import make_optimizer

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    # pin CPU BEFORE jax imports: with libtpu in the image an unset
    # JAX_PLATFORMS makes jax probe the TPU metadata server for minutes
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.config import OptimizerConfig, PrismConfig
    from repro.launch.mesh import compat_make_mesh
    from repro.launch import sharding as sh
    from repro.optim import bucketing, make_optimizer
    from repro.sharding_ctx import activation_sharding

    key = jax.random.PRNGKey(0)
    # one bucket with B = 3 + 1 + 1 + 1 = 6 — does NOT divide the 4-way
    # data axis (uneven split: pads to 8 with identity slices), one
    # square bucket, and one pad-to-bucket merge exercising the sharded
    # n_real trace-correction path
    shapes = [(3, 64, 32), (64, 32), (64, 32), (64, 32), (48, 48),
              (48, 44)]
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate(shapes)]
    cfg = OptimizerConfig(prism=PrismConfig(degree=2, iterations=6,
                                            warm_alpha_iters=1,
                                            sketch_dim=8),
                          bucket_pad=True)
    # replicated reference: no sharding context installed
    ref = bucketing.polar_bucketed(views, cfg, key)
    mesh = compat_make_mesh((4, 2), ("data", "model"))
    with mesh, activation_sharding(
            mesh, {"opt_layers": "model", "opt_rows": "data"}):
        mm, ax = bucketing.mesh_batch_axes(cfg)
        assert mm is mesh and ax == ("data",), (mm, ax)
        out = jax.jit(
            lambda vs: bucketing.polar_bucketed(vs, cfg, key))(views)
    for r, o in zip(ref, out):
        np.testing.assert_allclose(np.asarray(r), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)

    # optimizer-level parity: a full Muon update under the mesh equals
    # the single-device update (same inputs, same key)
    params = {"w": views[0], "v": views[4], "b": jnp.ones((64,))}
    axes_tree = {"w": ("layers", "embed", "mlp"), "v": ("embed", "mlp"),
                 "b": ("embed",)}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 77), p.shape),
        params)
    ocfg = OptimizerConfig(name="muon", learning_rate=0.05,
                           prism=PrismConfig(degree=2, iterations=5,
                                             warm_alpha_iters=1,
                                             sketch_dim=8))
    opt = make_optimizer(ocfg, axes_tree)
    p_ref, _ = jax.jit(opt.update)(grads, opt.init(params), params, 0, key)
    with mesh, activation_sharding(
            mesh, {"opt_layers": "model", "opt_rows": "data"}):
        p_sh, _ = jax.jit(opt.update)(grads, opt.init(params), params, 0,
                                      key)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_ref[k]),
                                   np.asarray(p_sh[k]),
                                   rtol=2e-5, atol=2e-5)

    # shampoo inverse-root path through the sharded transform_bucketed
    socfg = OptimizerConfig(name="shampoo", learning_rate=1e-3,
                            max_precond_dim=256,
                            prism=PrismConfig(degree=2, iterations=8,
                                              sketch_dim=8))
    sopt = make_optimizer(socfg, axes_tree)
    sp_ref, _ = jax.jit(sopt.update)(grads, sopt.init(params), params, 0,
                                     key)
    with mesh, activation_sharding(
            mesh, {"opt_layers": "model", "opt_rows": "data"}):
        sp_sh, _ = jax.jit(sopt.update)(grads, sopt.init(params), params,
                                        0, key)
    for k in params:
        np.testing.assert_allclose(np.asarray(sp_ref[k]),
                                   np.asarray(sp_sh[k]),
                                   rtol=2e-5, atol=2e-5)

    # mixed-precision engine on the sharded path (DESIGN.md §9): batch-dim
    # sharding is per-slice math in ANY dtype, so the bf16 policy keeps
    # sharded == replicated — same uneven-B bucket zoo, identity-slice
    # padding now in bf16.  Tolerance is a few bf16 ulps (2^-8), NOT
    # fp32-tight: jit-vs-eager fusion boundaries can move the fp32->bf16
    # rounding point, and the contractive chains keep such one-ulp
    # perturbations from growing.
    cfg16 = OptimizerConfig(prism=PrismConfig(degree=2, iterations=6,
                                              warm_alpha_iters=1,
                                              sketch_dim=8),
                            matfn_dtype="bfloat16", bucket_pad=True)
    ref16 = bucketing.polar_bucketed(views, cfg16, key)
    assert all(o.dtype == jnp.bfloat16 for o in ref16)
    with mesh, activation_sharding(
            mesh, {"opt_layers": "model", "opt_rows": "data"}):
        out16 = jax.jit(
            lambda vs: bucketing.polar_bucketed(vs, cfg16, key))(views)
    for r, o in zip(ref16, out16):
        np.testing.assert_allclose(np.asarray(r, np.float32),
                                   np.asarray(o, np.float32),
                                   rtol=0.05, atol=1.5 * 2.0 ** -8)

    # full Muon + Shampoo steps under the bf16 policy, sharded vs
    # replicated (bf16 staleness-cache state included via precond_every),
    # compared norm-level on the applied UPDATE — per-element checks are
    # brittle where grafting/aspect scaling amplifies one-ulp bf16 chain
    # divergence on isolated entries.  Muon runs the original mixed tree
    # (polar is well-conditioned: bf16-ulp-level parity).  Shampoo's
    # leaves get a controlled full-rank gradient spectrum instead: the
    # inverse root of a step-0 EMA factor G G^T of a WIDE G is rank-
    # deficient (eps-ridge cond ~1e6), where the principled u*kappa bf16
    # tolerance is vacuous — sharding of that case is already covered
    # tightly by the fp32 parity above; precision is the only new
    # variable here, tested where kappa keeps u*sqrt(kappa) meaningful.
    from repro.core import random_matrices as rm
    sq_sig = jnp.exp(jnp.linspace(jnp.log(0.3), 0.0, 48))
    sq_params = {"a": views[4], "c": jnp.ones((64,))}
    sq_axes = {"a": ("embed", "mlp"), "c": ("embed",)}
    sq_grads = {"a": rm.with_spectrum(jax.random.fold_in(key, 5), 48, 48,
                                      sq_sig),
                "c": jnp.ones((64,))}
    cases = (("muon", 0.05, params, axes_tree, grads, 2e-2),
             ("shampoo", 1e-3, sq_params, sq_axes, sq_grads, 5e-2))
    for name, lr, prms, axs, grds, tol in cases:
        ocfg16 = OptimizerConfig(name=name, learning_rate=lr,
                                 max_precond_dim=256,
                                 matfn_dtype="bfloat16", precond_every=2,
                                 prism=PrismConfig(degree=2, iterations=5,
                                                   warm_alpha_iters=1,
                                                   sketch_dim=8))
        o16 = make_optimizer(ocfg16, axs)
        q_ref, s_ref = jax.jit(o16.update)(grds, o16.init(prms), prms,
                                           0, key)
        with mesh, activation_sharding(
                mesh, {"opt_layers": "model", "opt_rows": "data"}):
            q_sh, s_sh = jax.jit(o16.update)(grds, o16.init(prms),
                                             prms, 0, key)
        for k in prms:
            d_ref = np.asarray(q_ref[k], np.float32) - np.asarray(prms[k])
            d_sh = np.asarray(q_sh[k], np.float32) - np.asarray(prms[k])
            rel = np.linalg.norm(d_ref - d_sh) / max(
                np.linalg.norm(d_ref), 1e-12)
            assert rel < tol, (name, k, rel)
    print("SHARDED_PRECOND_OK")
""")


def test_sharded_parity_8dev():
    """Sharded == replicated bucketed PRISM on an 8-device host mesh,
    including a bucket whose B does not divide the device count."""
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert "SHARDED_PRECOND_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-3000:]


INT8_PSUM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import compat_make_mesh
    from repro.optim import compression
    from repro.sharding_ctx import compat_shard_map
    from jax.sharding import PartitionSpec as P

    mesh = compat_make_mesh((8,), ("data",))
    key = jax.random.PRNGKey(3)
    # per-shard distinct magnitudes: exercises the shared-max-scale
    # renormalization (ratio < 1 on 7 of 8 shards), odd length 1000
    # exercises the block pad
    x = jax.random.normal(key, (8, 1000)) * \\
        (10.0 ** jnp.arange(8)[:, None] / 1e3)

    def quantized(xs):
        return compression.int8_psum(xs, "data")

    def exact(xs):
        return jax.lax.psum(xs, "data")

    run_q, run_f = [compat_shard_map(
        f, mesh=mesh, in_specs=(P("data", None),),
        out_specs=P("data", None))(x)
        for f in (quantized, exact)]
    # every shard returns the same reduced vector; quantization error is
    # bounded by half an int8 step of the LARGEST shard's block scale,
    # times the 8 contributions
    ref = np.asarray(run_f[0])
    step = np.abs(np.asarray(x)).max() / 127.0
    assert np.abs(np.asarray(run_q[0]) - ref).max() <= 8 * step, \\
        (np.abs(np.asarray(run_q[0]) - ref).max(), step)

    # collective census: the fixed int8_psum moves exactly ONE full-size
    # int32 psum (the payload) and ONE fp32 pmax (the [-,1] scale
    # column) — the dead second all-reduce stays dead
    def collectives(jaxpr, out):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in ("psum", "pmax", "pmin", "ppermute",
                                      "all_reduce", "psum2"):
                out.append((eqn.primitive.name,
                            eqn.invars[0].aval.dtype.name))
            for v in eqn.params.values():
                for j in (v if isinstance(v, (list, tuple)) else [v]):
                    if hasattr(j, "jaxpr"):      # ClosedJaxpr
                        collectives(j.jaxpr, out)
                    elif hasattr(j, "eqns"):     # raw Jaxpr
                        collectives(j, out)
        return out

    jaxpr = jax.make_jaxpr(compat_shard_map(
        quantized, mesh=mesh, in_specs=(P("data", None),),
        out_specs=P("data", None)))(x)
    seen = collectives(jaxpr.jaxpr, [])
    psums = [d for (n, d) in seen if n.startswith("psum")]
    pmaxs = [d for (n, d) in seen if n == "pmax"]
    assert psums == ["int32"], seen
    assert pmaxs == ["float32"], seen
    print("INT8_PSUM_OK")
""")


def test_int8_psum_parity_and_collective_census_8dev():
    """int8_psum on an 8-device mesh: matches the fp32 psum within
    quantization error, and its jaxpr contains exactly one int32 psum
    plus one fp32 pmax (regression for the dead duplicate all-reduce)."""
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", INT8_PSUM_SCRIPT],
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert "INT8_PSUM_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-3000:]


# ------------------------------------------------------------- staleness


def _tree(key):
    params = {"w1": jax.random.normal(key, (64, 32)),
              "w3": jax.random.normal(jax.random.fold_in(key, 2),
                                      (3, 48, 32)),
              "b": jax.random.normal(jax.random.fold_in(key, 4), (64,))}
    axes = {"w1": ("embed", "mlp"), "w3": ("layers", "embed", "mlp"),
            "b": ("embed",)}
    return params, axes


def test_staleness_reuses_cache_and_refreshes_on_K():
    """precond_every=K serves the cached orthogonalized update for K-1
    steps (cache bit-identical, update direction unchanged) and refreshes
    exactly on step K."""
    key = jax.random.PRNGKey(0)
    params, axes = _tree(key)
    ocfg = OptimizerConfig(name="muon", learning_rate=0.1,
                           weight_decay=0.0, precond_every=3,
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=1,
                                             sketch_dim=8))
    opt = make_optimizer(ocfg, axes)
    state = opt.init(params)
    assert "ortho" in state["leaves"]["w1"]  # cache carried in state
    upd = jax.jit(opt.update)
    p = params
    deltas, orthos = [], []
    for t in range(4):
        g = jax.tree.map(
            lambda x: jax.random.normal(jax.random.fold_in(key, 50 + t),
                                        x.shape), p)
        p2, state = upd(g, state, p, t, jax.random.fold_in(key, t))
        deltas.append(np.asarray(p["w1"]) - np.asarray(p2["w1"]))
        orthos.append(np.asarray(state["leaves"]["w1"]["ortho"]))
        p = p2
    # steps 1, 2 (count % 3 != 0): cache bit-identical to the step-0 fill
    assert np.array_equal(orthos[0], orthos[1])
    assert np.array_equal(orthos[1], orthos[2])
    # update direction unchanged while stale (lr * scale * O_cached)
    np.testing.assert_allclose(deltas[0], deltas[1], atol=1e-6)
    np.testing.assert_allclose(deltas[1], deltas[2], atol=1e-6)
    # step 3 (count % 3 == 0): refresh — new momentum orthogonalized
    assert not np.array_equal(orthos[2], orthos[3])
    assert np.abs(deltas[3] - deltas[2]).max() > 1e-4


def test_static_refresh_matches_dynamic_schedule():
    """update(..., refresh=<bool>) picks the same branch the in-state
    count schedule would — params and caches agree step for step."""
    key = jax.random.PRNGKey(1)
    params, axes = _tree(key)
    ocfg = OptimizerConfig(name="muon", learning_rate=0.1,
                           weight_decay=0.0, precond_every=2,
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=1,
                                             sketch_dim=8))
    opt = make_optimizer(ocfg, axes)
    upd = jax.jit(opt.update, static_argnums=(5,))
    grads = [jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 30 + t),
                                    x.shape), params) for t in range(3)]
    outs = {}
    for mode in ("dynamic", "static"):
        p, s = params, opt.init(params)
        for t in range(3):
            refresh = None if mode == "dynamic" else (t % 2 == 0)
            p, s = upd(grads[t], s, p, t, jax.random.fold_in(key, t),
                       refresh)
        outs[mode] = (p, s)
    for k in params:
        np.testing.assert_allclose(np.asarray(outs["dynamic"][0][k]),
                                   np.asarray(outs["static"][0][k]),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(outs["dynamic"][1]["leaves"]["w1"]["ortho"]),
        np.asarray(outs["static"][1]["leaves"]["w1"]["ortho"]),
        rtol=1e-6, atol=1e-6)


def test_shampoo_static_refresh_matches_dynamic():
    key = jax.random.PRNGKey(2)
    params, axes = _tree(key)
    ocfg = OptimizerConfig(name="shampoo", learning_rate=1e-3,
                           precond_every=2, max_precond_dim=256,
                           prism=PrismConfig(degree=2, iterations=8,
                                             sketch_dim=8))
    opt = make_optimizer(ocfg, axes)
    upd = jax.jit(opt.update, static_argnums=(5,))
    grads = [jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 40 + t),
                                    x.shape), params) for t in range(3)]
    outs = {}
    for mode in ("dynamic", "static"):
        p, s = params, opt.init(params)
        for t in range(3):
            refresh = None if mode == "dynamic" else (t % 2 == 0)
            p, s = upd(grads[t], s, p, t, jax.random.fold_in(key, t),
                       refresh)
        outs[mode] = p
    for k in params:
        np.testing.assert_allclose(np.asarray(outs["dynamic"][k]),
                                   np.asarray(outs["static"][k]),
                                   rtol=1e-6, atol=1e-6)
