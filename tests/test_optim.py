"""Optimizer tests: Muon/Shampoo/AdamW reduce loss on a real model;
matrix-view plumbing; compression roundtrip properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()


from repro.config import OptimizerConfig, PrismConfig
from repro.configs import get_smoke_config
from repro.data import DataConfig, make_batch_fn
from repro.models import build
from repro.optim import base, compression, make_optimizer


def _train(arch, ocfg, steps=12, seed=0):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = make_optimizer(ocfg, model.logical_axes())
    state = opt.init(params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      markov_rank=8)
    batch_fn = make_batch_fn(cfg, dcfg)

    @jax.jit
    def step_fn(params, state, step):
        batch = batch_fn(step)
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        grads, _ = base.clip_by_global_norm(grads, ocfg.grad_clip_norm)
        params, state = opt.update(grads, state, params, step,
                                   jax.random.fold_in(
                                       jax.random.PRNGKey(7), step))
        return params, state, loss

    losses = []
    for t in range(steps):
        params, state, loss = step_fn(params, state, jnp.asarray(t))
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("name,method", [
    ("muon", "prism"),
    ("muon", "polar_express"),
    ("muon", "newton_schulz"),
    ("adamw", None),
])
def test_optimizers_reduce_loss(name, method):
    ocfg = OptimizerConfig(
        name=name, learning_rate=0.02 if name == "muon" else 3e-3,
        matfn_method=method or "prism",
        prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=1,
                          sketch_dim=8))
    losses = _train("gpt2-paper", ocfg, steps=12)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.05, losses


@pytest.mark.parametrize("method", ["prism", "eigh"])
def test_shampoo_reduces_loss(method):
    ocfg = OptimizerConfig(
        name="shampoo", learning_rate=1e-3, matfn_method=method,
        precondition_every=2, max_precond_dim=512,
        prism=PrismConfig(degree=2, iterations=10, sketch_dim=8))
    losses = _train("gpt2-paper", ocfg, steps=12)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.05, losses


def test_muon_on_moe_arch():
    ocfg = OptimizerConfig(name="muon", learning_rate=0.02,
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=3))
    losses = _train("granite-moe-1b-a400m", ocfg, steps=8)
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]


def test_muon_on_ssm_arch():
    """PRISM/Muon applies to the attention-free arch too (optimizer-level)."""
    ocfg = OptimizerConfig(name="muon", learning_rate=0.02,
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=3))
    losses = _train("falcon-mamba-7b", ocfg, steps=8)
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]


# ---------------------------------------------------------------- plumbing

def test_matrix_view_roundtrip(key):
    p = jax.random.normal(key, (3, 8, 4, 16))  # [L, d, h, hd]
    axes = ("layers", "embed", "heads", "head_dim")
    M, meta = base.to_matrix_view(p, axes)
    assert M.shape == (3, 8, 64)
    back = base.from_matrix_view(M, meta)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(p))


def test_matrix_view_embed_last(key):
    p = jax.random.normal(key, (3, 4, 16, 8))  # wo: [L, h, hd, d]
    axes = ("layers", "heads", "head_dim", "embed")
    M, meta = base.to_matrix_view(p, axes)
    assert M.shape == (3, 8, 64)  # embed rows
    back = base.from_matrix_view(M, meta)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(p))


def test_is_matrix_param():
    assert base.is_matrix_param(("embed", "mlp"), (64, 128))
    assert not base.is_matrix_param(("vocab", "embed"), (1000, 64))
    assert not base.is_matrix_param(("embed",), (64,))
    assert not base.is_matrix_param((None, "mlp"), (4, 128))  # conv kernel
    assert base.is_matrix_param(("experts", "embed", "expert_mlp"),
                                (8, 64, 32))


def test_muon_orthogonalizes_update(key):
    """The muon update direction must be (approximately) orthogonal."""
    from repro.core import matfn

    ocfg = OptimizerConfig(name="muon", learning_rate=0.1,
                           prism=PrismConfig(degree=2, iterations=8))
    params = {"w": jax.random.normal(key, (64, 32))}
    axes = {"w": ("embed", "mlp")}
    opt = make_optimizer(ocfg, axes)
    state = opt.init(params)
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1), (64, 32))}
    new_p, _ = opt.update(grads, state, params, 0, key)
    upd = (np.asarray(params["w"], np.float32)
           * (1 - 0.1 * ocfg.weight_decay)
           - np.asarray(new_p["w"], np.float32)) / 0.1
    scale = np.sqrt(max(1.0, 64 / 32))
    utu = upd.T @ upd / scale ** 2
    np.testing.assert_allclose(utu, np.eye(32), atol=5e-2)


# ---------------------------------------------------------------- compression

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4000), st.floats(0.01, 100.0))
def test_int8_roundtrip_error_bound(n, scale):
    x = jnp.asarray(np.random.RandomState(n).randn(n) * scale,
                    jnp.float32)
    y = compression.int8_roundtrip_leaf(x)
    blk_max = float(jnp.max(jnp.abs(x)))
    # blockwise quantization error <= half step of the worst block
    assert float(jnp.max(jnp.abs(y - x))) <= blk_max / 127.0 + 1e-6


def test_int8_roundtrip_tree():
    tree = {"a": jnp.ones((10, 10)), "b": {"c": jnp.zeros((3,))}}
    out = compression.int8_roundtrip(tree)
    np.testing.assert_allclose(out["a"], tree["a"], atol=1e-2)
    np.testing.assert_allclose(out["b"]["c"], 0.0)
