"""Numerics guardian (DESIGN.md §15): adversarial spectra, quarantine,
skip-step rollback, validated async install, checkpoint integrity.

The containment invariant under test: FINITE INPUT -> FINITE OUTPUT for
every matfn family, no matter how hostile the spectrum — a slice that
cannot converge exits with a truthful status code (MAXITER/QUARANTINED)
and a bounded iterate instead of poisoning the caller.  The guards add
ZERO matrix-function launches (the §10/§12 contracts are guard-blind).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn, prism
from repro.core import random_matrices as rm
from repro.optim import base, make_optimizer

pytestmark = pytest.mark.tier1

VALID = {int(prism.STATUS_OK), int(prism.STATUS_MAXITER),
         int(prism.STATUS_QUARANTINED)}


def _cfg(tol, dtype="float32", iters=10, warm=1, **kw):
    return PrismConfig(degree=2, iterations=iters, warm_alpha_iters=warm,
                       sketch_dim=8, dtype=dtype, tol=tol, **kw)


def _finite(x) -> bool:
    return bool(np.all(np.isfinite(np.asarray(x, np.float32))))


# --------------------------- adversarial spectra x families x dtypes

def _spectrum(key, name: str, n: int = 32, spd: bool = False):
    """Hostile test matrices: exact zero (no signal), a rank-1 spike
    (maximally singular with one huge direction), and kappa ~ 1e8
    (at/under fp32's certification floor)."""
    if name == "zero":
        return jnp.zeros((n, n))
    if name == "rank1_spike":
        sig = jnp.zeros((n,)).at[0].set(1e4)
        A = rm.with_spectrum(key, n, n, sig)
    else:  # kappa1e8
        A = rm.log_uniform_spectrum(key, n, n, 1e-8)
    if spd:
        A = A @ A.T / 2 + 1e-30 * jnp.eye(n)
    return A


SPECTRA = ("zero", "rank1_spike", "kappa1e8")
DTYPES = ("float32", "bfloat16")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", SPECTRA)
def test_polar_containment(key, name, dtype):
    A = _spectrum(key, name)
    X, it, st = matfn.polar(A, method="prism", cfg=_cfg(1e-2, dtype),
                            key=key, return_iters=True,
                            return_status=True)
    assert _finite(X), (name, dtype)
    assert st.dtype == jnp.int8 and int(st) in VALID
    assert 0 <= int(it) <= 10
    if name == "zero":
        # no signal can never certify — the guardian must say so
        assert int(st) != int(prism.STATUS_OK)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", SPECTRA)
def test_chebyshev_inv_containment(key, name, dtype):
    A = _spectrum(key, name, spd=True)
    X, it, st = matfn.inv(A, iters=10, key=key, tol=1e-2,
                          dtype=jnp.dtype(dtype), return_iters=True,
                          return_status=True)
    assert _finite(X), (name, dtype)
    assert st.dtype == jnp.int8 and int(st) in VALID
    if name in ("zero", "rank1_spike"):
        # singular input: inversion must NOT report success
        assert int(st) != int(prism.STATUS_OK)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", SPECTRA)
def test_inverse_newton_containment(key, name, dtype):
    A = _spectrum(key, name, spd=True)
    X, it, st = matfn.inv_proot(A, p=4, iters=10, key=key, tol=1e-2,
                                dtype=jnp.dtype(dtype),
                                return_iters=True, return_status=True)
    assert _finite(X), (name, dtype)
    assert st.dtype == jnp.int8 and int(st) in VALID


def test_healthy_input_certifies_ok(key):
    """Control: a benign spectrum certifies with STATUS_OK in every
    family — the guards never flag healthy work."""
    A = rm.log_uniform_spectrum(key, 32, 32, 1e-1)
    S = A @ A.T / 2 + 0.1 * jnp.eye(32)
    _, _, st_p = matfn.polar(A, method="prism", cfg=_cfg(1e-2, iters=14),
                             key=key, return_iters=True,
                             return_status=True)
    _, _, st_i = matfn.inv(S, iters=30, key=key, tol=1e-2,
                           return_iters=True, return_status=True)
    _, _, st_n = matfn.inv_proot(S, p=4, iters=30, key=key, tol=1e-2,
                                 return_iters=True, return_status=True)
    assert int(st_p) == int(st_i) == int(st_n) == int(prism.STATUS_OK)


# ------------------------------------------------------- quarantine

def test_forced_divergence_quarantines(key):
    """alpha pinned to 50 makes every fitted NS step diverge: the
    detector must quarantine (status 2) and hand back a FINITE iterate
    (the pre-divergence snapshot) instead of the exploded one."""
    A = rm.log_uniform_spectrum(key, 32, 32, 1e-2)
    X, it, st = matfn.polar(A, method="prism",
                            cfg=_cfg(1e-6, iters=8,
                                     alpha_bounds=(50.0, 50.0)),
                            key=key, return_iters=True,
                            return_status=True)
    assert int(st) == int(prism.STATUS_QUARANTINED)
    assert _finite(X)


def test_quarantine_is_per_slice(key):
    """Batched run, one hostile slice: containment is per-slice — the
    healthy slice still certifies STATUS_OK and converges to the true
    polar factor (oracle residual), unpolluted by its neighbour."""
    good = rm.log_uniform_spectrum(key, 32, 32, 1e-1)
    bad = jnp.zeros((32, 32))  # can never certify
    Xb, _, stb = matfn.polar(jnp.stack([good, bad]), method="prism",
                             cfg=_cfg(1e-2, iters=14), key=key,
                             return_iters=True, return_status=True)
    assert int(stb[0]) == int(prism.STATUS_OK)
    assert int(stb[1]) != int(prism.STATUS_OK)
    assert _finite(Xb)
    G = np.asarray(Xb[0].T @ Xb[0])
    assert np.linalg.norm(np.eye(32) - G) < 5e-2


# ------------------------------------------- launch contracts (guards on)

def _count(fn, *args):
    from repro.kernels import ops

    return ops.count_launches(fn, *args)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_launch_contract_with_status(monkeypatch, key, dtype):
    """The divergence detector rides the existing certificate: asking
    for the status changes the traced launch count by ZERO (fused tier:
    warm tail 1 + fitted body 2, same as without the guard)."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = _cfg(1e-2, dtype=dtype, iters=5, use_kernels=True, fuse="on")
    A = jnp.zeros((4, 64, 48), jnp.dtype(dtype))
    n_plain = _count(lambda a: matfn.polar(a, method="prism", cfg=cfg,
                                           key=key), A)
    n_status = _count(lambda a: matfn.polar(a, method="prism", cfg=cfg,
                                            key=key, return_iters=True,
                                            return_status=True), A)
    assert n_status == n_plain == 1 + 2


def test_skip_step_adds_zero_matfn_launches(monkeypatch, key):
    """The §15 skip-step guard is a per-buffer select: the wrapped
    optimizer's steady-state update compiles with the SAME launch count
    as the bare one (zero matrix-function launches, §12 contract)."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    params = {"w": jax.random.normal(key, (64, 32))}
    axes = {"w": ("embed", "mlp")}
    grads = jax.tree.map(jnp.ones_like, params)
    counts = {}
    for skip in (False, True):
        ocfg = OptimizerConfig(
            name="muon", precond_every=4, skip_nonfinite=skip,
            prism=PrismConfig(degree=2, iterations=2, warm_alpha_iters=1,
                              sketch_dim=8, use_kernels=True))
        opt = make_optimizer(ocfg, axes)
        state = opt.init(params)
        counts[skip] = _count(
            lambda g, s, p: opt.update(g, s, p, 1, key, refresh=False),
            grads, state, params)
    assert counts[True] == counts[False] == 0, counts


# ---------------------------------------------------- skip-step guard

def _tiny_opt(skip=True):
    ocfg = OptimizerConfig(name="muon", matfn_tol=1e-2,
                           skip_nonfinite=skip,
                           prism=_cfg(1e-2, iters=4))
    params = {"w": jax.random.normal(jax.random.PRNGKey(7), (32, 16)),
              "b": jnp.ones((16,))}
    axes = {"w": ("embed", "mlp"), "b": ("mlp",)}
    return make_optimizer(ocfg, axes), params


def test_skip_step_rolls_back_bitwise(key):
    opt, params = _tiny_opt()
    state = opt.init(params)
    g_good = jax.tree.map(jnp.ones_like, params)
    p1, s1 = opt.update(g_good, state, params, 0, key)
    g_bad = jax.tree.map(lambda g: g * jnp.nan, g_good)
    p2, s2 = opt.update(g_bad, s1, p1, 1, key)
    # params AND every state buffer identical to the pre-step iterate
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2["bad_steps"]) == 1
    assert int(s2["count"]) == int(s1["count"])  # clock holds on a skip
    # ...and the run continues: the next good step applies normally
    p3, s3 = opt.update(g_good, s2, p2, 2, key)
    assert int(s3["bad_steps"]) == 1
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)))


def test_skip_step_catches_inf_gradient(key):
    opt, params = _tiny_opt()
    state = opt.init(params)
    g_inf = jax.tree.map(
        lambda p: jnp.full_like(p, jnp.inf), params)
    p1, s1 = opt.update(g_inf, state, params, 0, key)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s1["bad_steps"]) == 1
    assert all(_finite(l) for l in jax.tree.leaves(s1))


def test_clip_passes_nonfinite_through_unscaled():
    """A non-finite global norm must NOT zero (inf => scale 0) or NaN
    the gradients — the skip-step guard downstream needs to SEE the
    poison to count it."""
    g = {"a": jnp.array([1.0, jnp.inf]), "b": jnp.ones((2,))}
    clipped, gn = base.clip_by_global_norm(g, 1.0)
    assert not np.isfinite(float(gn))
    np.testing.assert_array_equal(np.asarray(clipped["b"]),
                                  np.ones((2,)))
    g0 = {"a": jnp.zeros((3,))}
    c0, gn0 = base.clip_by_global_norm(g0, 1.0)
    assert float(gn0) == 0.0 and _finite(c0["a"])


# ------------------------------------- validated async install (§15)

def _poisonable_service():
    ocfg = OptimizerConfig(
        name="muon", matfn_tol=1e-2, precond_every=8,
        precond_async=True, precond_swap_delay=1, precond_max_retries=2,
        precond_drift_slack=2.0,  # drift trigger armed (threshold 1e-2)
        prism=_cfg(1e-2, iters=3))
    params = {"w": jax.random.normal(jax.random.PRNGKey(3), (32, 16))}
    opt = make_optimizer(ocfg, {"w": ("embed", "mlp")})
    svc = base.AsyncPrecondService(opt, ocfg)
    real = svc._refresh
    poison = {"on": False}

    def maybe_poisoned(state, k):
        p = real(state, k)
        if poison["on"]:
            p = jax.tree.map(
                lambda x: x * jnp.nan
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
        return p

    svc._refresh = maybe_poisoned
    return opt, svc, opt.init(params), poison


def _active_leaves(state):
    """Non-pending state leaves: the discarded twin's payload stays in
    the inert ``*_p`` buffers (pending_at = NO_PENDING keeps the swap
    from ever consuming it), so only the ACTIVE plane must stay clean."""
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    return [l for path, l in flat
            if not any(str(getattr(p, "key", "")) in
                       base.PENDING_STATE_KEYS for p in path)]


def test_async_poisoned_buffer_never_installs():
    """A non-finite refresh result is discarded before the swap: the
    pending plane is marked stale and a backoff retry is scheduled."""
    opt, svc, state, poison = _poisonable_service()
    poison["on"] = True
    state = svc.step_begin(state, 0, jax.random.PRNGKey(0))
    # bootstrap validates immediately (its swap fires this very step)
    assert svc.counters["discarded"] == 1 and svc.failures == 1
    assert int(state["pending_at"]) == base.NO_PENDING
    assert all(_finite(l) for l in _active_leaves(state))


def test_async_retry_backoff_then_degrade():
    """Consecutive failures: discard -> backoff retry -> after
    max_retries the slot DEGRADES (loud counter, no retry storm) and
    keeps serving the active buffer; the next clean refresh recovers."""
    opt, svc, state, poison = _poisonable_service()
    key = jax.random.PRNGKey(0)
    # one clean bootstrap first: active buffers installed and swapped
    state = svc.step_begin(state, 0, key)
    grads = {"w": jnp.ones((32, 16))}
    params = {"w": jnp.zeros((32, 16))}
    params, state = opt.update(grads, state, params, 0, key,
                               refresh=False)
    assert svc.counters["refreshes"] == 1
    poison["on"] = True
    seen = []
    for t in range(1, 16):
        state = svc.step_begin(state, t, jax.random.fold_in(key, t),
                               drift=1e9)  # drift demands a refresh
        seen.append(svc.counters.copy())
        params, state = opt.update(grads, state, params, t,
                                   jax.random.fold_in(key, t),
                                   refresh=False)
        if svc.counters["degraded"]:
            break
    assert svc.counters["discarded"] == 2  # initial attempt + 1 retry
    assert svc.counters["retries"] >= 1
    assert svc.counters["degraded"] == 1
    # degraded: in-flight pending dropped, active plane still finite
    assert int(state["pending_at"]) == base.NO_PENDING
    assert all(_finite(l) for l in _active_leaves(state))
    # recovery: the next trigger dispatches a clean buffer that installs
    poison["on"] = False
    t0 = t + 1
    for t in range(t0, t0 + 4):
        state = svc.step_begin(state, t, jax.random.fold_in(key, t),
                               drift=1e9)
        if int(state["pending_at"]) != base.NO_PENDING:
            break
    assert int(state["pending_at"]) != base.NO_PENDING
    assert svc.counters["discarded"] == 2  # clean install, no new discard


# ------------------------------------------- checkpoint integrity (§15)

def _tree(x=0.0):
    return {"w": np.full((4, 3), 1.0 + x, np.float32),
            "n": np.arange(5) + int(x)}


def test_checkpoint_crc_detects_bit_rot(tmp_path):
    from repro import checkpoint as ckpt
    from repro.train.chaos import corrupt_checkpoint

    d = str(tmp_path)
    ckpt.save(d, 2, _tree(0.0))
    ckpt.save(d, 4, _tree(1.0))
    assert ckpt.verify_step(d, 4)
    corrupt_checkpoint(d, 4)
    assert not ckpt.verify_step(d, 4)
    assert ckpt.verify_step(d, 2)
    # newest-valid fallback: restore(None) lands on step 2...
    step, out = ckpt.restore(d, _tree())
    assert step == 2
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  _tree(0.0)["w"])
    # ...but an EXPLICITLY requested corrupt step must raise
    with pytest.raises(ValueError, match="crc32"):
        ckpt.restore(d, _tree(), step=4)


def test_checkpoint_without_manifest_is_not_complete(tmp_path):
    from repro import checkpoint as ckpt
    from repro.checkpoint.checkpoint import _complete_steps

    d = str(tmp_path)
    ckpt.save(d, 2, _tree())
    os.remove(os.path.join(d, "step_00000002", "MANIFEST"))
    assert _complete_steps(d) == []
    assert ckpt.latest_step(d) is None


def test_checkpoint_all_corrupt_raises(tmp_path):
    from repro import checkpoint as ckpt
    from repro.train.chaos import corrupt_checkpoint

    d = str(tmp_path)
    ckpt.save(d, 2, _tree())
    corrupt_checkpoint(d, 2)
    with pytest.raises(FileNotFoundError, match="uncorrupted"):
        ckpt.restore(d, _tree())
