"""Dtype-parameterized numerics suite for the mixed-precision matrix-
function engine (DESIGN.md §9).

Every matfn family runs under the bf16 policy (bf16 compute / fp32
accumulate / fp32 fit) against the fp32 policy over the paper's spectrum
zoo — Gaussian, HTMP heavy-tail, near-rank-deficient, ill-conditioned —
asserting principled tolerances: a self-correcting iteration in bf16
converges to f(round_bf16(A)), so the relative Frobenius error is
O(u_bf16 * kappa_f) with u_bf16 = 2^-8 ~ 3.9e-3 and kappa_f the
conditioning of the family on the given spectrum (amplified for the
inverse families, ~1 for polar/sign).  Tolerances below are 2-3x the
measured errors under those bounds.

Also asserts the engine-level contracts: PRISM-fitted bf16 NS reaches the
fp32 residual target within +1 iteration (the fit absorbs bf16 residual
noise), bucketed Muon/Shampoo steps match across policies, launch counts
are dtype-independent, and the pad-trace correction stays exact under
bf16 compute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MatfnPrecision, OptimizerConfig, PrismConfig
from repro.core import matfn
from repro.core import newton_schulz as ns
from repro.core import random_matrices as rm
from repro.core import sketch
from repro.optim import bucketing, make_optimizer

pytestmark = pytest.mark.tier1

SPECTRA = ["gaussian", "htmp", "near_rank_deficient", "ill_conditioned"]
U_BF16 = 2.0 ** -8

# relative-Frobenius parity tolerance per family: ~2-3x the measured
# bf16-vs-fp32 error over the spectrum zoo (see module docstring; the
# inverse families carry the kappa amplification of the cond<=100 SPDs)
TOL = {"polar": 0.08, "signm": 0.04, "sqrtm": 0.03, "inv_sqrtm": 0.06,
       "inv": 0.10, "inv_proot": 0.05}


def _base_matrix(kind: str, key, m: int, n: int):
    """[m, n] test matrix with the named singular spectrum, sigma_max ~ 1."""
    r = min(m, n)
    if kind == "gaussian":
        return rm.gaussian(key, m, n) / np.sqrt(m)
    if kind == "htmp":
        return rm.htmp(key, m, n, kappa=0.5)
    if kind == "near_rank_deficient":
        s = jnp.concatenate([jnp.linspace(1.0, 0.3, r - 3),
                             jnp.full((3,), 1e-2)])
        return rm.with_spectrum(key, m, n, s)
    assert kind == "ill_conditioned"
    return rm.log_uniform_spectrum(key, m, n, smin=5e-2)


def _spd_matrix(kind: str, key, n: int):
    """SPD companion: squared spectrum of the kind's base matrix, floored
    at cond = 100 — the inverse families' bf16 error scales with kappa,
    and past ~1/u_bf16 the comparison measures the spectrum, not the
    engine."""
    A = _base_matrix(kind, key, n, n)
    s = jnp.linalg.svd(A, compute_uv=False)
    eigs = jnp.clip(jnp.square(s) / s[0] ** 2, 1e-2, 1.0)
    return rm.spd_with_eigs(jax.random.fold_in(key, 7), n, eigs)


def _fro_rel(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-9)


def _run_family(family: str, kind: str, key, dtype: str):
    kk = jax.random.fold_in(key, SPECTRA.index(kind))
    cfg = PrismConfig(degree=2, iterations=10, warm_alpha_iters=1,
                      sketch_dim=8, dtype=dtype)
    if family == "polar":
        A = _base_matrix(kind, kk, 64, 48)
        return matfn.polar(A.astype(dtype), cfg=cfg, key=key)
    if family == "signm":
        B = _base_matrix(kind, kk, 48, 48)
        sym = 0.5 * (B + B.T)
        return matfn.signm(sym.astype(dtype), cfg=cfg, key=key)
    spd = _spd_matrix(kind, kk, 48)
    if family == "sqrtm":
        return matfn.sqrtm(spd.astype(dtype), cfg=cfg, key=key)[0]
    if family == "inv_sqrtm":
        return matfn.sqrtm(spd.astype(dtype), cfg=cfg, key=key)[1]
    if family == "inv":
        return matfn.inv(spd.astype(dtype), method="prism_chebyshev",
                         key=key, iters=25, dtype=jnp.dtype(dtype))
    assert family == "inv_proot"
    return matfn.inv_proot(spd.astype(dtype), p=4, method="prism", key=key,
                           iters=20, dtype=jnp.dtype(dtype))


@pytest.mark.parametrize("kind", SPECTRA)
@pytest.mark.parametrize("family", sorted(TOL))
def test_bf16_policy_parity(key, family, kind):
    """bf16-policy result vs fp32 policy, every family x spectrum."""
    f32 = _run_family(family, kind, key, "float32")
    f16 = _run_family(family, kind, key, "bfloat16")
    assert f16.dtype == jnp.bfloat16
    err = _fro_rel(f16, f32)
    assert err < TOL[family], (family, kind, err)


@pytest.mark.parametrize("kind", SPECTRA)
def test_bf16_polar_is_orthogonal(key, kind):
    """Quality, not just parity: the bf16 polar factor is orthogonal to
    ~u_bf16 resolution (||X^T X - I||_F / sqrt(n) at the rounding floor)."""
    X = np.asarray(_run_family("polar", kind, key, "bfloat16"), np.float32)
    n = X.shape[-1]
    ortho = np.linalg.norm(X.T @ X - np.eye(n)) / np.sqrt(n)
    assert ortho < 8 * U_BF16, (kind, ortho)


@pytest.mark.parametrize("kind", SPECTRA)
def test_bf16_prism_residual_within_one_iteration(key, kind):
    """The headline adaptivity contract: PRISM's fp32-pinned fit absorbs
    bf16 residual noise, so the bf16 chain reaches the fp32 residual
    target (5e-2 normalized — above the bf16 floor ~sqrt(n) u) within +1
    iteration of the fp32 chain, on every spectrum."""
    A = _base_matrix(kind, jax.random.fold_in(key, SPECTRA.index(kind)),
                     96, 64)
    target = 5e-2
    hits = {}
    for dt in ("float32", "bfloat16"):
        cfg = PrismConfig(degree=2, iterations=10, warm_alpha_iters=1,
                          sketch_dim=8, dtype=dt)
        _, info = ns.polar(A.astype(dt), cfg=cfg, method="prism", key=key,
                           return_info=True)
        # residual_fro[k] = ||R_k||_F BEFORE update k; normalized by
        # sqrt(n) so the target is a per-singular-value deviation
        r = np.asarray(info.residual_fro).reshape(-1) / np.sqrt(64)
        below = np.nonzero(r < target)[0]
        assert below.size, (kind, dt, r)
        hits[dt] = int(below[0])
    assert hits["bfloat16"] <= hits["float32"] + 1, (kind, hits)


def test_fit_is_fp32_regardless_of_compute(key):
    """MatfnPrecision pins the fit: alphas fitted from a bf16 residual are
    fp32 scalars and lie within the constraint interval; the fit of the
    bf16-rounded residual tracks the fp32 fit closely (the traces are
    fp32-accumulated, so the fit sees only O(u) input perturbation)."""
    from repro.core import polynomials as poly
    from repro.core import prism

    R = _base_matrix("gaussian", key, 64, 64)
    R = 0.15 * 0.5 * (R + R.T)
    apoly = poly.newton_schulz_residual(2)
    lo, hi = PrismConfig(degree=2).bounds
    a32 = prism.fit_alpha(R, apoly, lo, hi, key=key, sketch_dim=8)
    a16 = prism.fit_alpha(R.astype(jnp.bfloat16), apoly, lo, hi, key=key,
                          sketch_dim=8)
    assert a32.dtype == jnp.float32 and a16.dtype == jnp.float32
    assert lo <= float(a16) <= hi
    np.testing.assert_allclose(float(a16), float(a32), rtol=0.05, atol=0.02)


def test_pad_trace_correction_exact_under_bf16(key):
    """DESIGN.md §9: the §7 pad-trace correction stays exact in bf16 —
    zero padding is exact in any dtype, the pad block of R is exactly I,
    and the fp32-accumulated traces pick up exactly the fp32 sum of
    squared pad columns of the (bf16-rounded) sketch."""
    n, padn, p, maxp = 24, 32, 8, 10
    R = jax.random.normal(key, (n, n)) / (3 * np.sqrt(n))
    R = (0.5 * (R + R.T)).astype(jnp.bfloat16)
    Rp = jnp.eye(padn, dtype=jnp.bfloat16).at[:n, :n].set(R)
    S = sketch.gaussian_sketch(jax.random.fold_in(key, 1), p, padn,
                               dtype=jnp.bfloat16)
    t_pad = sketch.sketched_power_traces(Rp, S, maxp)
    c = jnp.sum(jnp.square(S[:, n:].astype(jnp.float32)))
    t_real = sketch.sketched_power_traces(R, S[:, :n], maxp)
    # fp32-tight: the only difference is fp32 summation order
    np.testing.assert_allclose(np.asarray(t_pad) - float(c),
                               np.asarray(t_real), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- optimizer level


def _tree(key):
    params = {"w1": jax.random.normal(key, (64, 32)),
              "w3": jax.random.normal(jax.random.fold_in(key, 2),
                                      (3, 48, 32)),
              "b": jax.random.normal(jax.random.fold_in(key, 4), (64,))}
    axes = {"w1": ("embed", "mlp"), "w3": ("layers", "embed", "mlp"),
            "b": ("embed",)}
    return params, axes


@pytest.mark.parametrize("name", ["muon", "shampoo"])
def test_bucketed_step_bf16_parity(key, name):
    """A full bucketed optimizer step under matfn_dtype="bfloat16" stays
    within the lr-scaled matfn tolerance of the fp32 step."""
    params, axes = _tree(key)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 9), p.shape),
        params)
    outs = {}
    for dt in ("float32", "bfloat16"):
        ocfg = OptimizerConfig(
            name=name, learning_rate=0.05 if name == "muon" else 1e-3,
            matfn_dtype=dt, max_precond_dim=256,
            prism=PrismConfig(degree=2, iterations=6, warm_alpha_iters=1,
                              sketch_dim=8))
        opt = make_optimizer(ocfg, axes)
        outs[dt], _ = jax.jit(opt.update)(grads, opt.init(params), params,
                                          0, key)
    for k in params:
        err = _fro_rel(outs["bfloat16"][k], outs["float32"][k])
        assert err < 2e-3, (name, k, err)


def test_bf16_gather_stacks_in_bf16(key):
    """The bucket gather materializes directly in the compute dtype —
    the stacked array every chain GEMM reads is bf16, not fp32-then-cast."""
    views = [jax.random.normal(jax.random.fold_in(key, i), (16, 8))
             for i in range(3)]
    b = bucketing.plan_buckets([v.shape for v in views])[0]
    stacked = bucketing.gather_bucket(b, views, dtype=jnp.bfloat16)
    assert stacked.dtype == jnp.bfloat16 and stacked.shape == (3, 16, 8)
    # and fp32 gathers are untouched by the dtype plumbing
    assert bucketing.gather_bucket(b, views).dtype == jnp.float32


def test_cache_dtype_follows_policy(key):
    """precond_cache_dtype="auto" stores the staleness caches in the
    matfn compute dtype; explicit "float32" overrides; lax.cond branches
    agree in dtype either way (a dynamic-schedule step compiles)."""
    params, axes = _tree(key)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 3), p.shape),
        params)
    for cache, want in [("auto", jnp.bfloat16), ("float32", jnp.float32)]:
        ocfg = OptimizerConfig(
            name="muon", precond_every=3, matfn_dtype="bfloat16",
            precond_cache_dtype=cache,
            prism=PrismConfig(degree=2, iterations=3, warm_alpha_iters=1,
                              sketch_dim=8))
        opt = make_optimizer(ocfg, axes)
        state = opt.init(params)
        assert state["leaves"]["w1"]["ortho"].dtype == want, cache
        _, s2 = jax.jit(opt.update)(grads, state, params, 0, key)
        assert s2["leaves"]["w1"]["ortho"].dtype == want, cache
    socfg = OptimizerConfig(name="shampoo", matfn_dtype="bfloat16",
                            max_precond_dim=256,
                            prism=PrismConfig(degree=2, iterations=6,
                                              sketch_dim=8))
    sopt = make_optimizer(socfg, axes)
    sstate = sopt.init(params)
    assert sstate["leaves"]["w1"]["Linv"].dtype == jnp.bfloat16
    _, ss2 = jax.jit(sopt.update)(grads, sstate, params, 0, key)
    assert ss2["leaves"]["w1"]["Linv"].dtype == jnp.bfloat16


def test_bf16_staleness_cache_schedule_invariant(key):
    """Stale steps serve the SAME (cache-rounded) polar the refresh step
    stored — the update direction is schedule-invariant under bf16
    caches, mirroring the fp32 contract of test_sharded_precond."""
    params, axes = _tree(key)
    ocfg = OptimizerConfig(name="muon", learning_rate=0.1,
                           weight_decay=0.0, precond_every=3,
                           matfn_dtype="bfloat16",
                           prism=PrismConfig(degree=2, iterations=3,
                                             warm_alpha_iters=1,
                                             sketch_dim=8))
    opt = make_optimizer(ocfg, axes)
    state = opt.init(params)
    upd = jax.jit(opt.update)
    p, deltas, orthos = params, [], []
    for t in range(3):
        g = jax.tree.map(
            lambda x: jax.random.normal(jax.random.fold_in(key, 50 + t),
                                        x.shape), p)
        p2, state = upd(g, state, p, t, jax.random.fold_in(key, t))
        deltas.append(np.asarray(p["w1"], np.float32)
                      - np.asarray(p2["w1"], np.float32))
        orthos.append(np.asarray(state["leaves"]["w1"]["ortho"]))
        p = p2
    assert np.array_equal(orthos[0], orthos[1])
    assert np.array_equal(orthos[1], orthos[2])
    np.testing.assert_allclose(deltas[0], deltas[1], atol=1e-6)
    np.testing.assert_allclose(deltas[1], deltas[2], atol=1e-6)


def test_launch_counts_dtype_independent(monkeypatch, key):
    """The launch-count contracts are precision-blind (bf16 changes tile
    CONTENTS, never dispatch structure): a fitted PRISM-NS iteration is 2
    launches on the fused tier (§10) and 2+d on the §7 batch-grid tier,
    whether the operands are fp32 or bf16."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.kernels import ops

    for fuse, want in (("auto", 2), ("off", 4)):
        counts = {}
        for dt in ("float32", "bfloat16"):
            cfg = PrismConfig(degree=2, iterations=1, warm_alpha_iters=0,
                              sketch_dim=8, use_kernels=True, dtype=dt,
                              fuse=fuse)
            A = jnp.zeros((4, 64, 48), jnp.dtype(dt))
            counts[dt] = ops.count_launches(
                lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key),
                A)
        assert counts["float32"] == counts["bfloat16"] == want, \
            (fuse, counts)


def test_precision_policy_validation():
    """Accumulate/fit are pinned fp32 by construction."""
    p = PrismConfig(dtype="bfloat16").precision
    assert (p.compute, p.accumulate, p.fit) == \
        ("bfloat16", "float32", "float32")
    assert p.compute_dtype == jnp.bfloat16
    with pytest.raises(ValueError):
        MatfnPrecision(compute="bfloat16", accumulate="bfloat16")
    with pytest.raises(ValueError):
        MatfnPrecision(fit="bfloat16")
    ocfg = OptimizerConfig(matfn_dtype="bfloat16")
    assert ocfg.resolved_prism.dtype == "bfloat16"
    assert ocfg.cache_dtype == "bfloat16"
    assert OptimizerConfig().resolved_prism.dtype == "float32"
    assert OptimizerConfig(
        matfn_dtype="bfloat16",
        precond_cache_dtype="float32").cache_dtype == "float32"
