"""Unit tests for the sharding-rule layer (no multi-device needed:
constrain_spec / rules are pure functions of mesh metadata)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # metadata-only usage: a 1-device mesh can't express 16x16, so build
    # an abstract mesh with the production shape
    from repro.launch.mesh import compat_abstract_mesh

    return compat_abstract_mesh((16, 16), ("data", "model"))


def test_constrain_spec_drops_nondivisible(mesh):
    # granite vocab 49155 % 16 != 0 -> axis dropped
    out = sh.constrain_spec(mesh, P("model", "data"), (49155, 1024))
    assert tuple(out) == (None, "data")


def test_constrain_spec_dedup_keeps_specific(mesh):
    # expert tensors under "zero": embed (data, model) + expert_mlp model
    # -> embed reduces to (data,), expert_mlp keeps model
    out = sh.constrain_spec(mesh, P(None, ("data", "model"), "model"),
                            (8, 4096, 14336))
    assert tuple(out) == (None, "data", "model")


def test_constrain_spec_pads_nothing_when_legal(mesh):
    out = sh.constrain_spec(mesh, P(("data", "model"), None), (5120, 32))
    assert tuple(out) == (("data", "model"), None)


def test_param_rules_strategies(mesh):
    cfg = get_config("mixtral-8x7b")
    tp = sh.param_rules(cfg, mesh, "tp")
    assert tp["mlp"] == "model" and tp["embed"] == "data"
    assert tp["expert_mlp"] == "model"  # mixtral: 8 experts < 16 -> TP
    zero = sh.param_rules(cfg, mesh, "zero")
    assert zero["embed"] == ("data", "model") and zero["mlp"] is None
    assert zero["expert_mlp"] == "model"  # experts keep 2D sharding
    cfg_ep = get_config("granite-moe-1b-a400m")
    ep = sh.param_rules(cfg_ep, mesh, "tp")
    assert ep["experts"] == "model" and ep["expert_mlp"] is None


def test_activation_rules_opt_targets(mesh):
    cfg = get_config("qwen3-14b")
    for strat in ["tp", "zero"]:
        r = sh.activation_rules(cfg, mesh, strat)
        assert r["opt_layers"] == "model" and r["opt_rows"] == "data"


def test_tree_shardings_match_param_tree(mesh):
    from repro.models import build

    cfg = get_config("qwen3-14b")
    model = build(cfg)
    shapes = model.param_shapes()
    shards = sh.tree_shardings(mesh, model.logical_axes(),
                               sh.param_rules(cfg, mesh, "tp"), shapes)
    assert jax.tree.structure(shapes) == jax.tree.structure(shards)
    # every sharded dim divides evenly (in_shardings legality)
    for s, nshard in zip(jax.tree.leaves(shapes), jax.tree.leaves(shards)):
        spec = nshard.spec
        for dim, entry in zip(s.shape, tuple(spec)):
            if entry is None:
                continue
            n = 1
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                n *= dict(data=16, model=16)[ax]
            assert dim % n == 0, (s.shape, spec)
