"""Every Table-1 algorithm validated against dense linear-algebra oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

pytestmark = pytest.mark.tier1

CFG2 = PrismConfig(degree=2, sketch_dim=8)
CFG1 = PrismConfig(degree=1, sketch_dim=8)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


# ---------------------------------------------------------------- polar

@pytest.mark.parametrize("method,kw", [
    ("prism", dict(cfg=CFG2, iters=12)),
    ("prism", dict(cfg=CFG1, iters=20)),
    ("prism", dict(cfg=PrismConfig(degree=2, sketch_dim=0), iters=12)),
    ("newton_schulz", dict(cfg=CFG2, iters=30)),
    ("polar_express", dict(iters=14)),
])
def test_polar_matches_svd(key, method, kw):
    A = rm.log_uniform_spectrum(key, 96, 64, 1e-3)
    if method == "prism" and "key" not in kw:
        kw = dict(kw, key=key)
    X = matfn.polar(A, method=method, **kw)
    ref = matfn.polar(A, method="svd")
    assert _rel(X, ref) < 5e-3


def test_polar_wide_matrix(key):
    A = rm.log_uniform_spectrum(key, 48, 80, 1e-2)
    X = matfn.polar(A, method="prism", cfg=CFG2, key=key, iters=12)
    ref = matfn.polar(A, method="svd")
    assert X.shape == A.shape
    assert _rel(X, ref) < 5e-3


def test_polar_batched_matches_loop(key):
    A = jax.random.normal(key, (3, 40, 24))
    Xb = matfn.polar(A, method="prism", cfg=CFG2, key=key, iters=10)
    for i in range(3):
        Xi = matfn.polar(A[i], method="prism", cfg=CFG2, key=key, iters=10)
        # same sketch key stream => identical results
        np.testing.assert_allclose(Xb[i], Xi, rtol=2e-4, atol=2e-4)


def test_polar_orthogonality(key):
    A = rm.gaussian(key, 128, 64)
    X = matfn.polar(A, method="prism", cfg=CFG2, key=key, iters=10)
    eye = jnp.eye(64)
    assert float(jnp.linalg.norm(X.T @ X - eye)) / 8.0 < 1e-2


def test_polar_bf16_no_nan(key):
    A = rm.gaussian(key, 64, 64).astype(jnp.bfloat16)
    X = matfn.polar(A, method="prism", cfg=CFG2, key=key, iters=8)
    assert X.dtype == jnp.bfloat16
    assert not bool(jnp.any(jnp.isnan(X.astype(jnp.float32))))


# ---------------------------------------------------------------- sqrtm

@pytest.mark.parametrize("method,kw", [
    ("prism", dict(cfg=CFG2, iters=15)),
    ("newton_schulz", dict(cfg=CFG2, iters=40)),
    ("polar_express", dict(iters=15)),
    ("newton", dict(iters=15)),
    ("newton_classical", dict(iters=25)),
])
def test_sqrtm_matches_eigh(key, method, kw):
    A = rm.spd_with_eigs(key, 96, jnp.linspace(1e-3, 1.0, 96))
    if method == "prism":
        kw = dict(kw, key=key)
    sq, isq = matfn.sqrtm(A, method=method, **kw)
    sq_ref, isq_ref = matfn.sqrtm(A, method="eigh")
    assert _rel(sq, sq_ref) < 1e-3
    assert _rel(isq, isq_ref) < 5e-3
    # defining property
    assert _rel(sq @ sq, A) < 5e-3


def test_sqrt_stability_after_convergence(key):
    """Regression: Thm-3 coupling (R = I - YX) must stay converged.

    The R = I - XY coupling diverges within ~3 iterations of convergence
    even in fp64 (classical coupled-NS instability).
    """
    A = rm.spd_with_eigs(key, 128, jnp.linspace(1e-4, 1.0, 128))
    (_, _), info = matfn.sqrtm(A, method="prism", cfg=CFG2, key=key,
                               iters=25, return_info=True)
    r = np.asarray(info.residual_fro)
    assert np.all(np.isfinite(r))
    assert r[-1] < 1e-3  # still converged at iteration 25


# ---------------------------------------------------------------- signm

def test_signm_symmetric_matches_eigh(key):
    eigs = jnp.concatenate([jnp.linspace(-1.0, -0.05, 32),
                            jnp.linspace(0.05, 1.0, 32)])
    A = rm.spd_with_eigs(key, 64, eigs)
    S = matfn.signm(A, method="prism", cfg=CFG2, key=key, iters=14)
    ref = matfn.signm(A, method="eigh")
    assert _rel(S, ref) < 5e-3
    assert _rel(S @ S, jnp.eye(64)) < 5e-3


def test_signm_classical(key):
    eigs = jnp.concatenate([jnp.linspace(-1.0, -0.2, 16),
                            jnp.linspace(0.2, 1.0, 16)])
    A = rm.spd_with_eigs(key, 32, eigs)
    S = matfn.signm(A, method="newton_schulz", cfg=CFG2, iters=40)
    assert _rel(S, matfn.signm(A, method="eigh")) < 5e-3


# ---------------------------------------------------------------- inverse

@pytest.mark.parametrize("method", ["prism_chebyshev", "chebyshev",
                                    "inverse_newton"])
def test_inv_matches_solve(key, method):
    A = rm.spd_with_eigs(key, 64, jnp.linspace(0.05, 1.0, 64))
    X = matfn.inv(A, method=method, iters=40, key=key)
    ref = matfn.inv(A, method="solve")
    assert _rel(X, ref) < 1e-3


def test_inv_nonsymmetric(key):
    # Chebyshev iteration does not require symmetry (X0 = A^T)
    A = rm.gaussian(key, 48, 48) / 10 + jnp.eye(48)
    X = matfn.inv(A, method="prism_chebyshev", iters=40, key=key)
    assert _rel(A @ X, jnp.eye(48)) < 1e-3


# ---------------------------------------------------------------- inv roots

@pytest.mark.parametrize("p", [1, 2, 3, 4])
def test_inv_proot_matches_eigh(key, p):
    A = rm.spd_with_eigs(key, 64, jnp.linspace(0.1, 1.0, 64))
    X = matfn.inv_proot(A, p=p, iters=40, key=key)
    ref = matfn.inv_proot(A, p=p, method="eigh")
    assert _rel(X, ref) < 2e-3


def test_inv_sqrtm_consistency(key):
    A = rm.spd_with_eigs(key, 64, jnp.linspace(0.05, 1.0, 64))
    Y1 = matfn.inv_sqrtm(A, method="prism", cfg=CFG2, key=key, iters=20)
    Y2 = matfn.inv_sqrtm(A, method="inverse_newton", iters=30, key=key)
    ref = matfn.sqrtm(A, method="eigh")[1]
    assert _rel(Y1, ref) < 5e-3
    assert _rel(Y2, ref) < 5e-3
