"""Bucketed batched matrix-function engine: planning, parity, padding,
and the constant-launch-count contract (DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn, prism, sketch
from repro.core import polynomials as poly
from repro.optim import base, bucketing, make_optimizer

pytestmark = pytest.mark.tier1

EXACT = PrismConfig(degree=2, iterations=6, warm_alpha_iters=1,
                    sketch_dim=0)  # exact traces: deterministic, key-free


# ------------------------------------------------------------------ planning

def test_plan_exact_groups():
    shapes = [(64, 32), (64, 32), (3, 64, 32), (32, 64), (128, 128)]
    buckets = bucketing.plan_buckets(shapes)
    got = {b.shape: b.size for b in buckets}
    # (64, 32) and (32, 64) must NOT merge (orientation preserved)
    assert got == {(64, 32): 5, (32, 64): 1, (128, 128): 1}
    by_shape = {b.shape: b for b in buckets}
    offs = [(e.index, e.offset, e.count) for e in by_shape[(64, 32)].entries]
    assert offs == [(0, 0, 1), (1, 1, 1), (2, 2, 3)]


def test_plan_pad_merges_within_slack():
    shapes = [(64, 64), (64, 60), (60, 64), (64, 16)]
    buckets = bucketing.plan_buckets(shapes, pad=True, pad_slack=0.25)
    got = {b.shape: b.size for b in buckets}
    # (64, 60) pads its Gram side (cols) up to (64, 64); (60, 64) would
    # need non-Gram-side (row) padding — refused; (64, 16) fits the side
    # rule but would be 4x area — refused by the slack bound
    assert got == {(64, 64): 2, (60, 64): 1, (64, 16): 1}
    assert bucketing.plan_buckets(shapes, pad=False)[0].padded is False


def test_gather_scatter_roundtrip(key):
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate([(2, 8, 6), (8, 6), (7, 5)])]
    buckets = bucketing.plan_buckets([v.shape for v in views], pad=True,
                                     pad_slack=0.4)
    outs = [None] * len(views)
    for b in buckets:
        stacked = bucketing.gather_bucket(b, views)
        assert stacked.shape == (b.size,) + b.shape
        bucketing.scatter_bucket(b, stacked, outs)
    for v, o in zip(views, outs):
        np.testing.assert_array_equal(np.asarray(v), np.asarray(o))


# ------------------------------------------------------------------- parity

def _tree(key):
    params = {
        "w1": jax.random.normal(key, (64, 32)),
        "w2": jax.random.normal(jax.random.fold_in(key, 1), (64, 32)),
        "w3": jax.random.normal(jax.random.fold_in(key, 2), (3, 48, 32)),
        "w4": jax.random.normal(jax.random.fold_in(key, 3), (32, 48)),
        "b": jax.random.normal(jax.random.fold_in(key, 4), (64,)),
    }
    axes = {"w1": ("embed", "mlp"), "w2": ("embed", "mlp"),
            "w3": ("layers", "embed", "mlp"), "w4": ("mlp", "embed"),
            "b": ("embed",)}
    return params, axes


@pytest.mark.parametrize("name", ["muon", "shampoo"])
def test_bucketed_matches_per_leaf(key, name):
    """Bucketed update == per-leaf update on a mixed-shape tree (exact
    alpha fit, so the two dispatch strategies are the same math)."""
    params, axes = _tree(key)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 9), p.shape),
        params)
    outs = {}
    for bucketed in (True, False):
        ocfg = OptimizerConfig(
            name=name, learning_rate=0.02 if name == "muon" else 1e-3,
            prism=EXACT, bucketed=bucketed, max_precond_dim=512)
        opt = make_optimizer(ocfg, axes)
        new_p, _ = jax.jit(opt.update)(grads, opt.init(params), params, 0,
                                       key)
        outs[bucketed] = new_p
    for k in params:
        np.testing.assert_allclose(np.asarray(outs[True][k]),
                                   np.asarray(outs[False][k]),
                                   rtol=2e-5, atol=2e-5)


def test_bucketed_sketched_still_orthogonalizes(key):
    """With a real (shared-sketch) fit the bucketed Muon update direction
    is still orthogonal per leaf."""
    params, axes = _tree(key)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 5), p.shape),
        params)
    ocfg = OptimizerConfig(
        name="muon", learning_rate=0.1, weight_decay=0.0,
        prism=PrismConfig(degree=2, iterations=8, sketch_dim=8))
    opt = make_optimizer(ocfg, axes)
    new_p, _ = opt.update(grads, opt.init(params), params, 0, key)
    upd = (np.asarray(params["w1"], np.float32)
           - np.asarray(new_p["w1"], np.float32)) / 0.1
    utu = upd.T @ upd / max(1.0, 64 / 32)
    np.testing.assert_allclose(utu, np.eye(32), atol=5e-2)


# ------------------------------------------------------------ pad-to-bucket

def test_pad_trace_correction_identity(key):
    """tr(S R_pad^i S^T) - c == tr(S_a R^i S_a^T) exactly, where R_pad =
    diag(R, I) and c = sum of ||S[:, j]||^2 over pad columns — the n_real
    correction fit_alpha applies."""
    n, padn, p, maxp = 24, 32, 8, 10
    R = jax.random.normal(key, (n, n)) / (3 * np.sqrt(n))
    R = 0.5 * (R + R.T)
    Rp = jnp.eye(padn).at[:n, :n].set(R)
    S = sketch.gaussian_sketch(jax.random.fold_in(key, 1), p, padn)
    t_pad = sketch.sketched_power_traces(Rp, S, maxp)
    c = float(jnp.sum(jnp.square(S[:, n:])))
    t_real = sketch.sketched_power_traces(R, S[:, :n], maxp)
    np.testing.assert_allclose(np.asarray(t_pad) - c, np.asarray(t_real),
                               rtol=1e-4, atol=1e-4)


def test_pad_invariance_exact_fit(key):
    """Padded rows/cols do not perturb the polar factor of the real block:
    the padded-bucket result equals the unpadded per-leaf result."""
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate([(64, 64), (64, 60), (60, 64),
                                    (2, 64, 64)])]
    ocfg = OptimizerConfig(prism=EXACT, bucket_pad=True)
    buckets = bucketing.plan_buckets([v.shape for v in views], pad=True,
                                     pad_slack=0.25)
    # (64, 60) merges into the padded (64, 64) bucket; (60, 64) would
    # need non-Gram-side padding and stays its own exact bucket
    sizes = {b.shape: (b.size, b.padded) for b in buckets}
    assert sizes == {(64, 64): (4, True), (60, 64): (1, False)}
    outs = bucketing.polar_bucketed(views, ocfg, key)
    for v, o in zip(views, outs):
        ref = matfn.polar(v, method="prism", cfg=EXACT, key=None)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=5e-5, atol=5e-5)


def test_pad_invariance_sketched(key):
    """Sketched fit with the n_real correction: padded-bucket polar still
    converges to the true orthogonal factor of the real block."""
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate([(64, 64), (64, 56)])]
    ocfg = OptimizerConfig(prism=PrismConfig(degree=2, iterations=10,
                                             warm_alpha_iters=2,
                                             sketch_dim=8),
                           bucket_pad=True)
    outs = bucketing.polar_bucketed(views, ocfg, key)
    for v, o in zip(views, outs):
        ref = matfn.polar(v, method="svd")
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=5e-3, atol=5e-3)


# --------------------------------------------------- launch-count contract

def _count_pallas_launches(fn, *args):
    from repro.kernels import ops

    return ops.count_launches(fn, *args)


@pytest.mark.parametrize("degree", [1, 2])
def test_constant_launch_count(monkeypatch, key, degree):
    """One fitted PRISM-NS iteration over a [B, n, n] bucket issues a
    constant number of Pallas launches, independent of B and of the sketch
    chain length max_power = 4d+2: with the fused tier (the default for
    VMEM-fitting buckets, DESIGN.md §10) exactly 2 — residual+chain, then
    the fused Horner — independent of d as well; the §7 batch-grid tier
    (fuse="off") keeps its 2 + d contract."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    for fuse, want in (("auto", 2), ("off", 2 + degree)):
        cfg = PrismConfig(degree=degree, iterations=1, warm_alpha_iters=0,
                          sketch_dim=8, use_kernels=True, fuse=fuse)
        counts = []
        for B in (1, 4, 16):
            A = jnp.zeros((B, 64, 48))
            counts.append(_count_pallas_launches(
                lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key),
                A))
        assert counts == [want] * 3, (fuse, counts)


def test_trainer_skip_step_zero_matfn_launches(monkeypatch, key):
    """The staleness contract (DESIGN.md §8): a FULL trainer step compiled
    with the static skip variant (refresh=False) issues ZERO matrix-
    function kernel launches — the cached orthogonalized views serve the
    update — while the refresh variant issues the bucketed counts."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, make_batch_fn
    from repro.models import build
    from repro.train.state import make_train_step, master_params

    cfg = get_smoke_config("gpt2-paper").replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128)
    model = build(cfg)
    ocfg = OptimizerConfig(
        name="muon", precond_every=4,
        prism=PrismConfig(degree=2, iterations=2, warm_alpha_iters=1,
                          sketch_dim=8, use_kernels=True))
    opt = make_optimizer(ocfg, model.logical_axes())
    step_fn = make_train_step(model, opt, ocfg)
    params = master_params(model.init(key))
    state = opt.init(params)
    batch = make_batch_fn(cfg, DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=16, global_batch=2,
                                          markov_rank=8))(jnp.asarray(0))
    step = jnp.asarray(0, jnp.int32)
    n_skip = _count_pallas_launches(
        lambda p, s, b: step_fn(p, s, b, step, False), params, state, batch)
    n_refresh = _count_pallas_launches(
        lambda p, s, b: step_fn(p, s, b, step, True), params, state, batch)
    assert n_skip == 0, n_skip
    assert n_refresh > 0, n_refresh


def test_fitted_iteration_launches_scale_with_iters_only(monkeypatch, key):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    def n_launches(iters, warm, fuse="auto"):
        cfg = PrismConfig(degree=2, iterations=iters, warm_alpha_iters=warm,
                          sketch_dim=8, use_kernels=True, fuse=fuse)
        return _count_pallas_launches(
            lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key),
            jnp.zeros((8, 64, 64)))
    # fused tier (§10): 2 per fitted iteration, 1 for the whole warm tail
    assert n_launches(3, 0) == 6
    assert n_launches(3, 1) == 1 + 2 * 2
    assert n_launches(3, 3) == 1
    # §7 batch-grid tier: fitted 2+d, warm skips the chain (1+d)
    assert n_launches(3, 0, fuse="off") == 12
    assert n_launches(3, 1, fuse="off") == 11
