"""Data-pipeline determinism + checkpoint save/restore/fault-tolerance."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.data import DataConfig, make_batch_fn, sample_tokens
from repro.configs import get_smoke_config


def test_data_deterministic_across_calls():
    cfg = DataConfig(vocab_size=128, seq_len=64, global_batch=4)
    a = sample_tokens(cfg, 7, 4)
    b = sample_tokens(cfg, 7, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = sample_tokens(cfg, 8, 4)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_data_learnable_structure():
    """Bigram source: empirical transition matrix is far from uniform."""
    cfg = DataConfig(vocab_size=16, seq_len=512, global_batch=8,
                     markov_rank=4)
    toks = np.asarray(sample_tokens(cfg, 0, 8))
    counts = np.zeros((16, 16))
    for row in toks:
        np.add.at(counts, (row[:-1], row[1:]), 1)
    probs = counts / np.maximum(counts.sum(1, keepdims=True), 1)
    # KL from uniform should be clearly positive
    kl = np.nansum(probs * np.log(np.maximum(probs, 1e-12) * 16))
    assert kl > 1.0


def test_batch_fn_families():
    for arch in ["musicgen-medium", "llava-next-34b", "qwen3-14b"]:
        mcfg = get_smoke_config(arch)
        dcfg = DataConfig(vocab_size=mcfg.vocab_size, seq_len=16,
                          global_batch=2)
        batch = make_batch_fn(mcfg, dcfg)(0)
        if mcfg.family == "audio":
            assert batch["tokens"].shape == (2, mcfg.num_codebooks, 16)
        else:
            assert batch["tokens"].shape == (2, 16)
        if mcfg.family == "vlm":
            assert batch["patches"].shape == (2, mcfg.num_patches,
                                              mcfg.vision_dim)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mom": jnp.ones((3, 4)), "count": jnp.asarray(5)}}
    ckpt.save(str(tmp_path), 10, tree)
    step, restored = ckpt.restore(str(tmp_path), tree)
    assert step == 10
    np.testing.assert_array_equal(restored["w"], tree["w"])
    np.testing.assert_array_equal(restored["opt"]["count"], 5)


def test_checkpoint_keeps_latest_k(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in [1, 2, 3, 4]:
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_async(tmp_path):
    tree = {"x": jnp.full((1000,), 3.0)}
    t = ckpt.save(str(tmp_path), 1, tree, async_write=True)
    assert isinstance(t, threading.Thread)
    t.join(timeout=30)
    step, restored = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(restored["x"], tree["x"])


def test_checkpoint_crash_mid_write_is_ignored(tmp_path):
    """A stale .tmp dir (simulated crash) must not break restore."""
    tree = {"x": jnp.ones(4)}
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crashed later write
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1
    step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones((2, 2))})
    try:
        ckpt.restore(str(tmp_path), {"x": jnp.ones((3, 3))})
        raise AssertionError("should have raised")
    except ValueError:
        pass
