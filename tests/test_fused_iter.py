"""Fused single-launch iteration tier (DESIGN.md §10).

Covers: seeded interpret-mode fuzz of the fused kernels against the
ref.py oracles over non-divisible (B, m, n) shapes x {fp32,
bf16-in/fp32-accum}; the launch-count contract (<= 2 launches per fitted
iteration, exactly 1 launch for a whole warm tail, independent of B, d,
warm length and dtype); fused-vs-unfused numerics at the dtype-principled
tolerances of tests/test_precision.py; the trace-time VMEM-budget tier
choice; the fp32-alpha epilogue invariant; and the sketch-chain VMEM
guard's per-step fallback.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn
from repro.core import newton_schulz as ns
from repro.kernels import fused_iter as fi
from repro.kernels import ref
from repro.optim import bucketing

pytestmark = pytest.mark.tier1

DTYPES = [jnp.float32, jnp.bfloat16]
U_BF16 = 2.0 ** -8

# deterministic fuzz corpus: non-divisible (B, m, n) drawn once at import
# so every CI run sweeps the same shapes (rerunnable failures)
_FUZZ_RNG = np.random.default_rng(7)
FUZZ_SHAPES = [tuple(int(x) for x in (_FUZZ_RNG.integers(1, 4),
                                      _FUZZ_RNG.integers(8, 90),
                                      _FUZZ_RNG.integers(4, 70)))
               for _ in range(5)]


def _tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-2)


def _coeffs(degree):
    from repro.core import polynomials as poly

    return tuple(float(c) for c in poly.taylor_inv_sqrt(degree - 1))


def _st(S, dtype):
    p = S.shape[0]
    return jnp.pad(S.T.astype(dtype), ((0, 0), (0, (-p) % 128)))


def _sym(key, B, n, dtype, scale=8.0):
    X = jax.random.normal(key, (B, n, n)) / scale
    return (0.5 * (X + jnp.swapaxes(X, -1, -2))).astype(dtype)


# ------------------------------------------------------------- kernel fuzz


@pytest.mark.parametrize("B,m,n", FUZZ_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_residual_chain_polar(key, B, m, n, dtype):
    """Fused residual+chain == ref oracle on non-divisible shapes."""
    kx, ks = jax.random.split(key)
    m, n = max(m, n), min(m, n)  # polar orientation
    X = (jax.random.normal(kx, (B, m, n)) / np.sqrt(m)).astype(dtype)
    p = 1 + n % 8
    S = (jax.random.normal(ks, (p, n)) / np.sqrt(p)).astype(dtype)
    R, t = fi.residual_chain(X, _st(S, dtype), 6, family="polar",
                             interpret=True)
    Rr, tr = ref.residual_chain(X, S, 6, family="polar")
    np.testing.assert_allclose(np.asarray(R, np.float32),
                               np.asarray(Rr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), **_tol(dtype))


@pytest.mark.parametrize("B,m,n", FUZZ_SHAPES[:3])
@pytest.mark.parametrize("family", ["sign", "sqrt"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_residual_chain_square(key, B, m, n, family, dtype):
    del m  # square families
    kx, ks = jax.random.split(key)
    X = _sym(kx, B, n, dtype)
    Y = jnp.broadcast_to(jnp.eye(n, dtype=dtype), X.shape) \
        if family == "sqrt" else None
    S = (jax.random.normal(ks, (8, n)) / np.sqrt(8)).astype(dtype)
    R, t = fi.residual_chain(X, _st(S, dtype), 5, family=family, Y=Y,
                             interpret=True)
    Rr, tr = ref.residual_chain(X, S, 5, family=family, Y=Y)
    np.testing.assert_allclose(np.asarray(R, np.float32),
                               np.asarray(Rr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), **_tol(dtype))


@pytest.mark.parametrize("B,m,n", FUZZ_SHAPES)
@pytest.mark.parametrize("degree", [1, 2])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_apply_g(key, B, m, n, degree, dtype):
    """Fused Horner == oracle; per-slice fp32 alphas enter unrounded."""
    kx, ka = jax.random.split(key)
    m, n = max(m, n), min(m, n)
    X = (jax.random.normal(kx, (B, m, n)) / np.sqrt(m)).astype(dtype)
    R = ref._residual(X, family="polar")
    a = jax.random.uniform(ka, (B,), jnp.float32, 0.4, 1.45)
    got = fi.apply_g(X, R, a, coeffs=_coeffs(degree), interpret=True)
    want = ref.apply_g(X, R, a, coeffs=_coeffs(degree))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_apply_g_coupled(key, dtype):
    kx, ka = jax.random.split(key)
    X = _sym(kx, 2, 45, dtype)
    Y = _sym(jax.random.fold_in(kx, 1), 2, 45, dtype)
    R = ref._residual(X, Y, family="sqrt")
    a = jax.random.uniform(ka, (2,), jnp.float32, 0.4, 1.45)
    gx, gy = fi.apply_g(X, R, a, coeffs=_coeffs(2), Y=Y, interpret=True)
    wx, wy = ref.apply_g(X, R, a, coeffs=_coeffs(2), Y=Y)
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(wx, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(gy, np.float32),
                               np.asarray(wy, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,m,n", FUZZ_SHAPES[:3])
@pytest.mark.parametrize("family", ["polar", "sign", "sqrt"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fuzz_warm_tail(key, B, m, n, family, dtype):
    """One multi-iteration launch == the per-iteration oracle loop."""
    alphas = (1.45, 1.2, 0.9)
    if family == "polar":
        m, n = max(m, n), min(m, n)
        X = (jax.random.normal(key, (B, m, n)) / np.sqrt(m)).astype(dtype)
        Y = None
    else:
        X = _sym(key, B, n, dtype, scale=2 * np.sqrt(n))
        if family == "sqrt":
            X = (jnp.matmul(X, jnp.swapaxes(X, -1, -2),
                            preferred_element_type=jnp.float32)
                 + 0.4 * jnp.eye(n)).astype(dtype)
            Y = jnp.broadcast_to(jnp.eye(n, dtype=dtype), X.shape)
        else:
            Y = None
    arr = jnp.asarray(alphas, jnp.float32)
    got = fi.warm_tail(X, arr, len(alphas), family=family,
                       coeffs=_coeffs(2), Y=Y, interpret=True)
    want = ref.warm_tail(X, alphas, coeffs=_coeffs(2), family=family, Y=Y)
    if family == "sqrt":
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32),
                                       **_tol(dtype))
    else:
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))


# ------------------------------------------------- launch-count contracts


def _count(fn, *args):
    from repro.kernels import ops

    return ops.count_launches(fn, *args)


@pytest.mark.parametrize("degree", [1, 2])
@pytest.mark.parametrize("B", [1, 4, 16])
def test_fitted_iteration_two_launches(monkeypatch, key, degree, B):
    """The §10 contract: a fitted iteration is <= 2 launches — fused
    residual+chain, then the fused Horner — independent of B AND d (the
    §7 tier still scaled as 2+d)."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = PrismConfig(degree=degree, iterations=1, warm_alpha_iters=0,
                      sketch_dim=8, use_kernels=True, fuse="on")
    A = jnp.zeros((B, 64, 48))
    n = _count(lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key),
               A)
    assert n == 2, (degree, B, n)


@pytest.mark.parametrize("warm", [1, 3, 6])
@pytest.mark.parametrize("degree", [1, 2])
def test_warm_tail_single_launch(monkeypatch, key, warm, degree):
    """The whole warm tail is EXACTLY one launch, independent of its
    length, of d, and of B."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = PrismConfig(degree=degree, iterations=warm,
                      warm_alpha_iters=warm, sketch_dim=8,
                      use_kernels=True, fuse="on")
    for B in (1, 8):
        A = jnp.zeros((B, 64, 48))
        n = _count(lambda A: matfn.polar(A, method="prism", cfg=cfg,
                                         key=key), A)
        assert n == 1, (warm, degree, B, n)


def test_whole_call_launches(monkeypatch, key):
    """warm run + fitted tail: 1 + 2 * n_fitted launches; a classical
    (constant-alpha) chain is ONE launch end to end."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    A = jnp.zeros((4, 64, 64))
    cfg = PrismConfig(degree=2, iterations=5, warm_alpha_iters=2,
                      sketch_dim=8, use_kernels=True, fuse="on")
    n = _count(lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key),
               A)
    assert n == 1 + 2 * 3, n
    ccfg = PrismConfig(degree=2, iterations=8, use_kernels=True, fuse="on")
    n = _count(lambda A: matfn.polar(A, method="newton_schulz", cfg=ccfg),
               A)
    assert n == 1, n


def test_launches_dtype_blind(monkeypatch, key):
    """bf16 changes tile contents, never the fused dispatch structure."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    counts = {}
    for dt in ("float32", "bfloat16"):
        cfg = PrismConfig(degree=2, iterations=4, warm_alpha_iters=2,
                          sketch_dim=8, use_kernels=True, fuse="on",
                          dtype=dt)
        A = jnp.zeros((3, 64, 48), jnp.dtype(dt))
        counts[dt] = _count(
            lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key), A)
    assert counts["float32"] == counts["bfloat16"] == 1 + 2 * 2, counts


def test_coupled_sqrt_launch_contract(monkeypatch, key):
    """The coupled family fuses both Horner applications into the second
    launch: fitted <= 2, warm tail == 1 (Shampoo's inverse-root path)."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    A = jnp.zeros((2, 48, 48)) + jnp.eye(48)
    cfg = PrismConfig(degree=2, iterations=4, warm_alpha_iters=2,
                      sketch_dim=8, use_kernels=True, fuse="on")
    n = _count(lambda A: matfn.sqrtm(A, cfg=cfg, key=key)[1], A)
    assert n == 1 + 2 * 2, n


# ------------------------------------------------------ tier + numerics


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fused_vs_unfused_polar(monkeypatch, key, dtype):
    """Fused and unfused tiers agree at the dtype-principled tolerances
    of tests/test_precision.py (fp32 fp-tight; bf16 O(u_bf16 kappa))."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    A = jax.random.normal(key, (3, 72, 40)).astype(dtype)
    outs = {}
    for fuse in ("on", "off"):
        cfg = PrismConfig(degree=2, iterations=8, warm_alpha_iters=2,
                          sketch_dim=8, use_kernels=True, fuse=fuse,
                          dtype=dtype)
        outs[fuse] = np.asarray(
            matfn.polar(A, method="prism", cfg=cfg, key=key), np.float32)
    err = np.linalg.norm(outs["on"] - outs["off"]) / \
        np.linalg.norm(outs["off"])
    assert err < (1e-5 if dtype == "float32" else 16 * U_BF16), err


def test_fused_pad_to_bucket_invariance(key):
    """The fused tier composes with §7 pad-to-bucket: the n_real trace
    correction flows through the fused chain's traces unchanged."""
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate([(64, 64), (64, 56)])]
    ocfg = OptimizerConfig(prism=PrismConfig(degree=2, iterations=10,
                                             warm_alpha_iters=2,
                                             sketch_dim=8,
                                             use_kernels=True, fuse="on"),
                           bucket_pad=True)
    outs = bucketing.polar_bucketed(views, ocfg, key)
    for v, o in zip(views, outs):
        want = matfn.polar(v, method="svd")
        np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                   rtol=5e-3, atol=5e-3)


def test_tier_resolution_budget(monkeypatch):
    """fused_fits is a pure trace-time shape test against the VMEM
    budget: B never enters, tiny budgets force the §7 tier, and
    bucketing pins auto -> on/off per bucket."""
    from repro.kernels import ops

    assert ops.fused_fits((64, 48), jnp.float32)
    assert not ops.fused_fits((4096, 4096), jnp.float32)
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "1024")
    assert not ops.fused_fits((64, 48), jnp.float32)
    monkeypatch.delenv("REPRO_VMEM_BUDGET")
    # config override beats the env default
    assert not ops.fused_fits((64, 48), jnp.float32, budget=1024)

    b = bucketing.plan_buckets([(64, 48), (64, 48)])[0]
    pc = PrismConfig(use_kernels=True)
    assert bucketing.resolve_fused_tier(pc, b).fuse == "on"
    assert bucketing.resolve_fused_tier(
        PrismConfig(use_kernels=True, vmem_budget=1024), b).fuse == "off"
    assert bucketing.resolve_fused_tier(
        PrismConfig(use_kernels=True, fuse="off"), b).fuse == "off"


def test_tier_auto_switches_launch_structure(monkeypatch, key):
    """auto under a tiny budget falls back to the §7 per-launch tier
    (2+d per fitted iteration); under the default budget it fuses."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = PrismConfig(degree=2, iterations=1, warm_alpha_iters=0,
                      sketch_dim=8, use_kernels=True)
    A = jnp.zeros((2, 64, 48))

    def n_launches():
        return _count(
            lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key), A)

    assert n_launches() == 2
    # 200 KB: the whole-chain kernel still fits (so the §7 tier keeps its
    # single-launch chain) but the fused iteration working set does not
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "200000")
    assert n_launches() == 2 + 2
    # 4 KB: the chain guard trips too — per-step fallback, max_power
    # launches for the chain alone (still bounded VMEM, never over budget)
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert n_launches() == 1 + 10 + 2
    monkeypatch.delenv("REPRO_VMEM_BUDGET")
    assert n_launches() == 2


# ------------------------------------------------ fp32 alpha epilogue (§9)


def test_alpha_enters_fp32(key):
    """The fitted fp32 alpha reaches the update unrounded: pre-rounding
    it to bf16 (the old `jnp.asarray(alpha, X.dtype)`) visibly changes
    the result, and the jnp path matches the fused oracle for d=1 (where
    the two accumulation orders coincide bit for bit)."""
    X = (jax.random.normal(key, (40, 32)) / 6).astype(jnp.bfloat16)
    R = ref._residual(X, family="polar")
    a = 4.0 / 3.0  # bf16 rounds it half an ulp away: a*X rounds visibly
    a16 = float(jnp.asarray(a, jnp.bfloat16))
    assert a16 != a
    got = ns.apply_g(X, R, a, 1, "right")
    pre_rounded = ns.apply_g(X, R, a16, 1, "right")
    oracle = ref.apply_g(X, R, a, coeffs=_coeffs(1))
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(oracle, np.float32))
    assert not np.array_equal(np.asarray(got, np.float32),
                              np.asarray(pre_rounded, np.float32))


def test_alpha_fp32_noop_for_fp32(key):
    """For fp32 compute the fix is a no-op: alpha was already fp32."""
    X = jax.random.normal(key, (24, 16)) / 5
    R = ref._residual(X, family="polar")
    got = ns.apply_g(X, R, 0.87654321, 2, "right")
    want = ref.apply_g(X, R, 0.87654321, coeffs=_coeffs(2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


# --------------------------------------------- sketch-chain VMEM guard


def test_sketch_chain_vmem_guard(monkeypatch, key):
    """Over-budget chains fall back to the bounded per-step sketch_step
    loop — max_power launches instead of one, identical numerics."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    from repro.kernels import ops

    R = _sym(key, 2, 64, jnp.float32)
    S = jax.random.normal(jax.random.fold_in(key, 1), (8, 64)) / np.sqrt(8)
    maxp = 6
    want = ref.sketch_traces(R, S, maxp)

    assert _count(lambda R, S: ops.sketch_traces(R, S, maxp), R, S) == 1
    got = ops.sketch_traces(R, S, maxp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")  # chain needs ~100KB
    assert _count(lambda R, S: ops.sketch_traces(R, S, maxp), R, S) == maxp
    got = ops.sketch_traces(R, S, maxp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    monkeypatch.delenv("REPRO_VMEM_BUDGET")

    # the config knob reaches the guard too: a fitted unfused iteration
    # with a tiny PrismConfig.vmem_budget falls back to the per-step
    # chain — gram + max_power steps + d Horner launches
    cfg = PrismConfig(degree=2, iterations=1, warm_alpha_iters=0,
                      sketch_dim=8, use_kernels=True, fuse="off",
                      vmem_budget=4096)
    n = _count(lambda A: matfn.polar(A, method="prism", cfg=cfg, key=key),
               jnp.zeros((2, 64, 48)))
    assert n == 1 + 10 + 2, n


def test_batched_sketch_step(key):
    """sketch_step grew the §7 batch grid: [B, n, p] chains in one
    launch per step, matching the 2-D contract per slice."""
    from repro.kernels import sketch_traces as sk_kernel

    R = _sym(key, 3, 40, jnp.float32)
    S = jax.random.normal(jax.random.fold_in(key, 1), (8, 40)) / np.sqrt(8)
    St = _st(S, jnp.float32)
    V = jnp.broadcast_to(St, (3,) + St.shape)
    Vb, tb = sk_kernel.sketch_step(R, V, St, bm=32, bk=32, interpret=True)
    for b in range(3):
        v2, t2 = sk_kernel.sketch_step(R[b], St, St, bm=32, bk=32,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(Vb[b]), np.asarray(v2),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(tb[b]), float(t2), rtol=2e-5)
