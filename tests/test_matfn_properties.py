"""Hypothesis property tests on matrix-function invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import PrismConfig
from repro.core import matfn
from repro.core import random_matrices as rm

CFG = PrismConfig(degree=2, sketch_dim=8)


def _mat(seed, n, m, smin):
    key = jax.random.PRNGKey(seed)
    return rm.log_uniform_spectrum(key, n, m, smin)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([24, 40, 64]),
       st.floats(1e-3, 0.9))
def test_polar_idempotent(seed, n, smin):
    """polar(polar(A)) == polar(A): the polar factor is a fixed point."""
    A = _mat(seed, n + 16, n, smin)
    key = jax.random.PRNGKey(seed + 1)
    X = matfn.polar(A, method="prism", cfg=CFG, key=key, iters=20)
    X2 = matfn.polar(X, method="prism", cfg=CFG, key=key, iters=6)
    np.testing.assert_allclose(np.asarray(X2), np.asarray(X),
                               rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 48]))
def test_sign_is_involution(seed, n):
    """sign(A)^2 == I for symmetric nonsingular A."""
    key = jax.random.PRNGKey(seed)
    eigs = jnp.concatenate([jnp.linspace(-1, -0.15, n // 2),
                            jnp.linspace(0.15, 1, n - n // 2)])
    A = rm.spd_with_eigs(key, n, eigs)
    S = matfn.signm(A, method="prism", cfg=CFG, key=key, iters=16)
    np.testing.assert_allclose(np.asarray(S @ S), np.eye(n),
                               rtol=0, atol=2e-2)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 32]),
       st.floats(0.05, 0.8))
def test_sqrt_squares_back(seed, n, lo):
    key = jax.random.PRNGKey(seed)
    A = rm.spd_with_eigs(key, n, jnp.linspace(lo, 1.0, n))
    sq, isq = matfn.sqrtm(A, method="prism", cfg=CFG, key=key, iters=18)
    np.testing.assert_allclose(np.asarray(sq @ sq), np.asarray(A),
                               rtol=0, atol=2e-2)
    # sqrt and inv-sqrt are mutual inverses
    np.testing.assert_allclose(np.asarray(sq @ isq), np.eye(n),
                               rtol=0, atol=2e-2)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4]))
def test_inv_proot_power_consistency(seed, p):
    """(A^{-1/p})^p == A^{-1}."""
    key = jax.random.PRNGKey(seed)
    n = 24
    A = rm.spd_with_eigs(key, n, jnp.linspace(0.2, 1.0, n))
    X = matfn.inv_proot(A, p=p, iters=30, key=key)
    Xp = X
    for _ in range(p - 1):
        Xp = Xp @ X
    np.testing.assert_allclose(np.asarray(Xp @ A), np.eye(n),
                               rtol=0, atol=3e-2)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_polar_orthogonal_invariance(seed):
    """polar(Q A) == Q polar(A) for orthogonal Q (left invariance)."""
    key = jax.random.PRNGKey(seed)
    n = 32
    A = _mat(seed, n, n, 1e-2)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    X1 = matfn.polar(Q @ A, method="prism", cfg=CFG, key=key, iters=16)
    X2 = Q @ matfn.polar(A, method="prism", cfg=CFG, key=key, iters=16)
    np.testing.assert_allclose(np.asarray(X1), np.asarray(X2),
                               rtol=5e-3, atol=5e-3)
