"""Unit + property tests for the PRISM polynomial/trace machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()


from repro.core import polynomials as poly
from repro.core import prism, sketch
from repro.core import random_matrices as rm


def _weights_to_dict(row):
    return {i: v for i, v in enumerate(row) if abs(v) > 1e-12}


def test_taylor_inv_sqrt():
    # (1-x)^{-1/2} = 1 + x/2 + 3x^2/8 + 5x^3/16 + 35x^4/128 + ...
    c = poly.taylor_inv_sqrt(4)
    np.testing.assert_allclose(c, [1, 0.5, 0.375, 0.3125, 0.2734375])


def test_paper_d1_trace_formulas():
    """Generic machinery reproduces the paper's hand-derived d=1 c1..c4."""
    W = poly.trace_weight_matrix(poly.newton_schulz_residual(1))
    expect = {
        1: {3: 4.0, 2: -4.0},
        2: {4: 6.0, 3: -10.0, 2: 4.0},
        3: {5: 4.0, 4: -8.0, 3: 4.0},
        4: {6: 1.0, 5: -2.0, 4: 1.0},
    }
    for k, want in expect.items():
        assert _weights_to_dict(W[k]) == pytest.approx(want)


def test_paper_d2_trace_formulas():
    W = poly.trace_weight_matrix(poly.newton_schulz_residual(2))
    expect = {
        1: {7: 0.5, 6: 2.0, 5: 0.5, 4: -3.0},
        2: {8: 1.5, 7: 3.0, 6: -4.5, 5: -4.0, 4: 4.0},
        3: {9: 2.0, 7: -6.0, 6: 4.0},
        4: {10: 1.0, 9: -2.0, 8: 1.0},
    }
    for k, want in expect.items():
        assert _weights_to_dict(W[k]) == pytest.approx(want)


def test_paper_inverse_newton_p2_formulas():
    """App. A.3, p=2 coefficients (same as NS d=1 per the paper)."""
    W = poly.trace_weight_matrix(poly.inverse_newton_residual(2))
    expect = {
        1: {3: 4.0, 2: -4.0},
        2: {4: 6.0, 3: -10.0, 2: 4.0},
        3: {5: 4.0, 4: -8.0, 3: 4.0},
        4: {6: 1.0, 5: -2.0, 4: 1.0},
    }
    for k, want in expect.items():
        assert _weights_to_dict(W[k]) == pytest.approx(want)


def test_paper_chebyshev_formulas():
    """App. A.4: c1 = -2 t4 + 2 t5, c2 = t4 - 2 t5 + t6."""
    W = poly.trace_weight_matrix(poly.chebyshev_residual())
    assert _weights_to_dict(W[1]) == pytest.approx({4: -2.0, 5: 2.0})
    assert _weights_to_dict(W[2]) == pytest.approx({4: 1.0, 5: -2.0, 6: 1.0})


def test_paper_inverse_newton_p1_formulas():
    """App. A.3 p=1: c1 = 2 t3 - 2 t2, c2 = t4 - 2 t3 + t2."""
    W = poly.trace_weight_matrix(poly.inverse_newton_residual(1))
    assert _weights_to_dict(W[1]) == pytest.approx({3: 2.0, 2: -2.0})
    assert _weights_to_dict(W[2]) == pytest.approx({4: 1.0, 3: -2.0, 2: 1.0})


def test_residual_poly_eval_matches_definition():
    ap = poly.newton_schulz_residual(2)
    xs = jnp.linspace(-0.5, 1.0, 31)
    for a in [0.375, 0.8, 1.45]:
        g = 1 + xs / 2 + a * xs ** 2
        want = 1 - (1 - xs) * g ** 2
        np.testing.assert_allclose(ap.eval(xs, a), want, rtol=1e-5, atol=1e-6)


def test_objective_matches_direct_frobenius(key):
    """m(alpha) from the trace map == ||h(R; alpha)||_F^2 computed directly."""
    R = rm.spd_with_eigs(key, 24, jnp.linspace(-0.4, 0.9, 24))
    ap = poly.newton_schulz_residual(2)
    for a in [0.4, 0.9, 1.4]:
        m_trace = prism.objective_value(R, ap, a)
        w, V = jnp.linalg.eigh(R)
        hw = ap.eval(w, a)
        hR = (V * hw[None, :]) @ V.T
        direct = jnp.sum(hR ** 2)
        np.testing.assert_allclose(m_trace, direct, rtol=2e-4)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-3, 3), min_size=5, max_size=5),
       st.floats(-1, 1), st.floats(0.05, 2.0))
def test_minimize_quartic_matches_grid(cs, lo, width):
    hi = lo + width
    coeffs = jnp.asarray(cs, jnp.float32)
    a_closed = poly.minimize_quartic(coeffs, lo, hi)
    a_grid = poly.minimize_poly_grid(coeffs, lo, hi, num=2001, newton_iters=0)
    m_closed = poly._polyval_asc(coeffs, a_closed)
    m_grid = poly._polyval_asc(coeffs, a_grid)
    scale = 1.0 + float(jnp.abs(m_grid))
    assert float(m_closed) <= float(m_grid) + 1e-3 * scale
    tol = 1e-5 * (1 + abs(lo) + abs(hi))  # fp32 rounding of the bounds
    assert lo - tol <= float(a_closed) <= hi + tol


@settings(max_examples=40, deadline=None)
@given(st.floats(-2, 2), st.floats(-2, 2), st.floats(-2, 2), st.floats(-2, 2))
def test_cubic_roots_are_roots(a, b, c, d):
    roots = poly.cubic_roots(jnp.float32(a), jnp.float32(b), jnp.float32(c),
                             jnp.float32(d))
    # at least one returned candidate must (approximately) satisfy the cubic
    vals = [abs(float(((a * r + b) * r + c) * r + d)) for r in roots]
    scale = 1 + max(abs(a), abs(b), abs(c), abs(d))
    if abs(a) > 1e-3:  # caller handles degenerate leading coefficient
        assert min(vals) < 5e-2 * scale


def test_minimize_quartic_batched():
    coeffs = jnp.asarray([[0.0, -1.0, 1.0, 0.0, 0.0],
                          [0.0, 1.0, 1.0, 0.0, 0.0]], jnp.float32)
    a = poly.minimize_quartic(coeffs, 0.0, 2.0)
    np.testing.assert_allclose(a, [0.5, 0.0], atol=1e-5)
