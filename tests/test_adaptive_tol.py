"""Sketched-residual adaptive early stopping (DESIGN.md §11).

Covers the convergence-certificate engine end to end: the est_r
certificate itself (exact, sketched, pad-corrected), the property that
an early exit never certifies a residual above tol (oracle ||R||_F
checks across families x dtypes x seeded spectra), bitwise stability of
frozen converged slices, tol-/dtype-blindness of the §10 launch
contracts, and the iters_used telemetry surfaced through bucketing into
the Muon/Shampoo state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, PrismConfig
from repro.core import matfn, prism, sketch
from repro.core import polynomials as poly
from repro.core import random_matrices as rm
from repro.optim import bucketing, make_optimizer

pytestmark = pytest.mark.tier1


def _cfg(tol, dtype="float32", iters=14, warm=1, sketch_dim=8, **kw):
    return PrismConfig(degree=2, iterations=iters, warm_alpha_iters=warm,
                       sketch_dim=sketch_dim, dtype=dtype, tol=tol, **kw)


def _polar_residual(A, X):
    """Oracle ||I - X^T X||_F of the polar factor, per batch slice."""
    X = X.astype(jnp.float32)
    if A.shape[-2] < A.shape[-1]:
        X = jnp.swapaxes(X, -1, -2)
    G = jnp.swapaxes(X, -1, -2) @ X
    return jnp.linalg.norm(jnp.eye(X.shape[-1]) - G, axis=(-2, -1))


# ----------------------------------------------------------- the certificate

def test_est_r_exact_traces_equals_fro(key):
    """sketch_dim=0 / key=None: est_r is exactly ||R||_F (t_2 = tr R^2)."""
    R = jax.random.normal(key, (3, 24, 24)) / 24
    R = 0.5 * (R + jnp.swapaxes(R, -1, -2))
    apoly = poly.newton_schulz_residual(2)
    _, est = prism.fit_alpha(R, apoly, 0.375, 1.45, key=None,
                             return_est_r=True)
    np.testing.assert_allclose(np.asarray(est),
                               np.linalg.norm(np.asarray(R), axis=(1, 2)),
                               rtol=1e-5)


def test_est_r_sketched_unbiased(key):
    """The sketched certificate concentrates around ||R||_F (N(0,1/p)
    sketch => E[t_2] = tr R^2)."""
    R = jax.random.normal(key, (16, 16)) / 16
    R = 0.5 * (R + R.T)
    apoly = poly.newton_schulz_residual(2)
    ests = []
    for i in range(64):
        S = sketch.gaussian_sketch(jax.random.fold_in(key, i), 8, 16)
        t = sketch.sketched_power_traces(R, S, poly.max_trace_power(apoly))
        _, est = prism.fit_alpha_from_traces(t, apoly, 0.375, 1.45,
                                             return_est_r=True)
        ests.append(float(est) ** 2)
    true = float(jnp.sum(R * R))
    assert abs(np.mean(ests) - true) < 0.2 * true, (np.mean(ests), true)


def test_est_r_pad_corrected(key):
    """For R_pad = diag(R, I) the n_real correction makes est_r estimate
    the REAL block's norm — the pad block's identity contribution to t_2
    is subtracted exactly (DESIGN.md §7/§11)."""
    n, npad = 20, 32
    R = jax.random.normal(key, (n, n)) / (3 * np.sqrt(n))
    R = 0.5 * (R + R.T)
    Rp = jnp.eye(npad).at[:n, :n].set(R)
    S = sketch.gaussian_sketch(jax.random.fold_in(key, 1), 8, npad)
    apoly = poly.newton_schulz_residual(2)
    t = sketch.sketched_power_traces(Rp, S, poly.max_trace_power(apoly))
    _, est = prism.fit_alpha_from_traces(
        t, apoly, 0.375, 1.45, S=S,
        n_real=jnp.asarray(n, jnp.int32)[None], return_est_r=True)
    t_real = sketch.sketched_power_traces(R, S[:, :n],
                                          poly.max_trace_power(apoly))
    _, est_real = prism.fit_alpha_from_traces(t_real, apoly, 0.375, 1.45,
                                              return_est_r=True)
    np.testing.assert_allclose(float(est[0]), float(est_real), rtol=1e-4)


# ----------------------------------- (a) early exit never certifies above tol

def _spectra(key, n):
    """Seeded instance zoo: well-conditioned to near-rank-deficient."""
    return {
        "gaussian": rm.gaussian(key, n, n),
        "log_uniform": rm.log_uniform_spectrum(jax.random.fold_in(key, 1),
                                               n, n, 1e-3),
        "near_rank_def": rm.log_uniform_spectrum(jax.random.fold_in(key, 2),
                                                 n, n, 1e-5),
    }


@pytest.mark.parametrize("dtype,tol,slack", [("float32", 2e-2, 1.3),
                                             ("bfloat16", 0.5, 1.3)])
@pytest.mark.parametrize("spectrum", ["gaussian", "log_uniform",
                                      "near_rank_def"])
def test_polar_certifies_below_tol(key, dtype, tol, slack, spectrum):
    """Certified slices really sit at/below tol (oracle check; the slack
    covers sketch variance at p=8 plus recompute rounding)."""
    A = _spectra(key, 48)[spectrum]
    cfg = _cfg(tol, dtype=dtype)
    X, used = matfn.polar(A, method="prism", cfg=cfg, key=key,
                          return_iters=True)
    res = float(_polar_residual(A, X))
    if int(used) < cfg.iterations:  # certified early => bound must hold
        assert res <= tol * slack, (spectrum, res, int(used))
    # adaptivity is real: the budget is generous enough to certify here
    assert int(used) < cfg.iterations, (spectrum, int(used), res)


def test_polar_exact_certificate_is_exact(key):
    """sketch_dim=0: the certificate IS ||R||_F, so the oracle bound
    holds with no sketch slack, for every slice of a mixed bucket."""
    A = jnp.stack(list(_spectra(key, 48).values()))
    cfg = _cfg(2e-2, sketch_dim=0)
    X, used = matfn.polar(A, method="prism", cfg=cfg, key=None,
                          return_iters=True)
    res = np.asarray(_polar_residual(A, X))
    early = np.asarray(used) < cfg.iterations
    assert early.all(), (np.asarray(used), res)
    np.testing.assert_array_less(res, 2e-2 * 1.02)


@pytest.mark.parametrize("dtype,tol,slack", [("float32", 2e-2, 1.3),
                                             ("bfloat16", 0.5, 1.3)])
def test_sqrtm_certifies_below_tol(key, dtype, tol, slack):
    S = rm.spd_with_eigs(key, 32, jnp.linspace(1e-3, 1.0, 32))
    cfg = _cfg(tol, dtype=dtype)
    (sq, isq), used = matfn.sqrtm(S, method="prism", cfg=cfg, key=key,
                                  return_iters=True)
    # oracle residual of the coupled iteration: ||I - Y X||_F on the
    # normalized problem == ||I - A^{-1/2} A^{1/2}||-style consistency
    c = float(jnp.linalg.norm(S))
    Xn = sq.astype(jnp.float32) / np.sqrt(c)
    Yn = isq.astype(jnp.float32) * np.sqrt(c)
    res = float(jnp.linalg.norm(jnp.eye(32) - Yn @ Xn))
    assert int(used) < cfg.iterations
    assert res <= tol * slack, (res, int(used))


def test_signm_certifies_below_tol(key):
    A = rm.spd_with_eigs(key, 32, jnp.linspace(0.05, 1.0, 32))
    cfg = _cfg(2e-2)
    X, used = matfn.signm(A, method="prism", cfg=cfg, key=key,
                          return_iters=True)
    # sign of SPD is I; oracle residual of the sign iteration is
    # ||I - X^2||_F
    X32 = X.astype(jnp.float32)
    res = float(jnp.linalg.norm(jnp.eye(32) - X32 @ X32))
    assert int(used) < cfg.iterations
    assert res <= 2e-2 * 1.3, (res, int(used))


def test_chebyshev_inv_certifies_below_tol(key):
    B = rm.spd_with_eigs(key, 32, jnp.linspace(0.05, 1.0, 32))
    inv, used = matfn.inv(B, method="prism_chebyshev", iters=40, key=key,
                          tol=1e-3, return_iters=True)
    # residual of the normalized chebyshev iterate: I - (A/c) (c X)
    res = float(jnp.linalg.norm(jnp.eye(32) - B @ inv))
    assert int(used) < 40
    assert res <= 1e-3 * 1.2, (res, int(used))


@pytest.mark.parametrize("p", [1, 2, 4])
def test_inverse_newton_certifies_below_tol(key, p):
    B = rm.spd_with_eigs(key, 32, jnp.linspace(0.05, 1.0, 32))
    out, used = matfn.inv_proot(B, p=p, iters=40, key=key, tol=1e-3,
                                return_iters=True)
    ref = matfn.inv_proot(B, p=p, method="eigh")
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert int(used) < 40
    # est_r certifies ||I - M_k||_F = ||I - X^p A / c^p||; the relative
    # error of X against A^{-1/p} is within a small factor of it here
    assert rel <= 5e-3, (p, rel, int(used))


def test_budget_exhaustion_no_certificate(key):
    """An unreachable tol runs the whole budget and never freezes."""
    A = rm.log_uniform_spectrum(key, 32, 32, 1e-4)
    cfg = _cfg(1e-30, iters=4)
    X, used = matfn.polar(A, method="prism", cfg=cfg, key=key,
                          return_iters=True)
    assert int(used) == 4
    assert bool(jnp.all(jnp.isfinite(X)))


def test_instance_adaptive_counts(key):
    """The §11 headline: in one bucket, a well-conditioned instance
    certifies strictly earlier than a near-rank-deficient one."""
    A = jnp.stack([rm.gaussian(key, 48, 48),
                   rm.log_uniform_spectrum(jax.random.fold_in(key, 1),
                                           48, 48, 1e-5)])
    X, used = matfn.polar(A, method="prism", cfg=_cfg(2e-2), key=key,
                          return_iters=True)
    used = np.asarray(used)
    assert used[0] < used[1], used
    np.testing.assert_array_less(np.asarray(_polar_residual(A, X)),
                                 2e-2 * 1.3)


# ----------------------------------------- (b) frozen slices: bitwise-stable

def test_frozen_slice_bitwise_stable_polar(key):
    """Once a slice certifies, later loop iterations (driven by the
    bucket's stragglers) must not touch it: truncating the budget right
    after the fast slice freezes yields the BITWISE-identical output."""
    A = jnp.stack([rm.gaussian(key, 48, 48),
                   rm.log_uniform_spectrum(jax.random.fold_in(key, 1),
                                           48, 48, 1e-5)])
    X_full, used = matfn.polar(A, method="prism", cfg=_cfg(2e-2, iters=14),
                               key=key, return_iters=True)
    used = np.asarray(used)
    assert used[0] < used[1] <= 14
    # budget cut to just past the fast slice's certificate: the fast
    # slice's frozen iterate must be unchanged bit for bit
    cut = int(used[0]) + 1
    X_cut, used_cut = matfn.polar(A, method="prism",
                                  cfg=_cfg(2e-2, iters=cut), key=key,
                                  return_iters=True)
    assert int(np.asarray(used_cut)[0]) == int(used[0])
    np.testing.assert_array_equal(np.asarray(X_full[0]),
                                  np.asarray(X_cut[0]))


def test_frozen_slice_bitwise_stable_chebyshev(key):
    Bs = jnp.stack([rm.spd_with_eigs(key, 32, jnp.linspace(0.3, 1.0, 32)),
                    rm.spd_with_eigs(jax.random.fold_in(key, 1), 32,
                                     jnp.linspace(0.01, 1.0, 32))])
    inv_full, used = matfn.inv(Bs, iters=40, key=key, tol=1e-3,
                               return_iters=True)
    used = np.asarray(used)
    assert used[0] < used[1] <= 40
    cut = int(used[0]) + 1
    inv_cut, _ = matfn.inv(Bs, iters=cut, key=key, tol=1e-3,
                           return_iters=True)
    np.testing.assert_array_equal(np.asarray(inv_full[0]),
                                  np.asarray(inv_cut[0]))


def test_tol_none_bit_matches_pre_adaptive(key):
    """tol=None runs the static unrolled chains — and an adaptive run
    whose tol never certifies applies the identical sequence of updates
    (same per-iteration sketch keys, same alphas)."""
    A = rm.log_uniform_spectrum(key, 32, 32, 1e-4)
    X_static = matfn.polar(A, method="prism", cfg=_cfg(None, iters=4),
                           key=key)
    X_adapt, used = matfn.polar(A, method="prism",
                                cfg=_cfg(1e-30, iters=4), key=key,
                                return_iters=True)
    assert int(used) == 4
    np.testing.assert_allclose(np.asarray(X_static), np.asarray(X_adapt),
                               rtol=0, atol=1e-6)


# -------------------------- (c) launch contracts: tol- and dtype-blind (§10)

def _count(fn, *args):
    from repro.kernels import ops

    return ops.count_launches(fn, *args)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_launch_contract_adaptive_fused(monkeypatch, key, dtype):
    """Fused tier with an adaptive tol: ONE warm-tail launch plus the
    2-launch fitted body traced ONCE inside the while_loop — the §10
    per-iteration contract (fitted <= 2, warm tail == 1) is intact and
    the traced count is independent of B, budget, dtype and tol."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    for iters in (2, 5):
        for B in (1, 4):
            cfg = _cfg(1e-2, dtype=dtype, iters=iters, warm=1,
                       use_kernels=True, fuse="on")
            n = _count(lambda A: matfn.polar(A, method="prism", cfg=cfg,
                                             key=key),
                       jnp.zeros((B, 64, 48), jnp.dtype(dtype)))
            assert n == 1 + 2, (iters, B, n)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_launch_contract_adaptive_unfused(monkeypatch, key, dtype):
    """§7 batch-grid tier (fuse=off): warm tail 1+d launches, fitted
    body 2+d traced once — tol- and dtype-blind."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    d = 2
    for iters in (2, 5):
        cfg = _cfg(1e-2, dtype=dtype, iters=iters, warm=1,
                   use_kernels=True, fuse="off")
        n = _count(lambda A: matfn.polar(A, method="prism", cfg=cfg,
                                         key=key),
                   jnp.zeros((4, 64, 48), jnp.dtype(dtype)))
        assert n == (1 + d) + (2 + d), (iters, n)


def test_launch_count_tol_blind(monkeypatch, key):
    """Same budget, tol on vs off: the §10 static plan issues 2 launches
    PER fitted iteration, the adaptive plan traces the body once — so
    the adaptive TRACED count never exceeds the static one, and both
    keep the per-iteration contract."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")

    def n_launches(tol):
        cfg = _cfg(tol, iters=3, warm=1, use_kernels=True, fuse="on")
        return _count(lambda A: matfn.polar(A, method="prism", cfg=cfg,
                                            key=key),
                      jnp.zeros((4, 64, 64)))

    assert n_launches(None) == 1 + 2 * 2   # warm tail + 2 static fits
    assert n_launches(1e-2) == 1 + 2       # warm tail + while body once


# ------------------------------------------------- telemetry: bucket + state

def test_polar_bucketed_with_iters(key):
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate([(48, 32), (48, 32), (2, 64, 64)])]
    ocfg = OptimizerConfig(prism=_cfg(2e-2), matfn_tol=2e-2)
    outs, iters, statuses = bucketing.polar_bucketed(views, ocfg, key,
                                           with_iters=True)
    assert [i.shape for i in iters] == [(), (), (2,)]
    for v, o, it in zip(views, outs, iters):
        assert o.shape == v.shape
        assert int(np.max(np.asarray(it))) <= 14
        assert int(np.min(np.asarray(it))) >= 1


def test_polar_bucketed_padded_adaptive(key):
    """Pad-to-bucket + adaptive: certificates are pad-blind (n_real
    corrected), so real blocks still converge below tol."""
    views = [jax.random.normal(jax.random.fold_in(key, i), s)
             for i, s in enumerate([(64, 64), (64, 56)])]
    ocfg = OptimizerConfig(prism=_cfg(2e-2, iters=16, warm=2),
                           matfn_tol=2e-2, bucket_pad=True)
    outs, iters, statuses = bucketing.polar_bucketed(views, ocfg, key,
                                           with_iters=True)
    for v, o, it in zip(views, outs, iters):
        ref = matfn.polar(v, method="svd")
        err = float(jnp.linalg.norm(o - ref) / jnp.linalg.norm(ref))
        assert err < 5e-2, (v.shape, err, int(it))
        assert 1 <= int(it) <= 16


def test_muon_state_iters_telemetry(key):
    params = {"w1": jax.random.normal(key, (64, 32)),
              "w3": jax.random.normal(jax.random.fold_in(key, 2),
                                      (3, 48, 32)),
              "b": jax.random.normal(jax.random.fold_in(key, 4), (64,))}
    axes = {"w1": ("embed", "mlp"), "w3": ("layers", "embed", "mlp"),
            "b": ("embed",)}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 9), p.shape),
        params)
    ocfg = OptimizerConfig(name="muon", matfn_tol=1e-2,
                           prism=_cfg(None, iters=10), precond_every=2)
    assert ocfg.matfn_telemetry
    opt = make_optimizer(ocfg, axes)
    state = opt.init(params)
    assert state["leaves"]["w1"]["iters"].shape == ()
    assert state["leaves"]["w3"]["iters"].shape == (3,)
    assert "iters" not in state["leaves"]["b"]
    _, s1 = jax.jit(opt.update)(grads, state, params, 0, key)
    it1 = np.asarray(s1["leaves"]["w3"]["iters"])
    assert (1 <= it1).all() and (it1 <= 10).all(), it1
    # stale step (count=1, precond_every=2): telemetry carried untouched
    _, s2 = jax.jit(opt.update)(grads, s1, params, 1, key)
    np.testing.assert_array_equal(np.asarray(s2["leaves"]["w3"]["iters"]),
                                  it1)


def test_shampoo_state_iters_telemetry(key):
    params = {"w1": jax.random.normal(key, (64, 32))}
    axes = {"w1": ("embed", "mlp")}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 9), p.shape),
        params)
    ocfg = OptimizerConfig(name="shampoo", matfn_tol=1e-2,
                           prism=_cfg(None, iters=12), max_precond_dim=512)
    opt = make_optimizer(ocfg, axes)
    state = opt.init(params)
    assert state["leaves"]["w1"]["Linv_iters"].shape == ()
    _, s1 = jax.jit(opt.update)(grads, state, params, 0, key)
    for side in ("Linv_iters", "Rinv_iters"):
        it = int(s1["leaves"]["w1"][side])
        assert 1 <= it <= 12, (side, it)


def test_baseline_methods_telemetry_contract(key):
    """Fit-free methods honor return_iters uniformly (zeros — they
    certify nothing) instead of mis-unpacking or raising, and reject
    return_info (no iteration trajectory) loudly."""
    A = rm.spd_with_eigs(key, 16, jnp.linspace(0.1, 1.0, 16))
    ref = matfn.inv_sqrtm(A, method="eigh")
    out, it = matfn.inv_sqrtm(A, method="eigh", return_iters=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert out.shape == (16, 16) and int(it) == 0
    for fn, m in [(matfn.polar, "svd"), (matfn.signm, "eigh"),
                  (matfn.inv_proot, "eigh")]:
        args = (A, 2) if fn is matfn.inv_proot else (A,)
        o, it = fn(*args, method=m, return_iters=True)
        assert o.shape == (16, 16) and int(it) == 0, (m, o.shape)
        with pytest.raises(ValueError):
            fn(*args, method=m, return_info=True)
    o, it = matfn.inv(A, method="solve", return_iters=True)
    assert int(it) == 0
    (sq, isq), it = matfn.sqrtm(A, method="newton", return_iters=True)
    assert sq.shape == (16, 16) and int(it) == 0
    # fixed-schedule families flatten the combo to (out, info, iters)
    isq2, info, it2 = matfn.inv_sqrtm(A, method="polar_express",
                                      return_info=True, return_iters=True)
    assert isq2.shape == (16, 16) and it2.shape == () and int(it2) == 0
    X, _, it3 = matfn.polar(A, method="polar_express", return_info=True,
                            return_iters=True)
    assert X.shape == (16, 16) and int(it3) == 0


def test_no_telemetry_without_tol(key):
    ocfg = OptimizerConfig(name="muon", prism=_cfg(None))
    assert not ocfg.matfn_telemetry
    params = {"w1": jax.random.normal(key, (32, 16))}
    opt = make_optimizer(ocfg, {"w1": ("embed", "mlp")})
    assert "iters" not in opt.init(params)["leaves"]["w1"]
